"""Federated learning (paper Table II): JAX MLP on synthetic MNIST.

Per round, each client runs local SGD steps on its shard (one task per
client), then an aggregation task averages the weights (FedAvg), then an
evaluation task scores the global model.  Labels derive from a fixed random
linear map of the images, so the model genuinely learns and the test
asserts decreasing loss.
"""
from __future__ import annotations

import functools

import numpy as np

from repro.apps.base import register_app
from repro.engine.task import task
from repro.injection.engines import NoInjector

SCALES = {
    # (clients, rounds, local_epochs, samples_per_client)
    "tiny": (2, 2, 1, 64),
    "small": (4, 2, 2, 128),
    "medium": (8, 3, 3, 256),   # paper: 8 clients, 3 rounds, 3 epochs
    "paper": (8, 3, 3, 1024),
}

_IMG = 64        # flattened "image" size (synthetic MNIST proxy)
_CLASSES = 10
_HIDDEN = 32


def _client_data(client: int, n: int) -> tuple[np.ndarray, np.ndarray]:
    rng = np.random.default_rng(500 + client)
    x = rng.standard_normal((n, _IMG)).astype(np.float32)
    w_true = np.random.default_rng(42).standard_normal((_IMG, _CLASSES))
    y = np.argmax(x @ w_true, axis=1)
    return x, y


def init_params(seed: int = 0) -> dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    return {
        "w1": (rng.standard_normal((_IMG, _HIDDEN)) * 0.1).astype(np.float32),
        "b1": np.zeros(_HIDDEN, np.float32),
        "w2": (rng.standard_normal((_HIDDEN, _CLASSES)) * 0.1).astype(np.float32),
        "b2": np.zeros(_CLASSES, np.float32),
    }


@functools.cache
def _train_fns():
    import jax
    import jax.numpy as jnp

    def loss_fn(params, x, y):
        h = jnp.tanh(x @ params["w1"] + params["b1"])
        logits = h @ params["w2"] + params["b2"]
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(logp[jnp.arange(x.shape[0]), y])

    @jax.jit
    def sgd_epoch(params, x, y, lr):
        grads = jax.grad(loss_fn)(params, x, y)
        return jax.tree.map(lambda p, g: p - lr * g, params, grads)

    return jax.jit(loss_fn), sgd_epoch


@task(name="client_update", memory_gb=1.0, est_duration_s=0.5)
def client_update(params: dict, client: int, n: int, epochs: int,
                  lr: float = 0.5) -> dict:
    _, sgd_epoch = _train_fns()
    x, y = _client_data(client, n)
    for _ in range(epochs):
        params = sgd_epoch(params, x, y, lr)
    import jax
    return jax.tree.map(np.asarray, params)


@task(name="aggregate", memory_gb=0.5)
def aggregate(client_params: list[dict]) -> dict:
    out = {}
    for k in client_params[0]:
        out[k] = np.mean([cp[k] for cp in client_params], axis=0)
    return out


@task(name="evaluate", memory_gb=0.5)
def evaluate(params: dict, n: int = 256) -> float:
    loss_fn, _ = _train_fns()
    x, y = _client_data(999, n)
    return float(loss_fn(params, x, y))


@register_app("fedlearn")
def submit(injector=None, scale: str = "small", seed: int = 0) -> list:
    injector = injector or NoInjector()
    clients, rounds, epochs, n = SCALES[scale]
    idx = 0

    def nxt(td, *, is_parent=True):
        nonlocal idx
        idx += 1
        return injector.maybe(td, idx, is_parent=is_parent)

    params: object = init_params(seed)
    out: list = []
    for r in range(rounds):
        updates = [nxt(client_update)(params, c, n, epochs)
                   for c in range(clients)]
        params = nxt(aggregate, is_parent=False)(updates)
        out.append(nxt(evaluate, is_parent=False)(params))
    out.append(params)
    return out
