"""ML-in-the-loop molecule design (paper Table II / §III-A).

Surrogate-model search for molecules with the largest ionization energy:
rounds of (simulate → train surrogate → inference → select).  The
*simulate* task reproduces the paper's **Random Seed Error** (§III-A): for
an unlucky fraction of randomly initialized "molecules" the quantum-
chemistry proxy diverges and raises; after regeneration with a new seed the
task succeeds — the canonical retriable application-layer failure.

The numerical payload is real JAX: the "simulation" computes the largest
eigenvalue of a molecule-derived symmetric matrix; the surrogate is ridge
regression on random features, fitted with ``jnp.linalg``.
"""
from __future__ import annotations

import numpy as np

from repro.apps.base import register_app
from repro.core.failures import RandomSeedError
from repro.engine.task import task
from repro.injection.engines import NoInjector

SCALES = {
    # (initial_sims, batch_size, rounds, candidate_pool)
    "tiny": (2, 2, 2, 16),
    "small": (4, 4, 3, 32),
    "medium": (4, 4, 16, 64),   # paper: init 4, batch 4, search count 16
    "paper": (4, 4, 16, 64),
}

_FEAT = 16
_SEED_FAIL_FRACTION = 0.15  # fraction of seeds whose simulation diverges
# per-molecule attempt counter: every (re)execution regenerates the random
# initial assumption, so a retried simulation may succeed (§III-A)
_ATTEMPTS: dict[tuple[int, int], int] = {}


def _molecule_features(mol_id: int) -> np.ndarray:
    rng = np.random.default_rng(10_000 + mol_id)
    return rng.standard_normal(_FEAT)


@task(name="simulate", memory_gb=1.0)
def simulate(mol_id: int, attempt_seed: int = 0) -> tuple[int, float]:
    """Quantum-chemistry proxy: largest eigenvalue of a feature-derived
    symmetric matrix.  Sporadically diverges depending on the random
    initial assumption (Random Seed Error, §III-A)."""
    import jax.numpy as jnp

    key = (mol_id, attempt_seed)
    attempt = _ATTEMPTS[key] = _ATTEMPTS.get(key, -1) + 1
    rng = np.random.default_rng(((mol_id << 16) ^ attempt_seed) + 7919 * attempt)
    if rng.random() < _SEED_FAIL_FRACTION:
        raise RandomSeedError(
            f"simulation diverged for molecule {mol_id} "
            f"(bad random initial assumption, attempt {attempt})")
    f = _molecule_features(mol_id)
    m = jnp.outer(f, f) + jnp.eye(_FEAT) * 0.1
    energy = float(jnp.linalg.eigvalsh(m)[-1])
    return mol_id, energy


@task(name="train_surrogate", memory_gb=1.0)
def train_surrogate(results: list[tuple[int, float]]) -> np.ndarray:
    """Ridge regression: features -> energy."""
    import jax.numpy as jnp

    x = jnp.stack([jnp.asarray(_molecule_features(mid)) for mid, _ in results])
    y = jnp.asarray([e for _, e in results])
    lam = 1e-3
    w = jnp.linalg.solve(x.T @ x + lam * jnp.eye(_FEAT), x.T @ y)
    return np.asarray(w)


@task(name="inference", memory_gb=0.5)
def inference(w: np.ndarray, mol_ids: list[int]) -> list[tuple[int, float]]:
    import jax.numpy as jnp

    x = jnp.stack([jnp.asarray(_molecule_features(m)) for m in mol_ids])
    preds = x @ jnp.asarray(w)
    return [(m, float(p)) for m, p in zip(mol_ids, preds)]


@task(name="select", memory_gb=0.5)
def select(preds: list[tuple[int, float]], k: int,
           done: list[int]) -> list[int]:
    ranked = sorted(preds, key=lambda t: -t[1])
    picked = [m for m, _ in ranked if m not in done][:k]
    return picked


@register_app("moldesign")
def submit(injector=None, scale: str = "small", seed: int = 0) -> list:
    injector = injector or NoInjector()
    init, batch, rounds, pool = SCALES[scale]
    idx = 0

    def nxt(td, *, is_parent=True):
        nonlocal idx
        idx += 1
        return injector.maybe(td, idx, is_parent=is_parent)

    out: list = []
    done_ids = list(range(init))
    sims = [nxt(simulate)(m, seed) for m in done_ids]
    out.extend(sims)
    candidates = list(range(init, pool))
    results_futures = list(sims)
    for r in range(rounds):
        w = nxt(train_surrogate, is_parent=False)(results_futures)
        preds = nxt(inference, is_parent=False)(w, candidates)
        picked = nxt(select, is_parent=False)(preds, batch, done_ids)
        # the next round simulates the picked molecules; since picked is a
        # future we submit the batch via a bridge task producing concrete ids
        new_sims = [nxt(simulate)(mid, seed + r + 1)
                    for mid in candidates[r * batch:(r + 1) * batch]]
        out.append(picked)
        out.extend(new_sims)
        results_futures = results_futures + new_sims
    return out
