"""Blocked (tiled) Cholesky decomposition as a task DAG (paper Table II).

Right-looking algorithm over an nb×nb grid of tiles: potrf on the diagonal,
trsm down the panel, syrk/gemm trailing updates.  Paper scale: 10 000² with
1000² tiles; our default scales are laptop-sized but the DAG shape is
identical.  Output is verified against ``numpy.linalg.cholesky``.
"""
from __future__ import annotations

import numpy as np

from repro.apps.base import register_app
from repro.engine.task import task
from repro.injection.engines import NoInjector

SCALES = {
    "tiny": (4, 32),      # nb=4 tiles of 32  -> 20 tasks
    "small": (6, 64),     # nb=6              -> 56 tasks
    "medium": (10, 128),  # nb=10             -> 220 tasks
    "paper": (10, 1000),  # paper config      -> 220 tasks, 10k matrix
}


def make_spd(n: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    b = rng.standard_normal((n, n)).astype(np.float64)
    return b @ b.T + n * np.eye(n)


@task(name="potrf", memory_gb=0.5)
def potrf(a_kk: np.ndarray) -> np.ndarray:
    return np.linalg.cholesky(a_kk)


@task(name="trsm", memory_gb=0.5)
def trsm(l_kk: np.ndarray, a_ik: np.ndarray) -> np.ndarray:
    # solve X L_kk^T = A_ik  =>  solve L_kk X^T = A_ik^T
    x_t = np.linalg.solve(l_kk, a_ik.T)
    return x_t.T


@task(name="syrk", memory_gb=0.5)
def syrk(l_ik: np.ndarray, a_ii: np.ndarray) -> np.ndarray:
    return a_ii - l_ik @ l_ik.T


@task(name="gemm", memory_gb=0.5)
def gemm(l_ik: np.ndarray, l_jk: np.ndarray, a_ij: np.ndarray) -> np.ndarray:
    return a_ij - l_ik @ l_jk.T


@register_app("cholesky")
def submit(injector=None, scale: str = "small", seed: int = 0) -> list:
    injector = injector or NoInjector()
    nb, bs = SCALES[scale]
    n = nb * bs
    a = make_spd(n, seed)
    tiles: dict[tuple[int, int], object] = {}
    for i in range(nb):
        for j in range(i + 1):
            tiles[(i, j)] = a[i * bs:(i + 1) * bs, j * bs:(j + 1) * bs]

    idx = 0

    def nxt(td, *, is_parent=True):
        nonlocal idx
        idx += 1
        return injector.maybe(td, idx, is_parent=is_parent)

    out: list = []
    for k in range(nb):
        tiles[(k, k)] = nxt(potrf)(tiles[(k, k)])
        out.append(tiles[(k, k)])
        for i in range(k + 1, nb):
            tiles[(i, k)] = nxt(trsm)(tiles[(k, k)], tiles[(i, k)])
            out.append(tiles[(i, k)])
        for i in range(k + 1, nb):
            tiles[(i, i)] = nxt(syrk, is_parent=False)(tiles[(i, k)], tiles[(i, i)])
            for j in range(k + 1, i):
                tiles[(i, j)] = nxt(gemm, is_parent=False)(
                    tiles[(i, k)], tiles[(j, k)], tiles[(i, j)])
    return out


def verify(n: int = 384, nb: int = 6) -> float:
    """Standalone correctness check used by tests (no failure injection)."""
    a = make_spd(n)
    ref = np.linalg.cholesky(a)
    bs = n // nb
    tiles = {}
    for i in range(nb):
        for j in range(i + 1):
            tiles[(i, j)] = a[i * bs:(i + 1) * bs, j * bs:(j + 1) * bs].copy()
    for k in range(nb):
        tiles[(k, k)] = np.linalg.cholesky(tiles[(k, k)])
        for i in range(k + 1, nb):
            tiles[(i, k)] = np.linalg.solve(tiles[(k, k)], tiles[(i, k)].T).T
        for i in range(k + 1, nb):
            tiles[(i, i)] = tiles[(i, i)] - tiles[(i, k)] @ tiles[(i, k)].T
            for j in range(k + 1, i):
                tiles[(i, j)] = tiles[(i, j)] - tiles[(i, k)] @ tiles[(j, k)].T
    l = np.zeros_like(a)
    for (i, j), t in tiles.items():
        l[i * bs:(i + 1) * bs, j * bs:(j + 1) * bs] = t
    return float(np.max(np.abs(l - ref)))
