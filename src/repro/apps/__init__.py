"""TaPS-analog benchmark applications (paper §VII-A, Table II).

Five real DAG applications with genuine numerical payloads:

* ``cholesky``  — blocked Cholesky decomposition (potrf/trsm/syrk/gemm DAG)
* ``docking``   — molecular-docking proxy (batched pose scoring rounds)
* ``fedlearn``  — federated learning on a synthetic MNIST with a JAX MLP
* ``mapreduce`` — word count over generated files (map + reduce)
* ``moldesign`` — ML-in-the-loop surrogate search for high-energy molecules

Each app exposes ``submit(injector, scale) -> list[AppFuture]`` (to be
called inside an active DFK session) and is registered in :data:`APPS` for
the benchmark harness.
"""
from repro.apps.base import APPS, AppRunResult, run_app
from repro.apps import cholesky, docking, fedlearn, mapreduce, moldesign  # noqa: F401

__all__ = ["APPS", "AppRunResult", "run_app"]
