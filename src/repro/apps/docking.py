"""Molecular docking proxy (paper Table II): batched pose scoring rounds.

Predicting the orientation/position of two molecules: each *dock* task
scores a batch of random rigid-body poses of a ligand against a receptor
(real numpy geometry: rotation matrices, Lennard-Jones-style scoring) and
returns the best pose; rounds select the most promising poses to refine.
Paper config: 8 initial simulations, batch 8, 3 rounds (160 tasks).
"""
from __future__ import annotations

import numpy as np

from repro.apps.base import register_app
from repro.engine.task import task
from repro.injection.engines import NoInjector

SCALES = {
    # (initial, batch, rounds, atoms, poses_per_task)
    "tiny": (2, 2, 2, 16, 8),
    "small": (4, 4, 2, 24, 16),
    "medium": (8, 8, 3, 48, 64),   # paper shape
    "paper": (8, 8, 3, 64, 256),
}


def _molecule(seed: int, atoms: int) -> np.ndarray:
    return np.random.default_rng(seed).standard_normal((atoms, 3))


def _rotation(seed: int) -> np.ndarray:
    q = np.random.default_rng(seed).standard_normal(4)
    q /= np.linalg.norm(q)
    w, x, y, z = q
    return np.array([
        [1 - 2 * (y * y + z * z), 2 * (x * y - z * w), 2 * (x * z + y * w)],
        [2 * (x * y + z * w), 1 - 2 * (x * x + z * z), 2 * (y * z - x * w)],
        [2 * (x * z - y * w), 2 * (y * z + x * w), 1 - 2 * (x * x + y * y)],
    ])


@task(name="dock", memory_gb=1.0)
def dock(receptor_seed: int, ligand_seed: int, pose_seed: int,
         atoms: int, n_poses: int) -> tuple[float, int]:
    """Score n_poses random rigid placements; return (best_score, best_seed)."""
    receptor = _molecule(receptor_seed, atoms)
    ligand = _molecule(ligand_seed, atoms // 2)
    best, best_seed = np.inf, pose_seed
    for p in range(n_poses):
        seed = pose_seed * 10_007 + p
        rot = _rotation(seed)
        shift = np.random.default_rng(seed + 1).standard_normal(3) * 2.0
        placed = ligand @ rot.T + shift
        d2 = ((receptor[:, None, :] - placed[None, :, :]) ** 2).sum(-1)
        d2 = np.maximum(d2, 1e-3)
        # 6-12 potential: clash penalty + attraction
        e = (1.0 / d2**6 - 1.0 / d2**3).sum()
        if e < best:
            best, best_seed = float(e), seed
    return best, best_seed


@task(name="select_poses", memory_gb=0.5)
def select_poses(results: list[tuple[float, int]], k: int) -> list[int]:
    ranked = sorted(results)[:k]
    return [seed for _, seed in ranked]


@register_app("docking")
def submit(injector=None, scale: str = "small", seed: int = 0) -> list:
    injector = injector or NoInjector()
    initial, batch, rounds, atoms, n_poses = SCALES[scale]
    idx = 0

    def nxt(td, *, is_parent=True):
        nonlocal idx
        idx += 1
        return injector.maybe(td, idx, is_parent=is_parent)

    out: list = []
    results = [nxt(dock)(seed, seed + 1, 100 + i, atoms, n_poses)
               for i in range(initial)]
    out.extend(results)
    for r in range(rounds):
        picked = nxt(select_poses, is_parent=False)(results, batch)
        out.append(picked)
        results = [nxt(dock)(seed, seed + 1, 1000 * (r + 1) + i, atoms, n_poses)
                   for i in range(batch)]
        out.extend(results)
    return out
