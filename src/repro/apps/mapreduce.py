"""MapReduce word count (paper Table II): N map tasks + 1 reduce task.

Each map task deterministically generates a "file" of words and counts
them; the reduce task merges the counts.  Paper config: 100 map tasks over
100 generated files.
"""
from __future__ import annotations

from collections import Counter

import numpy as np

from repro.apps.base import register_app
from repro.engine.task import task
from repro.injection.engines import NoInjector

_WORDS = ("wrath task pool node retry failure heartbeat monitor worker "
          "manager pilot resilience layer hierarchy denylist policy").split()

SCALES = {
    "tiny": (8, 200),
    "small": (20, 500),
    "medium": (100, 2000),
    "paper": (100, 20000),
}


@task(name="map_count", memory_gb=0.5)
def map_count(seed: int, n_words: int) -> dict[str, int]:
    rng = np.random.default_rng(seed)
    words = rng.choice(_WORDS, size=n_words)
    return dict(Counter(words.tolist()))


@task(name="reduce_merge", memory_gb=0.5)
def reduce_merge(counts: list[dict[str, int]]) -> dict[str, int]:
    total: Counter = Counter()
    for c in counts:
        total.update(c)
    return dict(total)


@register_app("mapreduce")
def submit(injector=None, scale: str = "small", seed: int = 0) -> list:
    injector = injector or NoInjector()
    n_map, n_words = SCALES[scale]
    maps = []
    for i in range(n_map):
        td = injector.maybe(map_count, i, is_parent=True)
        maps.append(td(seed + i, n_words))
    red = injector.maybe(reduce_merge, n_map, is_parent=False)
    return [red(maps)]
