"""Shared application harness: run an app on a cluster and collect the
paper's metrics (§VII-A): makespan, time-to-failure, overhead ratio, task /
retry / application success rates.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

from repro.engine.cluster import Cluster
from repro.engine.dfk import DataFlowKernel
from repro.engine.policies import normalize_policies, shim_legacy_kwargs
from repro.injection.engines import NoInjector

# registry: name -> submit(injector, scale, **kw) -> list[AppFuture]
APPS: dict[str, Callable[..., list]] = {}


def register_app(name: str):
    def deco(fn):
        APPS[name] = fn
        return fn
    return deco


@dataclass
class AppRunResult:
    app: str
    success: bool
    makespan: float
    time_to_failure: float | None
    error: str | None
    stats: dict[str, float]
    task_success_rate: float
    retry_success_rate: float
    overhead_ratio: float
    injected: int = 0
    extra: dict[str, Any] = field(default_factory=dict)

    def row(self) -> dict[str, Any]:
        return {
            "app": self.app, "success": int(self.success),
            "makespan_s": round(self.makespan, 4),
            "ttf_s": round(self.time_to_failure, 4) if self.time_to_failure else "",
            "task_sr": round(self.task_success_rate, 4),
            "retry_sr": round(self.retry_success_rate, 4),
            "overhead_ratio": round(self.overhead_ratio, 6),
            "injected": self.injected,
            "error": self.error or "",
        }


def run_app(
    app: str,
    cluster: Cluster,
    *,
    policy: Any = None,
    retry_handler=None,
    monitor=None,
    injector=None,
    proactive: bool = False,
    scale: str = "small",
    default_pool: str | None = None,
    default_retries: int = 2,
    wait_timeout: float = 300.0,
    **app_kwargs: Any,
) -> AppRunResult:
    """Execute one application run and collect the §VII-A metrics.

    Resilience is configured with ``policy=`` — a
    :class:`~repro.engine.policies.ResiliencePolicy`, a list of them, or
    a bare retry-handler callable.  The historical ``retry_handler=`` /
    ``proactive=`` arguments still work: they are adapted into
    equivalent stack members (appended after ``policy``'s), so both
    spellings drive identical decisions.  Each run executes inside a
    :class:`~repro.engine.workflow.Workflow` scope named after the app;
    its subtree stats land in ``extra["workflow"]``.  The per-task
    time-to-failure of terminally failed tasks is reported in
    ``extra["ttf_per_task_mean"]`` for every mode, so reactive and
    proactive runs are directly comparable (fig 4's normalized TTF).
    """
    injector = injector or NoInjector()
    submit = APPS[app]
    # run_app's own retry_handler=/proactive= kwargs are part of the same
    # deprecated surface: external callers get the migration warning too
    parts = normalize_policies(policy) + shim_legacy_kwargs(
        retry_handler=retry_handler, proactive=proactive)
    t0 = time.time()
    error: str | None = None
    ttf: float | None = None
    success = True
    with DataFlowKernel(
        cluster, policy=parts, monitor=monitor,
        default_pool=default_pool, default_retries=default_retries,
    ) as dfk:
        with dfk.workflow(app) as wf:
            futures = submit(injector=injector, scale=scale, **app_kwargs)
        for f in futures:
            try:
                f.result(timeout=wait_timeout)
            except Exception as e:  # noqa: BLE001 - application failed
                if success:
                    ttf = time.time() - t0
                success = False
                error = type(e).__name__
        # drain remaining work so stats are complete
        dfk.wait_all(timeout=wait_timeout)
        makespan = time.time() - t0
        rates = dfk.success_rates()
        overhead = dfk.stats["wrath_overhead_s"] / makespan if makespan > 0 else 0.0
        stats = dict(dfk.stats)
        task_ttfs = dfk.failed_task_ttfs()
    extra: dict[str, Any] = {"workflow": wf.stats()}
    if task_ttfs:
        extra["ttf_per_task_mean"] = sum(task_ttfs) / len(task_ttfs)
        extra["failed_tasks"] = len(task_ttfs)
    return AppRunResult(
        app=app, success=success, makespan=makespan, time_to_failure=ttf,
        error=error, stats=stats,
        task_success_rate=rates["task_success_rate"],
        retry_success_rate=rates["retry_success_rate"],
        overhead_ratio=overhead,
        injected=getattr(injector, "count", 0),
        extra=extra,
    )
