"""pjit step builders: train_step / prefill_step / serve_step.

``build_train_step`` composes: microbatch gradient accumulation
(``lax.scan``, cutting activation memory by the microbatch factor) →
global-norm clip → AdamW.  Params and optimizer state are donated.

All functions are *pure builders*: they return functions suitable for
``jax.jit(..., in_shardings=..., donate_argnums=...)``; shardings are
derived from the ParamDef trees by the rule engine and attached by the
caller (see ``repro.launch.dryrun`` / ``repro.launch.train``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import decode_step as model_decode_step
from repro.models import loss_fn as model_loss_fn
from repro.models.config import ModelConfig
from repro.models.model import prefill_forward
from repro.optim import OptConfig, adamw_apply


@dataclasses.dataclass(frozen=True)
class StepConfig:
    microbatches: int = 1
    remat: bool = True
    accum_dtype: str = "float32"     # "bfloat16" halves grad-accum memory
    ce_chunk: int = 512

    @property
    def adtype(self):
        return jnp.dtype(self.accum_dtype)


def build_train_step(cfg: ModelConfig, opt_cfg: OptConfig,
                     step_cfg: StepConfig = StepConfig()) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def loss_f(p: Any, b: dict):
        return model_loss_fn(p, b, cfg, remat=step_cfg.remat,
                             ce_chunk=step_cfg.ce_chunk)

    grad_f = jax.value_and_grad(loss_f, has_aux=True)

    def train_step(params: Any, opt_state: dict, batch: dict):
        k = step_cfg.microbatches
        if k > 1:
            def resh(x):
                return x.reshape((k, x.shape[0] // k) + x.shape[1:])

            mb = jax.tree.map(resh, batch)

            def body(carry, b):
                gsum, lsum = carry
                (l, _), g = grad_f(params, b)
                gsum = jax.tree.map(
                    lambda a, gg: a + gg.astype(step_cfg.adtype), gsum, g)
                return (gsum, lsum + l), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, step_cfg.adtype), params)
            (gsum, lsum), _ = jax.lax.scan(
                body, (g0, jnp.zeros((), jnp.float32)), mb)
            grads = jax.tree.map(lambda g: (g / k).astype(jnp.float32), gsum)
            loss = lsum / k
            metrics: dict[str, Any] = {}
        else:
            (loss, metrics), grads = grad_f(params, batch)
        new_params, new_state, om = adamw_apply(params, grads, opt_state, opt_cfg)
        out_metrics = {"loss": loss, **{k2: v for k2, v in (metrics or {}).items()},
                       **om}
        return new_params, new_state, out_metrics

    return train_step


def build_prefill_step(cfg: ModelConfig, step_cfg: StepConfig = StepConfig()
                       ) -> Callable:
    """(params, batch) -> (last-token logits, decode cache)."""

    def prefill_step(params: Any, batch: dict):
        return prefill_forward(params, batch, cfg, remat=step_cfg.remat)

    return prefill_step


def build_serve_step(cfg: ModelConfig) -> Callable:
    """(params, cache, batch) -> (logits, cache) — one decoded token."""

    def serve_step(params: Any, cache: dict, batch: dict):
        return model_decode_step(params, cache, batch, cfg)

    return serve_step
