from repro.distributed.sharding import (
    ACT_RULES,
    CACHE_RULES,
    PARAM_RULES,
    ShardingRules,
    activation_sharding,
    defs_pspecs,
    defs_shardings,
    spec_for,
)
from repro.distributed.step import (
    StepConfig,
    build_prefill_step,
    build_serve_step,
    build_train_step,
)

__all__ = [
    "ShardingRules", "PARAM_RULES", "ACT_RULES", "CACHE_RULES",
    "spec_for", "defs_pspecs", "defs_shardings", "activation_sharding",
    "StepConfig", "build_train_step", "build_serve_step", "build_prefill_step",
]
