"""Sharding-rule engine: logical axes → mesh axes with divisibility fallback.

Models annotate parameters (via ParamDef.axes) and activations (via
``constrain``) with *logical* axis names.  A :class:`ShardingRules` table
maps each logical name to an ordered preference of mesh-axis tuples; the
engine picks, per tensor, the first candidate whose mesh-axis product
divides the dimension and whose axes are not already claimed by another
dimension of the same tensor.  This is what makes one rule table work
across all 10 architectures and every degraded (elastic) mesh.

Default layout (v5e-style 2-D/3-D mesh, axes ``pod``/``data``/``model``):

* parameters — FSDP over (pod, data) on the ``d_model`` dim and tensor
  parallelism over ``model`` on heads / d_ff / experts / vocab;
* activations — batch over (pod, data), heads/d_ff/experts/vocab over
  ``model``;
* decode caches — batch over (pod, data) with ``seq`` over ``model`` so
  single-sequence long-context decode still spreads across chips.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.spec import ParamDef, is_def

Candidate = tuple[str, ...]          # one mesh-axis combination
Preference = tuple[Candidate, ...]   # ordered fallbacks


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """logical axis -> ordered candidates (first feasible wins)."""

    rules: dict[str, Preference]

    def lookup(self, name: str | None) -> Preference:
        if name is None:
            return ()
        return self.rules.get(name, ())

    def replace(self, **upd: Preference) -> "ShardingRules":
        d = dict(self.rules)
        d.update(upd)
        return ShardingRules(d)


def _mk(*cands: tuple[str, ...]) -> Preference:
    return tuple(cands)


# fsdp = (pod, data) when multi-pod; the engine prunes absent axes.
PARAM_RULES = ShardingRules({
    "d_model": _mk(("pod", "data"), ("data",)),
    "d_ff": _mk(("model",)),
    "heads": _mk(("model",)),
    "kv_heads": _mk(("model",)),
    "experts": _mk(("model",)),
    "vocab": _mk(("model",)),
    "layers": (),                    # never shard the scan axis
})

ACT_RULES = ShardingRules({
    "batch": _mk(("pod", "data"), ("data",)),
    "seq": (),
    # residual stream between blocks ("seq_res"): Megatron-SP-style
    # sequence sharding over the TP axis was MEASURED AND REFUTED for this
    # stack (EXPERIMENTS.md §Perf, deepseek-v3 iteration 3): the shard_map
    # MoE needs model-replicated tokens at entry, so SP inserted gather/
    # reshard pairs that grew collective time 93s→149s.  Left unsharded.
    "seq_res": (),
    "d_model": (),
    "d_ff": _mk(("model",)),
    "heads": _mk(("model",)),
    "kv_heads": _mk(("model",)),
    "experts": _mk(("model",)),
    "vocab": _mk(("model",)),
})

CACHE_RULES = ShardingRules({
    "batch": _mk(("pod", "data"), ("data",)),
    "seq": _mk(("model",)),          # long-context: spread the KV/latent cache
    "kv_heads": (),                  # seq sharding beats head sharding for caches
    "heads": _mk(("model",)),        # ssd/rglru state heads
    "d_ff": _mk(("model",)),
    "d_model": (),
})


def spec_for(shape: tuple[int, ...], axes: tuple[str | None, ...],
             rules: ShardingRules, mesh: Mesh) -> P:
    """Pick a PartitionSpec: first feasible candidate per dim, no axis reuse."""
    mesh_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    used: set[str] = set()
    parts: list[Any] = []
    for dim, name in zip(shape, axes):
        chosen: Candidate | None = None
        for cand in rules.lookup(name):
            cand = tuple(a for a in cand if a in mesh_sizes)
            if not cand or any(a in used for a in cand):
                continue
            prod = int(np.prod([mesh_sizes[a] for a in cand]))
            if prod > 1 and dim % prod == 0:
                chosen = cand
                break
        if chosen:
            used.update(chosen)
            parts.append(chosen if len(chosen) > 1 else chosen[0])
        else:
            parts.append(None)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def defs_pspecs(defs: Any, rules: ShardingRules, mesh: Mesh) -> Any:
    """ParamDef tree -> PartitionSpec tree."""
    return jax.tree.map(
        lambda d: spec_for(d.shape, d.axes, rules, mesh), defs, is_leaf=is_def)


def defs_shardings(defs: Any, rules: ShardingRules, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda d: NamedSharding(mesh, spec_for(d.shape, d.axes, rules, mesh)),
        defs, is_leaf=is_def)


def make_constrain_fn(mesh: Mesh, rules: ShardingRules):
    """The activation-sharding hook installed via models.layers.set_shard_fn."""

    def constrain(x: jax.Array, axes: tuple[str | None, ...]) -> jax.Array:
        if len(axes) != x.ndim:
            return x
        spec = spec_for(x.shape, axes, rules, mesh)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return constrain


class activation_sharding:
    """Context manager installing the activation-constraint hook."""

    def __init__(self, mesh: Mesh, rules: ShardingRules = ACT_RULES):
        self.mesh = mesh
        self.rules = rules

    def __enter__(self):
        from repro.models.layers import set_shard_fn

        self._token = set_shard_fn(make_constrain_fn(self.mesh, self.rules),
                                   mesh=self.mesh)
        return self

    def __exit__(self, *exc):
        from repro.models.layers import reset_shard_fn

        reset_shard_fn(self._token)
