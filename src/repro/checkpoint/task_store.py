"""Lineage-aware task-output store: the engine-layer checkpoint/restart plane.

The training plane already has :class:`~repro.checkpoint.store.
CheckpointManager` for model state; this module is the *task* analog —
the framework-layer recovery the paper says hierarchical retry must
compose with (Dichev et al.'s dependency-aware checkpoint-restart, MODC's
idempotent-task + persisted-results recipe).  Every committed task result
is keyed by a deterministic **invocation hash** over

* the task template name,
* the fully-resolved positional/keyword arguments — parent
  :class:`~repro.engine.task.AppFuture`\\ s have already been replaced by
  their results when the key is computed (at dispatch, after dependency
  resolution), so the key transitively covers every ancestor's output,

which makes the task DAG the engine already maintains
(``TaskRecord.depends_on``) the *lineage*: a restarted engine replaying
the same workflow script recomputes the same keys for every task whose
ancestry is unchanged, hits the store, and resolves those futures without
dispatching — only the incomplete frontier (tasks that never committed,
or whose ancestors now produce different results and therefore different
keys) re-executes.

Two pieces:

* :class:`TaskStore` — the persistence layer.  ``directory=None`` keeps
  everything in memory (it still survives an engine teardown, since the
  store object outlives :class:`~repro.engine.dfk.DataFlowKernel`
  incarnations — exactly what the simulation plane's ``engine_crash``
  scenarios exercise); with a directory every commit is two atomic
  renames (value pickle first, JSON meta last — the meta file is the
  commit marker, so a crash mid-commit leaves an orphan value file that
  the next open sweeps).  Each entry records its parents' lineage keys,
  giving the store the reverse DAG needed for **dependency-aware
  rollback**: invalidating a key can drop every transitive descendant.
* :class:`CheckpointPolicy` — the store as a
  :class:`~repro.engine.policies.ResiliencePolicy` stack member:
  ``memo_lookup`` is the dispatch-time short-circuit, ``on_result``
  commits successful results, ``memo_invalidate`` is the rollback hook
  the engine fires when a cached result fails result validation.

Wire-up is one kwarg at either level::

    store = TaskStore("results/")            # or TaskStore() in-memory
    with DataFlowKernel(cluster, checkpoint=store) as dfk: ...
    with dfk.workflow("stage2", checkpoint=store) as wf: ...
"""
from __future__ import annotations

import hashlib
import json
import os
import pickle
import re
import threading
import weakref
from pathlib import Path
from typing import Any, Iterable

from repro.engine.policies import ResiliencePolicy
from repro.engine.retry_api import SchedulingContext

__all__ = ["TaskStore", "CheckpointPolicy", "as_checkpoint_policy",
           "lineage_key", "hash_value"]

_META_SUFFIX = ".json"
_VALUE_SUFFIX = ".pkl"
_TMP_PREFIX = ".tmp-"
#: every store key is a sha256 hex digest; scans and sweeps only ever
#: touch files with such names, so a store pointed at a directory that
#: also holds unrelated user files never deletes them
_KEY_RE = re.compile(r"[0-9a-f]{64}")


# --------------------------------------------------------------------------
# deterministic hashing
# --------------------------------------------------------------------------
def _chunk(tag: bytes, payload: bytes) -> bytes:
    """Self-delimiting encoding: tag + byte length + payload.

    The length prefix makes concatenated chunks unambiguous — without it
    adjacent variable-length elements could collide (``("aS", "b")`` vs
    ``("a", "Sb")``) and two different invocations would share one
    lineage key, silently memo-hitting the wrong result.
    """
    return tag + str(len(payload)).encode() + b":" + payload


def _feed(h: "hashlib._Hash", obj: Any) -> None:
    """Feed a canonical byte encoding of ``obj`` into ``h``.

    Type tags keep ``1`` / ``1.0`` / ``True`` / ``"1"`` distinct; dict
    items are sorted by their own hashes so insertion order never leaks
    into the key.  Unknown objects go through ``pickle`` (deterministic
    for the value types tasks realistically exchange); anything
    unpicklable degrades to ``repr`` — a weaker key that may miss across
    processes, never a wrong hit.
    """
    if obj is None:
        h.update(b"N:")
    elif isinstance(obj, bool):
        h.update(b"B1:" if obj else b"B0:")
    elif isinstance(obj, int):
        h.update(_chunk(b"I", str(obj).encode()))
    elif isinstance(obj, float):
        h.update(_chunk(b"F", obj.hex().encode()))
    elif isinstance(obj, str):
        h.update(_chunk(b"S", obj.encode()))
    elif isinstance(obj, bytes):
        h.update(_chunk(b"Y", obj))
    elif isinstance(obj, (list, tuple)):
        h.update((b"L" if isinstance(obj, list) else b"T")
                 + str(len(obj)).encode() + b":")
        for x in obj:
            _feed(h, x)
    elif isinstance(obj, (set, frozenset)):
        h.update(b"E" + str(len(obj)).encode() + b":")
        for d in sorted(hash_value(x) for x in obj):
            h.update(d.encode())          # fixed-width hex digests
    elif isinstance(obj, dict):
        h.update(b"D" + str(len(obj)).encode() + b":")
        for kd, vd in sorted((hash_value(k), hash_value(v))
                             for k, v in obj.items()):
            h.update(kd.encode() + vd.encode())
    elif hasattr(obj, "dtype") and hasattr(obj, "tobytes"):
        # ndarray-likes (numpy / jax device arrays): dtype + shape + bytes
        h.update(_chunk(b"A", str(obj.dtype).encode()
                        + str(getattr(obj, "shape", ())).encode()))
        h.update(_chunk(b"a", obj.tobytes() if callable(obj.tobytes)
                        else bytes(obj)))
    else:
        try:
            h.update(_chunk(b"P", pickle.dumps(obj, protocol=4)))
        except Exception:  # noqa: BLE001 - unhashable arg => weak (repr) key
            h.update(_chunk(b"R", repr(obj).encode()))


def hash_value(obj: Any) -> str:
    """Deterministic content hash of an arbitrary task argument/result."""
    h = hashlib.sha256()
    _feed(h, obj)
    return h.hexdigest()


#: fn -> code fingerprint; weak so task functions can be collected
_fn_prints: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


def _code_bytes(code: Any) -> bytes:
    """Deterministic bytes for a code object: bytecode + consts + names.

    Nested code objects (lambdas, comprehensions) recurse instead of
    taking ``repr`` — a code object's repr embeds a memory address and
    would differ every process.  Frozenset consts are sorted by repr for
    the same reason (str-hash randomization shuffles their iteration).
    """
    parts = [code.co_code]
    for c in code.co_consts:
        if hasattr(c, "co_code"):
            parts.append(_code_bytes(c))
        elif isinstance(c, frozenset):
            parts.append(repr(sorted(c, key=repr)).encode())
        else:
            parts.append(repr(c).encode())
    parts.append(" ".join(code.co_names).encode())
    return b"|".join(parts)


def _fn_fingerprint(fn: Any) -> bytes:
    """Content fingerprint of a task's implementation.

    Keys must change when the task's *code* changes, or a persistent
    store would silently serve results computed by an older
    implementation (and two distinct templates sharing a ``__name__``
    would alias).  Bytecode + consts + referenced names is the proxy;
    changes visible only through globals/closure *values* are not
    captured — same-code-same-behaviour remains the caller's contract,
    as in any memoizing runtime.
    """
    try:
        return _fn_prints[fn]
    except (KeyError, TypeError):
        pass
    code = getattr(fn, "__code__", None)
    if code is None:                      # builtins / callables: name-level
        fp = getattr(fn, "__qualname__", type(fn).__qualname__).encode()
    else:
        fp = hashlib.sha256(_code_bytes(code)).digest()
    try:
        _fn_prints[fn] = fp
    except TypeError:                     # unweakrefable callable
        pass
    return fp


def lineage_key(rec: Any) -> str:
    """Invocation hash of a task record whose args are fully resolved.

    Must be called *after* dependency resolution (parent futures replaced
    by their results): the key then covers template name + implementation
    fingerprint + resolved args + every parent's output, i.e. the task's
    full lineage.
    """
    h = hashlib.sha256()
    h.update(_chunk(b"task", rec.name.encode()))
    fn = getattr(rec, "fn", None)
    if fn is not None:
        h.update(_chunk(b"code", _fn_fingerprint(fn)))
    _feed(h, tuple(rec.args))
    _feed(h, dict(rec.kwargs))
    return h.hexdigest()


# --------------------------------------------------------------------------
# the store
# --------------------------------------------------------------------------
class TaskStore:
    """Task results keyed by lineage hash, with parent links for rollback.

    Thread-safe; an instance may be shared by several engine incarnations
    (that is the whole point — it is the state that survives a crash).
    """

    def __init__(self, directory: str | Path | None = None):
        self.directory = Path(directory) if directory is not None else None
        self._lock = threading.RLock()
        #: key -> {"task_name": str, "parents": list[str], "value_hash": str}
        self._entries: dict[str, dict[str, Any]] = {}
        self._values: dict[str, Any] = {}      # in-memory value cache
        self._loaded: set[str] = set()         # keys whose value is cached
        if self.directory is not None:
            self.directory.mkdir(parents=True, exist_ok=True)
            self._open()

    # -- disk layout -------------------------------------------------------
    def _meta_path(self, key: str) -> Path:
        assert self.directory is not None
        return self.directory / f"{key}{_META_SUFFIX}"

    def _value_path(self, key: str) -> Path:
        assert self.directory is not None
        return self.directory / f"{key}{_VALUE_SUFFIX}"

    def _open(self) -> None:
        """Load committed entries; sweep tmp files and orphan values.

        The JSON meta file is the commit marker (written last): a value
        pickle without its meta is an interrupted commit and is removed,
        as is any leftover ``.tmp-*`` from a crash mid-rename.  Only
        sha256-keyed names are scanned or swept — files the store did not
        write (a user's own ``analysis.json``/``model.pkl`` sharing the
        directory) are never touched.
        """
        assert self.directory is not None
        for p in self.directory.glob(f"{_TMP_PREFIX}*"):
            stem = p.name[len(_TMP_PREFIX):]
            for suffix in (_META_SUFFIX, _VALUE_SUFFIX):
                if (stem.endswith(suffix)
                        and _KEY_RE.fullmatch(stem[: -len(suffix)])):
                    p.unlink(missing_ok=True)
        committed: set[str] = set()
        for p in self.directory.glob(f"*{_META_SUFFIX}"):
            key = p.name[: -len(_META_SUFFIX)]
            if not _KEY_RE.fullmatch(key):
                continue
            try:
                meta = json.loads(p.read_text())
            except (OSError, ValueError):
                p.unlink(missing_ok=True)
                continue
            if not self._value_path(key).exists():
                p.unlink(missing_ok=True)
                continue
            self._entries[key] = meta
            committed.add(key)
        for p in self.directory.glob(f"*{_VALUE_SUFFIX}"):
            key = p.name[: -len(_VALUE_SUFFIX)]
            if _KEY_RE.fullmatch(key) and key not in committed:
                p.unlink(missing_ok=True)

    def _atomic_write(self, path: Path, data: bytes) -> None:
        assert self.directory is not None
        tmp = self.directory / f"{_TMP_PREFIX}{path.name}"
        tmp.write_bytes(data)
        os.replace(tmp, path)

    # -- core API ----------------------------------------------------------
    def lookup(self, key: str) -> tuple[bool, Any]:
        """Return ``(hit, value)``; a corrupt on-disk value counts as a
        miss and is invalidated (descendants included) so stale children
        cannot outlive an unreadable parent."""
        with self._lock:
            if key not in self._entries:
                return False, None
            if key in self._loaded:
                return True, self._values.get(key)
            try:
                value = pickle.loads(self._value_path(key).read_bytes())
            except Exception:  # noqa: BLE001 - corrupt entry => miss + rollback
                self.invalidate(key, descendants=True)
                return False, None
            self._values[key] = value
            self._loaded.add(key)
            return True, value

    def commit(self, key: str, value: Any, *, task_name: str = "",
               parents: Iterable[str] = ()) -> str:
        """Persist a result; returns its content hash.

        Re-committing an identical value only *unions in* any new parent
        keys — converging lineages (two different parents producing the
        same value, hence one child key) must all be linked or
        dependency-aware rollback would miss descendants.  A *different*
        value overwrites; its descendants' keys change anyway, so no
        rollback is needed here.
        """
        if not _KEY_RE.fullmatch(key):
            raise ValueError(
                f"task-store keys are sha256 hex digests (use lineage_key()"
                f" / hash_value()); got {key!r}")
        vhash = hash_value(value)
        with self._lock:
            prev = self._entries.get(key)
            if prev is not None and prev.get("value_hash") == vhash:
                merged = sorted(set(prev.get("parents", ())) | set(parents))
                if merged != prev.get("parents"):
                    meta = dict(prev, parents=merged)
                    if self.directory is not None:
                        self._atomic_write(self._meta_path(key),
                                           json.dumps(meta).encode())
                    self._entries[key] = meta
                return vhash
            meta = {"task_name": task_name, "parents": sorted(set(parents)),
                    "value_hash": vhash}
            if self.directory is not None:
                self._atomic_write(self._value_path(key),
                                   pickle.dumps(value, protocol=4))
                self._atomic_write(self._meta_path(key),
                                   json.dumps(meta).encode())
            self._entries[key] = meta
            self._values[key] = value
            self._loaded.add(key)
            return vhash

    def invalidate(self, key: str, *, descendants: bool = False) -> list[str]:
        """Drop an entry (and, with ``descendants=True``, every entry
        whose parent chain reaches it).  Returns the removed keys."""
        with self._lock:
            doomed = [key]
            if descendants:
                children: dict[str, list[str]] = {}
                for k, meta in self._entries.items():
                    for parent in meta.get("parents", ()):
                        children.setdefault(parent, []).append(k)
                frontier, seen = [key], {key}
                while frontier:
                    nxt = frontier.pop()
                    for child in children.get(nxt, ()):
                        if child not in seen:
                            seen.add(child)
                            doomed.append(child)
                            frontier.append(child)
            removed = []
            for k in doomed:
                if k in self._entries:
                    removed.append(k)
                    self._entries.pop(k, None)
                    self._values.pop(k, None)
                    self._loaded.discard(k)
                    if self.directory is not None:
                        self._meta_path(k).unlink(missing_ok=True)
                        self._value_path(k).unlink(missing_ok=True)
            return removed

    # -- introspection -----------------------------------------------------
    def keys(self) -> list[str]:
        with self._lock:
            return list(self._entries)

    def entry(self, key: str) -> dict[str, Any] | None:
        with self._lock:
            meta = self._entries.get(key)
            return dict(meta) if meta is not None else None

    def __contains__(self, key: str) -> bool:
        with self._lock:
            return key in self._entries

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        where = str(self.directory) if self.directory else "memory"
        return f"<TaskStore {where} entries={len(self)}>"


# --------------------------------------------------------------------------
# the policy
# --------------------------------------------------------------------------
class CheckpointPolicy(ResiliencePolicy):
    """The task-output store as resilience middleware.

    * ``memo_lookup`` (dispatch time, args resolved): compute the
      record's lineage key and probe the store — a hit short-circuits
      dispatch, the engine resolves the future with the cached result;
    * ``memo_commit``: persist a successful result under the record's
      lineage key, linking it to its parents' keys.  The engine fires
      this only for the attempt that actually *won* the task (after the
      duplicate-completion guard), so a discarded racing copy of a
      nondeterministic task can never overwrite the value the future
      resolved with;
    * ``memo_invalidate``: dependency-aware rollback — drop the record's
      entry *and every descendant* when its cached result fails the
      stack's result validation.

    Failures are deliberately never committed: a destined-to-fail task
    re-executes after a restart, exactly like a fresh run.
    """

    def __init__(self, store: TaskStore | str | Path | None = None):
        if store is None:
            store = TaskStore()
        elif not isinstance(store, TaskStore):
            store = TaskStore(store)
        self.store: TaskStore = store

    def _key(self, rec: Any) -> str:
        key = getattr(rec, "lineage_key", None)
        if key is None:
            key = lineage_key(rec)
            rec.lineage_key = key
        return key

    def memo_lookup(self, rec: Any, ctx: SchedulingContext) -> tuple[bool, Any]:
        return self.store.lookup(self._key(rec))

    def memo_invalidate(self, rec: Any, reason: str = "") -> list[str]:
        key = getattr(rec, "lineage_key", None)
        if key is None:
            return []
        return self.store.invalidate(key, descendants=True)

    def memo_commit(self, rec: Any, result: Any,
                    ctx: SchedulingContext) -> None:
        parents = [p.lineage_key for p in getattr(rec, "depends_on", ())
                   if getattr(p, "lineage_key", None)]
        self.store.commit(self._key(rec), result, task_name=rec.name,
                          parents=parents)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"<CheckpointPolicy {self.store!r}>"


def as_checkpoint_policy(checkpoint: Any) -> CheckpointPolicy:
    """Coerce the public ``checkpoint=`` argument into a policy.

    Accepts a :class:`CheckpointPolicy`, a :class:`TaskStore`, a
    directory path (``str``/``Path``), or ``True`` (fresh in-memory
    store).
    """
    if isinstance(checkpoint, CheckpointPolicy):
        return checkpoint
    if isinstance(checkpoint, TaskStore):
        return CheckpointPolicy(checkpoint)
    if checkpoint is True:
        return CheckpointPolicy(TaskStore())
    if isinstance(checkpoint, (str, Path)):
        return CheckpointPolicy(TaskStore(checkpoint))
    raise TypeError(
        f"checkpoint= expects a CheckpointPolicy, TaskStore, path or True; "
        f"got {checkpoint!r}")
