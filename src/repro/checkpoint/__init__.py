"""Checkpointing: training-plane state (``store``) + engine-plane task
outputs (``task_store``).

The training-plane symbols import jax, which the engine layer must not
pay for just to memoize task results — they resolve lazily via module
``__getattr__``; the jax-free task store loads eagerly.
"""
from repro.checkpoint.task_store import (
    CheckpointPolicy,
    TaskStore,
    as_checkpoint_policy,
    hash_value,
    lineage_key,
)

__all__ = [
    "CheckpointManager", "save_checkpoint", "load_checkpoint",
    "TaskStore", "CheckpointPolicy", "as_checkpoint_policy",
    "lineage_key", "hash_value",
]

_LAZY = ("CheckpointManager", "save_checkpoint", "load_checkpoint")


def __getattr__(name: str):
    if name in _LAZY:
        from repro.checkpoint import store
        return getattr(store, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
