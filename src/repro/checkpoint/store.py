"""Sharded checkpointing: atomic commit, retention, async save, elastic
restore-with-resharding.

Layout (one directory per step)::

    <dir>/step_0000100/
        manifest.json         # tree structure, shapes, dtypes, metadata
        shard_00000.npz       # flattened leaves, chunked by byte budget
        ...
        COMMITTED             # written last — crash-safe commit marker

Restore rebuilds the pytree and (optionally) ``device_put``s each leaf to a
new sharding — the elastic re-mesh path: a checkpoint written on a 16×16
mesh restores cleanly onto a degraded 8×16 mesh because shardings are
reapplied at load time, not baked into the files.

The paper's framework-layer recovery (restart component → retry) maps to
``CheckpointManager.restore_latest()`` after a training-plane failure.
"""
from __future__ import annotations

import json
import shutil
import threading
import time
from pathlib import Path
from typing import Any

import jax
import numpy as np

_COMMIT = "COMMITTED"


def _flatten(tree: Any) -> tuple[list[tuple[str, Any]], Any]:
    from repro.compat import tree_flatten_with_path

    leaves, treedef = tree_flatten_with_path(tree)
    out = []
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, leaf))
    return out, treedef


def save_checkpoint(directory: str | Path, step: int, tree: Any, *,
                    metadata: dict | None = None,
                    shard_mb: int = 256) -> Path:
    """Atomic checkpoint save; returns the committed directory."""
    directory = Path(directory)
    final = directory / f"step_{step:08d}"
    tmp = directory / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves, _ = _flatten(tree)
    manifest: dict[str, Any] = {
        "step": step,
        "time": time.time(),
        "metadata": metadata or {},
        "leaves": [],
    }
    budget = shard_mb * 2**20
    shard_idx, shard_bytes, shard_data = 0, 0, {}

    def flush():
        nonlocal shard_idx, shard_bytes, shard_data
        if shard_data:
            np.savez(tmp / f"shard_{shard_idx:05d}.npz", **shard_data)
            shard_idx += 1
            shard_bytes, shard_data = 0, {}

    for key, leaf in leaves:
        arr = np.asarray(leaf)
        dtype_str = str(arr.dtype)
        if arr.dtype.kind == "V" or dtype_str not in np.sctypeDict:
            # ml_dtypes (bfloat16, fp8, ...): store a raw uint view and
            # record the logical dtype for the loader to view back
            dtype_str = str(leaf.dtype) if hasattr(leaf, "dtype") else dtype_str
            arr = arr.view(np.dtype(f"u{arr.dtype.itemsize}"))
        # npz keys cannot contain '/'
        nkey = key.replace("/", "|")
        manifest["leaves"].append({
            "key": key, "npz_key": nkey, "shard": None,
            "shape": list(arr.shape), "dtype": dtype_str})
        if shard_bytes + arr.nbytes > budget:
            flush()
        manifest["leaves"][-1]["shard"] = shard_idx
        shard_data[nkey] = arr
        shard_bytes += arr.nbytes
    flush()
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    (tmp / _COMMIT).write_text(str(time.time()))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    return final


def load_checkpoint(path: str | Path, tree_like: Any, *,
                    shardings: Any | None = None) -> tuple[Any, dict]:
    """Restore into the structure of ``tree_like``.

    ``shardings``: optional matching pytree of NamedShardings — the elastic
    restore path places each leaf on the (possibly different) target mesh.
    """
    path = Path(path)
    if not (path / _COMMIT).exists():
        raise FileNotFoundError(f"checkpoint {path} is not committed")
    manifest = json.loads((path / "manifest.json").read_text())
    by_key = {l["key"]: l for l in manifest["leaves"]}
    shards: dict[int, Any] = {}

    def get(key: str) -> np.ndarray:
        info = by_key[key]
        si = info["shard"]
        if si not in shards:
            shards[si] = np.load(path / f"shard_{si:05d}.npz")
        arr = shards[si][info["npz_key"]]
        if str(arr.dtype) != info["dtype"]:
            import ml_dtypes  # noqa: F401 - registers bf16/fp8 dtypes

            arr = arr.view(np.dtype(info["dtype"]))
        return arr

    leaves, treedef = _flatten(tree_like)
    if shardings is not None:
        sh_leaves = jax.tree.leaves(shardings)
        if len(sh_leaves) != len(leaves):
            # a partial/mismatched shardings pytree would zip-truncate
            # silently (list-shaped) or die deep in jax.tree.unflatten
            raise ValueError(
                f"shardings pytree has {len(sh_leaves)} leaves but "
                f"checkpoint {path} expects {len(leaves)}; pass one "
                f"sharding per restored leaf (or shardings=None)")
    else:
        sh_leaves = [None] * len(leaves)
    out = []
    for (key, like), sh in zip(leaves, sh_leaves):
        arr = get(key)
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.numpy.asarray(arr))
    tree = jax.tree.unflatten(jax.tree.structure(tree_like), out)
    return tree, manifest["metadata"] | {"step": manifest["step"]}


class CheckpointManager:
    """Retention + async save + latest-restore."""

    def __init__(self, directory: str | Path, *, keep: int = 3,
                 async_save: bool = False):
        self.directory = Path(directory)
        self.directory.mkdir(parents=True, exist_ok=True)
        self._sweep_tmp()  # a crash mid-save leaves orphaned .tmp_step_* dirs
        self.keep = keep
        self.async_save = async_save
        self._pending: threading.Thread | None = None
        # exception raised by the async writer thread, surfaced to the
        # caller on the next wait()/save()/restore_latest() instead of
        # dying silently in a daemon thread
        self._async_error: BaseException | None = None

    # ------------------------------------------------------------------ #
    def steps(self) -> list[int]:
        out = []
        for p in self.directory.glob("step_*"):
            if (p / _COMMIT).exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def save(self, step: int, tree: Any, metadata: dict | None = None) -> None:
        tree = jax.tree.map(np.asarray, tree)  # snapshot before async write

        def do():
            save_checkpoint(self.directory, step, tree, metadata=metadata)
            self._retain()

        if self.async_save:
            self.wait()  # re-raises a previous async failure before queuing more

            def do_async():
                try:
                    do()
                except BaseException as e:  # noqa: BLE001 - surfaced on wait()
                    self._async_error = e

            self._pending = threading.Thread(target=do_async, daemon=True)
            self._pending.start()
        else:
            do()

    def wait(self) -> None:
        """Block until the pending async save finishes.

        Re-raises any exception the writer thread hit — a failed
        checkpoint must not be discovered only at restore time.
        """
        if self._pending is not None:
            self._pending.join()
            self._pending = None
        if self._async_error is not None:
            err = self._async_error
            self._async_error = None
            raise err

    def _sweep_tmp(self) -> None:
        """Remove uncommitted ``.tmp_step_*`` dirs from interrupted saves.

        Safe while a save is in flight: :func:`save_checkpoint` recreates
        its tmp dir from scratch, and the manager serializes saves (every
        ``save()`` waits for the previous async writer), so any tmp dir
        seen here belongs to a crashed writer, not a live one.
        """
        for p in self.directory.glob(".tmp_step_*"):
            shutil.rmtree(p, ignore_errors=True)

    def _retain(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self.directory / f"step_{s:08d}", ignore_errors=True)
        self._sweep_tmp()

    def restore_latest(self, tree_like: Any, *, shardings: Any | None = None
                       ) -> tuple[Any, dict] | None:
        self.wait()
        steps = self.steps()
        if not steps:
            return None
        return load_checkpoint(self.directory / f"step_{steps[-1]:08d}",
                               tree_like, shardings=shardings)
