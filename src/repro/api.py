"""``repro.api`` — the task-hierarchy facade: one import for everything.

The paper's thesis is that resilience must follow the *layered structure*
of TBPP frameworks (WRATH §III–§V).  This module is that structure as an
API::

    from repro.api import (
        Cluster, DataFlowKernel, Workflow, task,
        WrathPolicy, ProactivePolicy, replay, replicate,
    )

    @task(memory_gb=2)
    def f(x):
        return x + 1

    with DataFlowKernel(Cluster.paper_testbed(),
                        policy=[WrathPolicy(), ProactivePolicy()]) as dfk:
        with dfk.workflow("pipeline", pool="small-mem",
                          propagate="siblings") as wf:
            with wf.workflow("stage1", policy=replay(3)) as stage:
                futs = [f(i) for i in range(8)]
            wf.wait(timeout=30)

Three ideas, one surface:

* **Workflow scopes** (:class:`Workflow`) make the task hierarchy
  explicit: named, nestable, with per-scope defaults (pool / retries /
  node), scope-wide ``cancel()``/``wait()``/``stats()``, and failure
  propagation (``propagate="none"|"siblings"|"ancestors"``).
* **Composable resilience** (:class:`ResiliencePolicy`,
  :class:`PolicyStack`): middleware with lifecycle hooks, resolved per
  invocation (task > workflow chain > engine), first decisive
  :class:`RetryDecision` wins.
* **HPX-style combinators**: :func:`replay` (re-execute up to *n*
  times) and :func:`replicate` (race *n* copies, first ``validate``-d
  result wins), per Gupta et al.'s task-level resiliency primitives.
"""
from repro.checkpoint.task_store import CheckpointPolicy, TaskStore, lineage_key
from repro.core.failures import (
    DependencyError,
    FailureReport,
    TaskCancelledError,
)
from repro.core.monitoring import MonitoringDatabase
from repro.core.proactive import ProactiveConfig, ProactiveSentinel
from repro.engine.cluster import Cluster, Node, ResourcePool
from repro.engine.dfk import DataFlowKernel
from repro.engine.policies import (
    PolicyStack,
    ProactivePolicy,
    ReplayPolicy,
    ReplicatePolicy,
    ReplicationError,
    ResiliencePolicy,
    RetryHandlerPolicy,
    StragglerPolicy,
    WrathPolicy,
    normalize_policies,
    replay,
    replicate,
)
from repro.engine.retry_api import Action, RetryDecision, SchedulingContext
from repro.engine.scheduler import SCHEDULERS, Scheduler, make_scheduler
from repro.engine.task import (
    AppFuture,
    ResourceSpec,
    TaskDef,
    TaskRecord,
    TaskState,
    task,
)
from repro.engine.workflow import PROPAGATE_MODES, Workflow

__all__ = [
    # engine & hierarchy
    "Cluster", "Node", "ResourcePool", "DataFlowKernel", "Workflow",
    "PROPAGATE_MODES", "task", "TaskDef", "TaskRecord", "TaskState",
    "AppFuture", "ResourceSpec",
    # resilience policies
    "ResiliencePolicy", "PolicyStack", "RetryHandlerPolicy", "WrathPolicy",
    "ProactivePolicy", "StragglerPolicy", "ReplayPolicy", "ReplicatePolicy",
    "ReplicationError", "normalize_policies", "replay", "replicate",
    # decisions & context
    "Action", "RetryDecision", "SchedulingContext", "FailureReport",
    "DependencyError", "TaskCancelledError",
    # monitoring & proactive tunables
    "MonitoringDatabase", "ProactiveConfig", "ProactiveSentinel",
    # lineage-aware checkpoint/restart plane
    "TaskStore", "CheckpointPolicy", "lineage_key",
    # placement
    "Scheduler", "SCHEDULERS", "make_scheduler",
]
