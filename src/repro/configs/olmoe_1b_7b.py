"""olmoe-1b-7b [moe]: 16L d2048 16H (GQA kv=16) v50304, 64 experts top-8
ff1024/expert [arXiv:2409.02060]."""
from repro.models import ModelConfig, MoECfg

CONFIG = ModelConfig(
    name="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1024, vocab_size=50304, head_dim=128,
    pattern=(("attn", "moe"),),
    moe=MoECfg(n_experts=64, top_k=8, d_ff_expert=1024, n_shared=0,
               capacity_factor=1.25, dispatch="shard_map"),
)


def smoke_config() -> ModelConfig:
    return CONFIG.scaled(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=64,
        vocab_size=256, head_dim=16,
        moe=MoECfg(n_experts=8, top_k=2, d_ff_expert=64, n_shared=0,
                   capacity_factor=1.25, dispatch="gshard"),
    )
