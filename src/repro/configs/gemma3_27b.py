"""gemma3-27b [dense]: 62L d5376 32H (GQA kv=16) ff21504 v262144 — 5:1
local:global sliding-window attention, 128k context [hf:google/gemma-3]."""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b", family="dense",
    n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16,
    d_ff=21504, vocab_size=262144, head_dim=128,
    # 5 local (sliding-window 1024) : 1 global, repeating
    pattern=(("swa", "dense"),) * 5 + (("attn", "dense"),),
    window=1024,
    tie_embeddings=True,
    subquadratic=True,   # SWA layers dominate; global layers are decode-linear
)


def smoke_config() -> ModelConfig:
    return CONFIG.scaled(n_layers=6, d_model=64, n_heads=4, n_kv_heads=2,
                         d_ff=128, vocab_size=256, head_dim=16, window=32)
