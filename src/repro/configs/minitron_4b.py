"""minitron-4b [dense]: 32L d3072 24H (GQA kv=8) ff9216 v256000 — pruned
nemotron [arXiv:2407.14679]."""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8,
    d_ff=9216, vocab_size=256000, head_dim=128,
    pattern=(("attn", "dense"),),
    head_pad=32,   # 24 heads don't divide the 16-way model axis (§Perf)
)


def smoke_config() -> ModelConfig:
    return CONFIG.scaled(n_layers=2, d_model=48, n_heads=3, n_kv_heads=1,
                         d_ff=96, vocab_size=256, head_dim=16)
