"""llava-next-34b [vlm]: 60L d7168 56H (GQA kv=8) ff20480 v64000 — anyres
tiling [hf:llava-hf/llava-v1.6].  Backbone only: the vision frontend is a
stub; ``input_specs`` provides precomputed patch embeddings (B, S, d)."""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=20480, vocab_size=64000, head_dim=128,
    pattern=(("attn", "dense"),),
    input_kind="embeds",
    head_pad=64,   # 56 heads don't divide the 16-way model axis (§Perf)
)


def smoke_config() -> ModelConfig:
    return CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                         d_ff=128, vocab_size=256, head_dim=16)
