"""seamless-m4t-medium [audio]: enc-dec 12L+12L d1024 16H (GQA kv=16)
ff4096 v256206 [arXiv:2308.11596].  Backbone only: the speech frontend is
a stub; ``input_specs`` provides precomputed frame embeddings."""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="audio",
    n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab_size=256206, head_dim=64,
    pattern=(("attn", "dense"),),
    encoder_layers=12,
)


def smoke_config() -> ModelConfig:
    return CONFIG.scaled(n_layers=2, encoder_layers=2, d_model=64, n_heads=4,
                         n_kv_heads=4, d_ff=128, vocab_size=256, head_dim=16)
