"""mamba2-780m [ssm]: 48L d1536 attn-free, SSD state 128 (state-space
duality) [arXiv:2405.21060]."""
from repro.models import ModelConfig, SSMCfg

CONFIG = ModelConfig(
    name="mamba2-780m", family="ssm",
    n_layers=48, d_model=1536, n_heads=48, n_kv_heads=48,   # heads = d_inner/64
    d_ff=0, vocab_size=50280, head_dim=64,
    pattern=(("ssd", "none"),),
    ssm=SSMCfg(d_state=128, head_dim=64, expand=2, chunk=128, conv_width=4,
               n_groups=1),
    tie_embeddings=True,
    subquadratic=True,
)


def smoke_config() -> ModelConfig:
    return CONFIG.scaled(n_layers=2, d_model=64, n_heads=2, n_kv_heads=2,
                         vocab_size=256,
                         ssm=SSMCfg(d_state=16, head_dim=16, expand=2,
                                    chunk=32, conv_width=4, n_groups=1))
