"""Architecture registry: ``--arch <id>`` resolves here.

Each module defines ``CONFIG`` (the exact assigned configuration) and
``smoke_config()`` (a reduced same-family variant for CPU smoke tests).
"""
from __future__ import annotations

import importlib

ARCH_IDS = (
    "granite_3_2b",
    "minitron_4b",
    "gemma3_27b",
    "deepseek_67b",
    "llava_next_34b",
    "seamless_m4t_medium",
    "deepseek_v3_671b",
    "olmoe_1b_7b",
    "mamba2_780m",
    "recurrentgemma_9b",
)

# canonical dashed ids (CLI spelling) -> module names
ALIASES = {i.replace("_", "-"): i for i in ARCH_IDS}


def get_config(arch: str):
    mod = importlib.import_module(f"repro.configs.{ALIASES.get(arch, arch)}")
    return mod.CONFIG


def get_smoke_config(arch: str):
    mod = importlib.import_module(f"repro.configs.{ALIASES.get(arch, arch)}")
    return mod.smoke_config()


def all_configs():
    return {a: get_config(a) for a in ARCH_IDS}
