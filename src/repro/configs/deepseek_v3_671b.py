"""deepseek-v3-671b [moe]: 61L d7168 128H MLA, ff2048/expert, v129280,
MoE 1 shared + 256 routed top-8, first 3 layers dense (ff 18432), MTP
[arXiv:2412.19437]."""
from repro.models import MLACfg, ModelConfig, MoECfg

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
    d_ff=18432,            # dense layers (first 3) use the big FFN
    vocab_size=129280,
    pattern=(("mla", "moe"),),
    first_k_dense=3,
    mla=MLACfg(q_lora_rank=1536, kv_lora_rank=512,
               qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoECfg(n_experts=256, top_k=8, d_ff_expert=2048, n_shared=1,
               capacity_factor=1.25, dispatch="shard_map"),
    mtp=True,
)


def smoke_config() -> ModelConfig:
    return CONFIG.scaled(
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=160,
        vocab_size=256, first_k_dense=1,
        mla=MLACfg(q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
                   qk_rope_head_dim=8, v_head_dim=16),
        moe=MoECfg(n_experts=8, top_k=2, d_ff_expert=32, n_shared=1,
                   capacity_factor=1.25, dispatch="gshard"),
    )
