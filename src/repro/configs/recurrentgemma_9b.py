"""recurrentgemma-9b [hybrid]: 38L d4096 16H (MQA kv=1) ff12288 v256000 —
RG-LRU + local attention, 2 recurrent : 1 local-attn [arXiv:2402.19427]."""
from repro.models import ModelConfig, RGLRUCfg

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1,
    d_ff=12288, vocab_size=256000, head_dim=256,
    pattern=(("rglru", "dense"), ("rglru", "dense"), ("swa", "dense")),
    window=2048,
    rglru=RGLRUCfg(conv_width=4, lru_width=0),
    tie_embeddings=True,
    subquadratic=True,
)


def smoke_config() -> ModelConfig:
    return CONFIG.scaled(n_layers=3, d_model=64, n_heads=4, n_kv_heads=1,
                         d_ff=128, vocab_size=256, head_dim=16, window=32,
                         rglru=RGLRUCfg(conv_width=4, lru_width=64))
