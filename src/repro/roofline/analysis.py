"""Three-term roofline analysis from the compiled dry-run artifact.

    compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory term     = HLO_bytes / (chips × HBM_bw)
    collective term = collective_bytes / (chips × link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``.
collective_bytes is parsed from the HLO text: the summed operand sizes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute (the prompt-specified convention).

``model_flops`` computes the useful-compute yardstick 6·N·D (train, dense)
or 6·N_active·D (MoE); the ratio MODEL_FLOPS / HLO_FLOPs exposes remat and
dispatch waste.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

import numpy as np

from repro.launch.mesh import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16
from repro.models.config import ModelConfig
from repro.models.spec import is_def

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

# e.g. "bf16[256,4096,7168]{2,1,0}" — captures dtype + dims
_TYPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _type_bytes(dtype: str, dims: str) -> int:
    nb = _DTYPE_BYTES.get(dtype)
    if nb is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * nb


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum operand sizes per collective kind over the HLO module text."""
    out = {k: 0 for k in _COLLECTIVES}
    out["total"] = 0
    for line in hlo_text.splitlines():
        # match "= <type> <op-name>(" — the op must be the instruction,
        # not a substring of a metadata field
        m = re.search(r"=\s+[\w\[\],{}() ]*?\s(" + "|".join(_COLLECTIVES)
                      + r")(?:-start|-done)?\(", line)
        if not m:
            continue
        kind = m.group(1)
        # operand types appear inside the call parentheses
        call = line[m.end() - 1:]
        nbytes = 0
        for tm in _TYPE_RE.finditer(call):
            nbytes += _type_bytes(tm.group(1), tm.group(2))
        out[kind] += nbytes
        out["total"] += nbytes
    return out


def hlo_cost(compiled: Any) -> dict[str, float]:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    byac = float(ca.get("bytes accessed", 0.0))
    return {"flops": flops, "bytes": byac}


def active_param_count(cfg: ModelConfig, defs: Any) -> tuple[int, int]:
    """(total_params, active_params): routed experts count as top_k/E."""
    from repro.compat import tree_flatten_with_path

    total = 0
    active = 0.0
    for path, d in tree_flatten_with_path(defs, is_leaf=is_def)[0]:
        n = int(np.prod(d.shape)) if d.shape else 1
        total += n
        if cfg.moe and "experts" in d.axes:
            active += n * (cfg.moe.top_k / cfg.moe.n_experts)
        else:
            active += n
    return total, int(active)


def model_flops(cfg: ModelConfig, defs: Any, *, kind: str, tokens: int) -> float:
    """6·N_active·D for training, 2·N_active·D for inference."""
    _, active = active_param_count(cfg, defs)
    mult = 6.0 if kind == "train" else 2.0
    return mult * active * tokens


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    coll_breakdown: dict[str, int]
    model_flops: float
    per_device_hbm_bytes: float = 0.0
    # raw per-instruction surface traffic (CPU-module, fusion-naive) — the
    # memory term uses the TPU-fusion-adjusted hlo_bytes instead
    hlo_bytes_raw: float = 0.0
    # surface of score-dominated attention dots (VMEM-resident under the
    # Pallas flash kernel; memory_kernel_s subtracts it)
    attn_score_bytes: float = 0.0
    xla_reported_flops: float = 0.0   # raw HloCostAnalysis (while-body-once)
    xla_reported_bytes: float = 0.0

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS_BF16)

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def memory_kernel_s(self) -> float:
        """Memory term with the flash-attention kernel deployed (score
        tiles stay in VMEM; conservative — softmax reduce traffic on the
        tiles is still counted)."""
        return max(self.hlo_bytes - self.attn_score_bytes, 0.0) / (
            self.chips * HBM_BW)

    @property
    def collective_s(self) -> float:
        return self.coll_bytes / (self.chips * ICI_BW_PER_LINK)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful compute / achievable time: MODEL_FLOPS / (chips·peak·T_bound)
        where T_bound = max of the three terms (the bound on step time)."""
        t = max(self.compute_s, self.memory_s, self.collective_s)
        if t <= 0:
            return 0.0
        return self.model_flops / (self.chips * PEAK_FLOPS_BF16 * t)

    @property
    def roofline_fraction_kernel(self) -> float:
        """Roofline fraction with the Pallas flash-attention kernel's
        VMEM-resident score tiles subtracted from the memory term."""
        t = max(self.compute_s, self.memory_kernel_s, self.collective_s)
        if t <= 0:
            return 0.0
        return self.model_flops / (self.chips * PEAK_FLOPS_BF16 * t)

    def row(self) -> dict[str, Any]:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops": f"{self.hlo_flops:.3e}",
            "hlo_bytes": f"{self.hlo_bytes:.3e}",
            "hlo_bytes_raw": f"{self.hlo_bytes_raw:.3e}",
            "coll_bytes": f"{self.coll_bytes:.3e}",
            "compute_s": round(self.compute_s, 6),
            "memory_s": round(self.memory_s, 6),
            "memory_kernel_s": round(self.memory_kernel_s, 6),
            "collective_s": round(self.collective_s, 6),
            "dominant": self.dominant,
            "model_flops": f"{self.model_flops:.3e}",
            "useful_ratio": round(self.useful_ratio, 4),
            "roofline_fraction": round(self.roofline_fraction, 4),
            "roofline_fraction_kernel": round(self.roofline_fraction_kernel, 4),
            "per_device_hbm_gb": round(self.per_device_hbm_bytes / 2**30, 3),
        }


def analyze(*, arch: str, shape: str, mesh_name: str, chips: int,
            compiled: Any, hlo_text: str, cfg: ModelConfig, defs: Any,
            kind: str, tokens: int,
            per_device_hbm_bytes: float = 0.0) -> RooflineReport:
    """All reported quantities are GLOBAL (per-device HLO costs × chips).

    FLOPs/bytes/collective bytes come from the trip-count-aware HLO
    roll-up (``hlo_cost.analyze_hlo``) because XLA's HloCostAnalysis counts
    while-loop bodies once — a ~n_layers× undercount for scanned models.
    The raw XLA numbers are retained as ``xla_reported_*`` for reference.
    """
    from repro.roofline.hlo_cost import analyze_hlo

    cost = analyze_hlo(hlo_text)
    xla = hlo_cost(compiled)
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=cost.flops * chips, hlo_bytes=cost.bytes_tpu * chips,
        attn_score_bytes=cost.attn_score_bytes * chips,
        hlo_bytes_raw=cost.bytes * chips,
        coll_bytes=cost.coll_total * chips,
        coll_breakdown={k: int(v * chips) for k, v in cost.coll.items()},
        model_flops=model_flops(cfg, defs, kind=kind, tokens=tokens),
        per_device_hbm_bytes=per_device_hbm_bytes,
        xla_reported_flops=xla["flops"] * chips,
        xla_reported_bytes=xla["bytes"] * chips,
    )
