"""Trip-count-aware HLO cost analysis.

``compiled.cost_analysis()`` (XLA HloCostAnalysis) counts each while-loop
*body once*, so scan-over-layers programs under-report FLOPs/bytes/
collectives by ~the layer count.  This module parses the post-optimization
HLO text (``compiled.as_text()``) into its computation call graph and
rolls costs up bottom-up, multiplying while bodies by their trip counts
(extracted from the loop-condition constants).

Per-instruction costs:
* ``dot``          — 2 · prod(result dims) · prod(contracting dims)
* ``convolution``  — 2 · prod(result dims) · prod(kernel dims ÷ features)
* ``fusion``/other — bytes = operand sizes + result size (HBM surface
  traffic; internal fused ops don't touch HBM).  Dots *inside* fusion
  computations still contribute FLOPs via the call roll-up.
* collectives      — operand bytes, attributed by kind.

All values are per-device (post-SPMD module); callers scale by chip count.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# instruction: "  %name = <type(s)> opcode(...operands...), attrs"
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)\)(.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\(|\{)")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        nb = _DTYPE_BYTES.get(m.group(1))
        if nb is None:
            continue
        n = 1
        for d in m.group(2).split(","):
            if d:
                n *= int(d)
        total += n * nb
    return total


def _shape_elems(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


@dataclasses.dataclass
class Instr:
    name: str
    result_type: str
    opcode: str
    operands: list[str]
    attrs: str
    raw: str


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0       # raw: every instruction's surface traffic
    bytes_tpu: float = 0.0   # TPU-fusion-adjusted traffic (see analyze_hlo)
    # traffic of score-dominated attention dots (the part a flash-attention
    # kernel keeps resident in VMEM; see analyze_hlo docstring)
    attn_score_bytes: float = 0.0
    coll: dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.bytes_tpu += other.bytes_tpu * mult
        self.attn_score_bytes += other.attn_score_bytes * mult
        for k, v in other.coll.items():
            self.coll[k] += v * mult

    @property
    def coll_total(self) -> float:
        return sum(self.coll.values())


_OPERAND_REF = re.compile(r"%([\w.\-]+)")
_CALLED = re.compile(r"(?:to_apply|calls|body|condition|branch_computations)="
                     r"[{]?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)[}]?")


def parse_hlo(text: str) -> dict[str, list[Instr]]:
    comps: dict[str, list[Instr]] = {}
    cur: list[Instr] | None = None
    for line in text.splitlines():
        stripped = line.rstrip()
        if not stripped:
            continue
        # computation start: "%name (...) -> ... {" or "ENTRY %name ..."
        if (stripped.endswith("{") and ("->" in stripped or
                                        stripped.startswith("ENTRY"))):
            m = _COMP_RE.match(stripped.lstrip())
            if m:
                cur = comps.setdefault(m.group(1), [])
            continue
        if stripped.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(stripped)
        if not m:
            continue
        name, rtype, opcode, operands, attrs = m.groups()
        cur.append(Instr(name=name, result_type=rtype, opcode=opcode,
                         operands=_OPERAND_REF.findall(operands),
                         attrs=attrs + " " + operands, raw=stripped))
    return comps


def _dot_flops(instr: Instr, types: dict[str, str]) -> float:
    out_elems = _shape_elems(instr.result_type)
    # contracting dims from lhs
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.raw)
    lhs_type = types.get(instr.operands[0], "") if instr.operands else ""
    sm = _SHAPE_RE.search(lhs_type)
    if not (m and sm):
        return 2.0 * out_elems  # fallback
    dims = [int(x) for x in sm.group(2).split(",") if x]
    k = 1
    for ci in (int(x) for x in m.group(1).split(",") if x):
        if ci < len(dims):
            k *= dims[ci]
    return 2.0 * out_elems * k


def _conv_flops(instr: Instr, types: dict[str, str]) -> float:
    out_elems = _shape_elems(instr.result_type)
    k_type = types.get(instr.operands[1], "") if len(instr.operands) > 1 else ""
    k_elems = _shape_elems(k_type)
    sm = _SHAPE_RE.search(k_type)
    if sm:
        dims = [int(x) for x in sm.group(2).split(",") if x]
        # output feature dim contributes to out_elems already
        k_elems = max(1, k_elems // max(dims[-1], 1))
    return 2.0 * out_elems * max(k_elems, 1)


def _while_trips(cond_instrs: list[Instr]) -> int:
    """Extract the loop bound from the condition computation: the constant
    compared against the induction variable with direction=LT."""
    consts: dict[str, int] = {}
    for ins in cond_instrs:
        if ins.opcode == "constant" and ins.result_type.startswith("s32"):
            m = re.search(r"constant\((-?\d+)\)", ins.raw)
            if m:
                consts[ins.name] = int(m.group(1))
    for ins in cond_instrs:
        if ins.opcode == "compare" and "direction=LT" in ins.raw:
            for op in ins.operands:
                if op in consts:
                    return max(consts[op], 1)
    return 1


_SURFACE_BYTES_OPS = {
    "fusion", "copy", "transpose", "broadcast", "reshape", "bitcast",
    "concatenate", "slice", "dynamic-slice", "dynamic-update-slice", "pad",
    "reduce", "convert", "gather", "scatter", "iota", "reverse", "sort",
    "select-and-scatter", "reduce-window", "dot", "convolution", "add",
    "multiply", "subtract", "divide", "exponential", "rsqrt", "tanh",
    "maximum", "minimum", "compare", "select", "log", "negate", "custom-call",
}

# ops whose surface traffic survives TPU fusion: matmuls, data movement
# with nontrivial access patterns, reductions and loop stacking.  Pure
# elementwise chains, converts, copies, broadcasts and layout ops fuse
# into their producers/consumers on TPU and are excluded from bytes_tpu.
_TPU_BYTES_OPS = {
    "dot", "convolution", "gather", "scatter", "dynamic-slice",
    "dynamic-update-slice", "concatenate", "sort", "reduce",
    "reduce-window", "select-and-scatter", "custom-call",
}
# bytes_tpu of a fusion = Σ surfaces of marker instructions INSIDE its
# computation (the fusion's own surface is the union of what its markers
# stream; pure-elementwise fusions contribute nothing)
_TPU_FUSION_MARKERS = _TPU_BYTES_OPS


def analyze_hlo(text: str, entry: str | None = None) -> Cost:
    comps = parse_hlo(text)
    if not comps:
        return Cost()
    if entry is None:
        m = re.search(r"ENTRY\s+%?([\w.\-]+)", text)
        entry = m.group(1) if m else max(comps, key=lambda c: len(comps[c]))

    memo: dict[str, Cost] = {}

    def comp_cost(name: str, stack: tuple = ()) -> Cost:
        if name in memo:
            return memo[name]
        if name in stack or name not in comps:
            return Cost()
        instrs = comps[name]
        types = {i.name: i.result_type for i in instrs}
        total = Cost()
        for ins in instrs:
            op = ins.opcode
            if op == "while":
                mb = re.search(r"body=%?([\w.\-]+)", ins.raw)
                mc = re.search(r"condition=%?([\w.\-]+)", ins.raw)
                body = mb.group(1) if mb else None
                cond = mc.group(1) if mc else None
                # XLA records the static trip count in backend_config
                mt = re.search(r'"known_trip_count":\s*\{"n":\s*"(\d+)"', ins.raw)
                if mt:
                    trips = int(mt.group(1))
                else:
                    trips = _while_trips(comps.get(cond, [])) if cond else 1
                if body:
                    total.add(comp_cost(body, stack + (name,)), trips)
                continue
            is_coll = None
            for ck in _COLLECTIVES:
                if op == ck or op == ck + "-start":
                    is_coll = ck
                    break
            if is_coll:
                nbytes = sum(_shape_bytes(types.get(o, "")) for o in ins.operands)
                if nbytes == 0:
                    nbytes = _shape_bytes(ins.result_type)
                if is_coll == "all-reduce":
                    # physically reduce-scatter + all-gather: 2× the wire
                    # traffic of the one-directional collectives
                    nbytes *= 2
                total.coll[is_coll] += nbytes
                total.bytes += nbytes
                total.bytes_tpu += nbytes
                continue
            surface = (_shape_bytes(ins.result_type)
                       + sum(_shape_bytes(types.get(o, ""))
                             for o in ins.operands))
            # in-place / sparse-access ops: traffic is the moved region,
            # not the full buffer (XLA aliases DUS in place; gather reads
            # only the gathered rows)
            if op == "dynamic-update-slice" and len(ins.operands) > 1:
                surface = 2 * _shape_bytes(types.get(ins.operands[1], ""))
            elif op == "dynamic-slice":
                surface = 2 * _shape_bytes(ins.result_type)
            elif op == "gather":
                surface = 2 * _shape_bytes(ins.result_type) + sum(
                    _shape_bytes(types.get(o, "")) for o in ins.operands[1:])
            elif op == "scatter" and len(ins.operands) > 2:
                surface = (2 * _shape_bytes(types.get(ins.operands[2], ""))
                           + _shape_bytes(types.get(ins.operands[1], "")))
            if op == "dot":
                total.flops += _dot_flops(ins, types)
                total.bytes += surface
                total.bytes_tpu += surface
                # score-dominated attention dot: one tensor (the S×S score
                # tile) carries ≥75% of the dot's surface.  A flash kernel
                # keeps that tile in VMEM — bucket it for the adjusted
                # memory term.
                sizes = [_shape_bytes(ins.result_type)] + [
                    _shape_bytes(types.get(o, "")) for o in ins.operands]
                if sizes and max(sizes) >= 0.75 * sum(sizes):
                    total.attn_score_bytes += max(sizes)
                continue
            if op == "convolution":
                total.flops += _conv_flops(ins, types)
                total.bytes += surface
                total.bytes_tpu += surface
                continue
            if op in ("call", "conditional", "custom-call") or op == "fusion":
                for group in _CALLED.findall(ins.attrs):
                    for callee in re.split(r",\s*%?", group):
                        sub = comp_cost(callee, stack + (name,))
                        # fusion internals don't touch HBM for raw bytes
                        # (surface counted below), but marker instructions
                        # inside DO stream their operands: roll bytes_tpu up
                        total.flops += sub.flops
                        total.bytes_tpu += sub.bytes_tpu
                        for k, v in sub.coll.items():
                            total.coll[k] += v
            if op in _SURFACE_BYTES_OPS:
                total.bytes += surface
                if op in _TPU_BYTES_OPS:
                    total.bytes_tpu += surface
                # elementwise-ish fusion flops: 1 flop per output element
                if op == "fusion":
                    total.flops += _shape_elems(ins.result_type)
        memo[name] = total
        return total

    # reduce/sort/map also reference computations via to_apply; those are
    # tiny scalar computations — the roll-up above handles them generically.
    return comp_cost(entry)
