"""Int8 gradient compression with error feedback.

Beyond-paper distributed-optimization trick (DESIGN.md §5): before the
data-parallel all-reduce, gradients are quantized to int8 with a per-tensor
scale; the quantization error is carried into the next step (error
feedback), which keeps SGD/Adam convergence intact in practice.  Used by
the shard_map data-parallel variant measured in EXPERIMENTS.md §Perf — the
collective moves 4x fewer bytes than fp32 (2x vs bf16).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def compress_int8(g: jax.Array, err: jax.Array | None = None
                  ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (q int8, scale fp32 scalar, new_err fp32)."""
    g32 = g.astype(jnp.float32)
    if err is not None:
        g32 = g32 + err
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    new_err = g32 - deq
    return q, scale, new_err


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_tree(grads: Any, err_tree: Any | None = None):
    leaves, td = jax.tree.flatten(grads)
    errs = jax.tree.leaves(err_tree) if err_tree is not None else [None] * len(leaves)
    qs, scales, new_errs = [], [], []
    for g, e in zip(leaves, errs):
        q, s, ne = compress_int8(g, e)
        qs.append(q)
        scales.append(s)
        new_errs.append(ne)
    return (jax.tree.unflatten(td, qs), jax.tree.unflatten(td, scales),
            jax.tree.unflatten(td, new_errs))


def decompress_tree(qs: Any, scales: Any):
    return jax.tree.map(decompress_int8, qs, scales)
