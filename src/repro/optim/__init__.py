from repro.optim.adamw import (
    OptConfig,
    adamw_apply,
    opt_state_defs,
    init_opt_state,
    lr_at,
)
from repro.optim.compress import compress_int8, decompress_int8

__all__ = ["OptConfig", "adamw_apply", "opt_state_defs", "init_opt_state",
           "lr_at", "compress_int8", "decompress_int8"]
