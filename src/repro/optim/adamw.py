"""AdamW with dtype-configurable moments and warmup-cosine schedule.

For ≥67B-parameter cells the Adam moments are stored in bf16 so that
(params bf16 + m bf16 + v bf16 + fp32 master off) fits 16 GB/chip at 512
chips (DESIGN.md §5); smaller models default to fp32 moments.  The state
tree is expressible as ParamDefs so the dry-run can build it abstractly.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.spec import ParamDef, is_def


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"       # "bfloat16" for the huge cells

    @property
    def mdtype(self):
        return jnp.dtype(self.moment_dtype)


def lr_at(step: jax.Array, cfg: OptConfig) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(math.pi * frac))
    scale = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * scale


def opt_state_defs(param_defs_tree: Any, cfg: OptConfig) -> dict:
    """Abstract Adam state (for the dry-run): m, v mirror params."""

    def moment(d: ParamDef) -> ParamDef:
        return ParamDef(d.shape, d.axes, init="zeros", dtype=cfg.mdtype)

    return {
        "m": jax.tree.map(moment, param_defs_tree, is_leaf=is_def),
        "v": jax.tree.map(moment, param_defs_tree, is_leaf=is_def),
        "count": ParamDef((), (), init="zeros", dtype=jnp.int32),
    }


def init_opt_state(params: Any, cfg: OptConfig) -> dict:
    return {
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, cfg.mdtype), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, cfg.mdtype), params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_apply(params: Any, grads: Any, state: dict, cfg: OptConfig
                ) -> tuple[Any, dict, dict]:
    """One AdamW update.  Returns (params, state, metrics)."""
    count = state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if cfg.clip_norm > 0 else jnp.float32(1.0)
    lr = lr_at(count, cfg)
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m32 / b1c
        vhat = v32 / b2c
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * step
        return newp.astype(p.dtype), m32.astype(cfg.mdtype), v32.astype(cfg.mdtype)

    flat_p, td = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(td, [o[0] for o in out])
    new_m = jax.tree.unflatten(td, [o[1] for o in out])
    new_v = jax.tree.unflatten(td, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "count": count}, metrics
