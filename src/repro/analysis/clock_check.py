"""CLK: clock discipline — no ambient time or global randomness.

Byte-identical sim traces (the PR 4 contract: same seed => identical
event trace on every machine) require every timestamp in sim-reachable
code to flow through the injected :class:`~repro.engine.events.Clock`
and every random draw through a seeded ``random.Random``.  One raw
``time.time()`` in a code path the sim plane exercises silently splits
real-run and sim-run behaviour.

Rules (monotonic *measurement* time — ``time.monotonic`` /
``time.perf_counter`` — is deliberately allowed: it never lands in a
trace and has no virtual-clock analog worth faking):

=======  =========================================================
CLK001   ``time.time()`` call — use ``clock.time()`` / ``ctx.now()``
CLK002   ``time.sleep()`` call — use ``clock.sleep()`` /
         ``Event.wait(timeout)`` / EventLoop scheduling
CLK003   naive ``datetime.now/utcnow/today`` — derive wall stamps
         from ``clock.time()``
CLK004   global ``random.*`` call — use a seeded ``random.Random``
CLK005   bare reference to ``time.time``/``time.sleep`` (e.g.
         ``default_factory=time.time``) — same fix as CLK001/2
=======  =========================================================
"""
from __future__ import annotations

import ast

from repro.analysis.findings import Finding
from repro.analysis.scan import Module, ScopedVisitor, canonical, import_aliases

_CALL_RULES = {
    "time.time": ("CLK001", "raw time.time() call",
                  "read the injected Clock: clock.time() / ctx.now() / REAL_CLOCK.time()"),
    "time.time_ns": ("CLK001", "raw time.time_ns() call",
                     "read the injected Clock: clock.time() / ctx.now()"),
    "time.sleep": ("CLK002", "raw time.sleep() call",
                   "clock.sleep(), Event.wait(timeout), or an EventLoop call_later"),
}

_DATETIME_BANNED = {
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "datetime.datetime.today", "datetime.date.today",
}

#: global-module random callables that are fine: constructing an owned,
#: seedable generator is the *fix*, not the violation
_RANDOM_ALLOWED = {"Random", "SystemRandom"}


class _ClockVisitor(ScopedVisitor):
    def __init__(self, mod: Module):
        super().__init__()
        self.mod = mod
        self.mod_alias, self.from_alias = import_aliases(mod.tree)
        self.findings: list[Finding] = []
        self._call_funcs: set[int] = set()  # ids of nodes used as call targets

    def _emit(self, node: ast.AST, rule: str, message: str, hint: str) -> None:
        self.findings.append(Finding(
            rule=rule, file=self.mod.rel, line=node.lineno,
            col=node.col_offset, symbol=self.symbol,
            message=message, hint=hint))

    def _canon(self, node: ast.AST) -> str | None:
        return canonical(node, self.mod_alias, self.from_alias)

    def visit_Call(self, node: ast.Call) -> None:
        self._call_funcs.add(id(node.func))
        canon = self._canon(node.func)
        if canon is not None:
            if canon in _CALL_RULES:
                rule, msg, hint = _CALL_RULES[canon]
                self._emit(node, rule, msg, hint)
            elif canon in _DATETIME_BANNED:
                self._emit(node, "CLK003",
                           f"naive wall-clock call {canon}()",
                           "derive wall stamps from clock.time() "
                           "(virtual clocks have a deterministic epoch)")
            elif (canon.startswith("random.") and canon.count(".") == 1
                    and canon.split(".")[1] not in _RANDOM_ALLOWED):
                self._emit(node, "CLK004",
                           f"global {canon}() draws from shared, unseeded state",
                           "draw from an owned seeded generator: rng = random.Random(seed)")
        self.generic_visit(node)

    def _visit_ref(self, node: ast.AST) -> None:
        # bare references (not call targets) to banned callables — the
        # `default_factory=time.time` pattern defers the violation to runtime
        if id(node) not in self._call_funcs and isinstance(node.ctx, ast.Load):
            canon = self._canon(node)
            if canon in _CALL_RULES:
                _, msg, hint = _CALL_RULES[canon]
                self._emit(node, "CLK005", f"reference to {canon} "
                           "(called later, outside clock control)", hint)
            elif canon in _DATETIME_BANNED:
                self._emit(node, "CLK005", f"reference to {canon}",
                           "derive wall stamps from clock.time()")
        self.generic_visit(node)

    def visit_Attribute(self, node: ast.Attribute) -> None:
        self._visit_ref(node)

    def visit_Name(self, node: ast.Name) -> None:
        self._visit_ref(node)


def check_clock(modules: list[Module]) -> list[Finding]:
    findings: list[Finding] = []
    for mod in modules:
        if not mod.sim_reachable:
            continue
        v = _ClockVisitor(mod)
        v.visit(mod.tree)
        findings += v.findings
    return findings
