"""EVT: the event-schema registry — monitor-event names are an API.

The chaos search's n-gram coverage (PR 9), the golden sim traces, and
every dashboard query key off monitor-event *name strings*.  A typo'd
name doesn't crash anything — it silently forks the schema: coverage
tokens stop matching, trace diffs churn, queries miss events.  This
checker extracts every name literal passed to ``record_task_event`` /
``record_system_event`` / ``record_gauge`` and validates it against the
checked-in :mod:`repro.analysis.event_registry`.

=======  ==========================================================
EVT001   event/gauge name literal not in the registry (typo, or a
         new event — add it via ``--update-registry``)
EVT002   dynamic event name whose shape the registry cannot check
         (no registered prefix, not an if-else of literals, not an
         exempt plumbing function)
=======  ==========================================================

Recognized dynamic shapes: f-strings with a registered prefix
(``f"fault_{kind}"``), if-else of two literals (both validated), and
registered pass-through wrappers (``RequestQueue._event`` — its *call
sites* are validated instead).  ``MonitoringDatabase.ingest`` is the
radio deserializer and exempt by construction (its names were validated
at the sending site).
"""
from __future__ import annotations

import ast
from pathlib import Path

from repro.analysis.findings import Finding
from repro.analysis.scan import Module, ScopedVisitor, terminal_name

#: recorder method -> (registry kind, positional index of the name arg)
RECORDERS = {
    "record_task_event": ("task", 1),
    "record_system_event": ("system", 0),
    "record_gauge": ("gauge", 0),
}

#: pass-through wrappers: method name -> (kind, name-arg index).  Calls
#: *to* a wrapper are validated like recorder calls; the non-literal
#: recorder call *inside* the wrapper body is exempt.
WRAPPERS = {
    "_event": ("system", 0),
}

#: f-string prefixes that name a registered event *family*; members are
#: closed sets elsewhere (sim fault kinds, proactive decision kinds)
KNOWN_PREFIXES = ("fault_", "proactive_")

#: functions whose dynamic recorder calls re-emit already-validated
#: names (deserializers / generic re-publishers)
EXEMPT_DYNAMIC = frozenset({
    ("core/monitoring.py", "MonitoringDatabase.ingest"),
})


def _load_registry() -> dict[str, frozenset[str]]:
    from repro.analysis import event_registry as reg

    return {"task": reg.TASK_EVENTS, "system": reg.SYSTEM_EVENTS,
            "gauge": reg.GAUGES}


def _recorder_target(node: ast.Call) -> tuple[str, int, bool] | None:
    """(kind, name-arg index, is_wrapper) if this call emits an event."""
    name = terminal_name(node.func)
    if name in RECORDERS:
        kind, idx = RECORDERS[name]
        return kind, idx, False
    if name in WRAPPERS:
        kind, idx = WRAPPERS[name]
        return kind, idx, True
    return None


def _literal_names(arg: ast.AST) -> list[str] | None:
    """Extract the literal name(s), or None if the shape is dynamic."""
    if isinstance(arg, ast.Constant) and isinstance(arg.value, str):
        return [arg.value]
    if (isinstance(arg, ast.IfExp)
            and isinstance(arg.body, ast.Constant) and isinstance(arg.body.value, str)
            and isinstance(arg.orelse, ast.Constant) and isinstance(arg.orelse.value, str)):
        return [arg.body.value, arg.orelse.value]
    return None


def _fstring_prefix(arg: ast.AST) -> str | None:
    if (isinstance(arg, ast.JoinedStr) and arg.values
            and isinstance(arg.values[0], ast.Constant)
            and isinstance(arg.values[0].value, str)):
        return arg.values[0].value
    return None


class _EventVisitor(ScopedVisitor):
    def __init__(self, mod: Module, registry: dict[str, frozenset[str]] | None,
                 extract: dict[str, set[str]] | None):
        super().__init__()
        self.mod = mod
        self.registry = registry
        self.extract = extract
        self.findings: list[Finding] = []

    def visit_Call(self, node: ast.Call) -> None:
        target = _recorder_target(node)
        if target is not None:
            kind, idx, is_wrapper = target
            inside_wrapper = any(part in WRAPPERS for part in self.symbol.split("."))
            exempt = ((self.mod.rel, self.symbol) in EXEMPT_DYNAMIC
                      or (not is_wrapper and inside_wrapper))
            if not exempt:
                self._check_name_arg(node, kind, idx)
        self.generic_visit(node)

    def _check_name_arg(self, node: ast.Call, kind: str, idx: int) -> None:
        if len(node.args) <= idx:
            return  # name passed by keyword / malformed — out of scope
        arg = node.args[idx]
        names = _literal_names(arg)
        if names is not None:
            for name in names:
                if self.extract is not None:
                    self.extract[kind].add(name)
                elif self.registry is not None and name not in self.registry[kind]:
                    self._emit(arg, "EVT001",
                               f"{kind} event name {name!r} is not in the registry",
                               "fix the typo, or register the new name: "
                               "python -m repro.analysis --update-registry")
            return
        prefix = _fstring_prefix(arg)
        if prefix is not None:
            if any(prefix.startswith(p) for p in KNOWN_PREFIXES):
                return  # registered event family, e.g. f"fault_{kind}"
            if self.registry is not None:
                self._emit(arg, "EVT002",
                           f"f-string event prefix {prefix!r} is not a registered family",
                           f"registered prefixes: {', '.join(KNOWN_PREFIXES)}")
            return
        if self.registry is not None:
            self._emit(arg, "EVT002",
                       f"dynamic {kind} event name the registry cannot validate",
                       "use a literal, an if-else of literals, a registered "
                       "prefix family, or register the function as a wrapper")

    def _emit(self, node: ast.AST, rule: str, msg: str, hint: str) -> None:
        self.findings.append(Finding(
            rule=rule, file=self.mod.rel, line=node.lineno,
            col=node.col_offset, symbol=self.symbol, message=msg, hint=hint))


def check_events(modules: list[Module]) -> list[Finding]:
    registry = _load_registry()
    findings: list[Finding] = []
    for mod in modules:
        v = _EventVisitor(mod, registry, extract=None)
        v.visit(mod.tree)
        findings += v.findings
    return findings


def extract_registry(modules: list[Module]) -> dict[str, set[str]]:
    """Collect every literal event/gauge name emitted by ``modules``."""
    out: dict[str, set[str]] = {"task": set(), "system": set(), "gauge": set()}
    for mod in modules:
        v = _EventVisitor(mod, registry=None, extract=out)
        v.visit(mod.tree)
    return out


_REGISTRY_TEMPLATE = '''"""Checked-in registry of every monitor-event and gauge name.

GENERATED by ``python -m repro.analysis --update-registry`` from the
name literals in ``src/repro`` — edit code, not this file.  The chaos
search's coverage tokens and the golden sim traces key off these exact
strings; an unregistered name fails the build (EVT001), and CI checks
this file matches the code (``--check-registry``).
"""
from __future__ import annotations

TASK_EVENTS = frozenset({{
{task}
}})

SYSTEM_EVENTS = frozenset({{
{system}
}})

GAUGES = frozenset({{
{gauge}
}})

#: dynamic-name families (``f"fault_{{kind}}"`` …); members are closed
#: sets owned by the emitting module
PREFIXES = {prefixes!r}
'''


def render_registry(extracted: dict[str, set[str]]) -> str:
    def block(names: set[str]) -> str:
        return "\n".join(f"    {n!r}," for n in sorted(names))

    return _REGISTRY_TEMPLATE.format(
        task=block(extracted["task"]),
        system=block(extracted["system"]),
        gauge=block(extracted["gauge"]),
        prefixes=tuple(KNOWN_PREFIXES),
    )


def registry_path() -> Path:
    return Path(__file__).resolve().parent / "event_registry.py"


def registry_drift(modules: list[Module]) -> list[str]:
    """Human-readable diffs between the code and the committed registry
    (empty = in sync)."""
    current = _load_registry()
    extracted = extract_registry(modules)
    drift: list[str] = []
    for kind in ("task", "system", "gauge"):
        missing = sorted(extracted[kind] - current[kind])
        stale = sorted(current[kind] - extracted[kind])
        for name in missing:
            drift.append(f"{kind} event {name!r} emitted but not registered")
        for name in stale:
            drift.append(f"{kind} event {name!r} registered but never emitted")
    return drift
