"""Findings and the baseline waiver file.

A :class:`Finding` is one rule violation at one source location.  The
committed ``analysis_baseline.json`` waives *intentional* violations —
each entry needs a one-line justification — and ``--strict`` fails on
anything not waived.

Baseline entries match on ``(rule, file, symbol)`` rather than line
numbers, so routine edits to a file don't invalidate its waivers; a
waiver only goes stale when the violating code moves to a different
function or is removed (reported as an unused waiver).
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from pathlib import Path
from typing import Any


@dataclass(frozen=True)
class Finding:
    """One rule violation: ruff-style location + code + fix hint."""

    rule: str       # e.g. "CLK001"
    file: str       # package-relative posix path, e.g. "engine/dfk.py"
    line: int
    col: int
    symbol: str     # enclosing qualname ("Class.method", "func", "<module>")
    message: str
    hint: str = ""  # how to fix it

    def render(self) -> str:
        s = f"{self.file}:{self.line}:{self.col} {self.rule} [{self.symbol}] {self.message}"
        if self.hint:
            s += f"\n    fix: {self.hint}"
        return s


class Baseline:
    """The committed waiver list: intentional violations + justifications."""

    def __init__(self, entries: list[dict[str, Any]]):
        for e in entries:
            for field in ("rule", "file", "symbol", "justification"):
                if not e.get(field):
                    raise ValueError(
                        f"baseline entry missing {field!r}: {e!r}")
        self.entries = entries
        self._used = [False] * len(entries)

    @classmethod
    def load(cls, path: str | Path) -> "Baseline":
        path = Path(path)
        if not path.exists():
            return cls([])
        data = json.loads(path.read_text())
        return cls(data.get("waivers", []))

    def match(self, finding: Finding) -> bool:
        """True (and mark the entry used) if ``finding`` is waived."""
        for i, e in enumerate(self.entries):
            if (e["rule"] == finding.rule and e["file"] == finding.file
                    and e["symbol"] == finding.symbol):
                self._used[i] = True
                return True
        return False

    def unused(self) -> list[dict[str, Any]]:
        """Waivers that matched nothing — stale entries to prune."""
        return [e for i, e in enumerate(self.entries) if not self._used[i]]


def split_baselined(findings: list[Finding],
                    baseline: Baseline) -> tuple[list[Finding], list[Finding]]:
    """Partition into (active, waived)."""
    active: list[Finding] = []
    waived: list[Finding] = []
    for f in findings:
        (waived if baseline.match(f) else active).append(f)
    return active, waived
