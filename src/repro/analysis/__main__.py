"""CLI for the contract-enforcing static-analysis suite.

Usage::

    python -m repro.analysis [paths...]      # report all findings
    python -m repro.analysis --strict        # exit 1 on non-baselined
    python -m repro.analysis --update-registry
    python -m repro.analysis --check-registry

With no paths, scans the ``repro`` package this module was imported
from.  Baseline waivers live next to this package in
``analysis_baseline.json`` (override with ``--baseline``).
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from repro.analysis import find_modules, run_checks
from repro.analysis.event_check import (
    extract_registry,
    registry_drift,
    registry_path,
    render_registry,
)
from repro.analysis.findings import Baseline, split_baselined

_PKG_ROOT = Path(__file__).resolve().parent.parent  # .../src/repro
_DEFAULT_BASELINE = Path(__file__).resolve().parent / "analysis_baseline.json"


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="contract-enforcing static analysis (clock/lock/event/hook)")
    ap.add_argument("paths", nargs="*", type=Path,
                    help="package roots or files to scan (default: the "
                         "installed repro package)")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on any non-baselined finding")
    ap.add_argument("--baseline", type=Path, default=_DEFAULT_BASELINE,
                    help="waiver file (default: %(default)s)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline (report everything as active)")
    ap.add_argument("--update-registry", action="store_true",
                    help="regenerate event_registry.py from the scanned code")
    ap.add_argument("--check-registry", action="store_true",
                    help="exit 1 if event_registry.py drifted from the code")
    args = ap.parse_args(argv)

    roots = args.paths or [_PKG_ROOT]
    modules = find_modules(roots)
    if not modules:
        print(f"no python modules found under {', '.join(map(str, roots))}",
              file=sys.stderr)
        return 2

    if args.update_registry:
        text = render_registry(extract_registry(modules))
        registry_path().write_text(text)
        print(f"wrote {registry_path()}")
        return 0

    if args.check_registry:
        drift = registry_drift(modules)
        for line in drift:
            print(f"registry drift: {line}")
        if drift:
            print(f"{len(drift)} drift(s) — regenerate with "
                  "`python -m repro.analysis --update-registry`")
            return 1
        print("event registry in sync")
        return 0

    findings = run_checks(modules)
    baseline = Baseline([]) if args.no_baseline else Baseline.load(args.baseline)
    active, waived = split_baselined(findings, baseline)

    for f in active:
        print(f.render())
    stale = baseline.unused()
    for e in stale:
        print(f"stale baseline waiver (matched nothing): "
              f"{e['rule']} {e['file']} [{e['symbol']}]")

    print(f"{len(active)} finding(s), {len(waived)} baselined, "
          f"{len(stale)} stale waiver(s)")
    if args.strict and (active or stale):
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
