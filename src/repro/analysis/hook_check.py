"""HOK: hook exception-safety — raises must meet a degrade path.

:class:`~repro.engine.policies.PolicyStack` wraps every hook fan-out in
``try/except`` with *documented* per-hook degrade semantics (a raising
``on_failure`` fails the task terminally; a raising reviewer lets the
decision stand; a raising admitter admits).  A hook invoked directly —
not through the stack, not under a local ``try`` — turns any policy bug
into an engine crash on whatever thread happened to fire it.

=======  ==========================================================
HOK001   direct hook invocation with no degrade path: the receiver
         is not a policy stack and the call sits outside any
         exception-catching ``try``
HOK002   explicit ``raise`` inside a ``ResiliencePolicy`` hook
         override — it relies on the stack's per-hook degrade
         semantics; confirm them and baseline with the reason
=======  ==========================================================

Receivers named ``policies``/``stack``/``policy`` are assumed to be
:class:`PolicyStack` instances (the engine's convention), and
``engine/policies.py`` itself is exempt — its per-policy calls *are*
the degrade path.
"""
from __future__ import annotations

import ast

from repro.analysis.findings import Finding
from repro.analysis.scan import Module, ScopedVisitor, dotted

#: the ResiliencePolicy hook surface (keep in sync with engine/policies.py)
HOOK_NAMES = frozenset({
    "on_submit", "on_dispatch", "on_running", "on_failure", "on_result",
    "on_tick", "review_decision", "admit_request", "memo_lookup",
    "memo_commit", "memo_invalidate", "bind", "unbind",
})

#: HOK001 scope: runtime fan-out hooks only.  Lifecycle ``bind``/
#: ``unbind`` are excluded — a failing bind *should* propagate at
#: session start (and ``bind`` is too generic a name: schedulers and
#: sockets bind too) — as is ``on_result``-style dispatch through an
#: object's *own* callback attribute (``self.on_result`` is the engine's
#: completion pipeline, not a policy invocation).
RUNTIME_HOOKS = HOOK_NAMES - {"bind", "unbind"}

#: receiver names assumed to be PolicyStack instances (engine convention)
SAFE_RECEIVERS = frozenset({"policies", "stack", "policy", "_policies"})

#: the stack module: its per-policy fan-out calls ARE the degrade path
EXEMPT_MODULES = frozenset({"engine/policies.py"})


def _receiver_tail(expr: ast.AST) -> str | None:
    name = dotted(expr)
    if name is None:
        return None
    return name.split(".")[-1]


def _catches_broadly(handler: ast.ExceptHandler) -> bool:
    t = handler.type
    if t is None:
        return True  # bare except
    names = []
    if isinstance(t, ast.Tuple):
        names = [dotted(e) or "" for e in t.elts]
    else:
        names = [dotted(t) or ""]
    return any(n.split(".")[-1] in ("Exception", "BaseException") for n in names)


class _HookCallVisitor(ScopedVisitor):
    def __init__(self, mod: Module):
        super().__init__()
        self.mod = mod
        self.findings: list[Finding] = []
        self._try_depth = 0  # inside a broadly-catching try body?

    def visit_Try(self, node: ast.Try) -> None:
        protected = any(_catches_broadly(h) for h in node.handlers)
        if protected:
            self._try_depth += 1
        for stmt in node.body:
            self.visit(stmt)
        if protected:
            self._try_depth -= 1
        for part in (node.handlers, node.orelse, node.finalbody):
            for stmt in part:
                self.visit(stmt)

    def visit_Call(self, node: ast.Call) -> None:
        f = node.func
        if (isinstance(f, ast.Attribute) and f.attr in RUNTIME_HOOKS
                and self._try_depth == 0):
            tail = _receiver_tail(f.value)
            is_super = (isinstance(f.value, ast.Call)
                        and isinstance(f.value.func, ast.Name)
                        and f.value.func.id == "super")
            is_own_attr = isinstance(f.value, ast.Name) and f.value.id == "self"
            if tail not in SAFE_RECEIVERS and not is_super and not is_own_attr:
                self.findings.append(Finding(
                    rule="HOK001", file=self.mod.rel, line=node.lineno,
                    col=node.col_offset, symbol=self.symbol,
                    message=f"hook {f.attr}() invoked on {dotted(f.value) or '<expr>'} "
                            "with no degrade path",
                    hint="route it through the PolicyStack, or wrap the call "
                         "in try/except with explicit degrade semantics"))
        self.generic_visit(node)


def _policy_subclasses(tree: ast.Module) -> list[ast.ClassDef]:
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef):
            for base in node.bases:
                name = dotted(base) or ""
                if name.split(".")[-1] == "ResiliencePolicy":
                    out.append(node)
                    break
    return out


def _raises_in(fn: ast.FunctionDef) -> list[ast.Raise]:
    """Raise statements lexically in ``fn`` (nested defs excluded)."""
    out: list[ast.Raise] = []

    def rec(node: ast.AST) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
                continue
            if isinstance(child, ast.Raise):
                out.append(child)
            rec(child)

    rec(fn)
    return out


def check_hooks(modules: list[Module]) -> list[Finding]:
    findings: list[Finding] = []
    for mod in modules:
        if mod.rel in EXEMPT_MODULES:
            continue
        v = _HookCallVisitor(mod)
        v.visit(mod.tree)
        findings += v.findings
        # HOK002: raising hook overrides
        for cls in _policy_subclasses(mod.tree):
            for item in cls.body:
                if not isinstance(item, ast.FunctionDef) or item.name not in HOOK_NAMES:
                    continue
                for sub in _raises_in(item):
                    findings.append(Finding(
                        rule="HOK002", file=mod.rel, line=sub.lineno,
                        col=sub.col_offset,
                        symbol=f"{cls.name}.{item.name}",
                        message=f"hook {item.name}() raises; it relies on the "
                                "PolicyStack's per-hook degrade semantics",
                        hint="prefer returning a decision; if raising is the "
                             "intended degrade, baseline with the semantics"))
    return findings
