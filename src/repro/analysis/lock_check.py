"""LCK: lock discipline — what may happen while a lock is held.

The DataFlowKernel's locking contract (``dfk.py``, "LOCKING
DISCIPLINE") says ``_lock`` guards bookkeeping *only*: policy hooks,
future resolution (``set_result``/``set_exception``), and anything that
can block must run outside it, or a policy callback that re-enters the
engine deadlocks the whole run.  PR 6 audited this by hand, once; this
checker re-audits on every push.

Mechanics: for every ``with <lock>:`` region we collect what happens
inside — directly, and transitively through an intra-module call graph
(``self.method()`` -> same class, ``func()`` -> same module; anything
else is a resolution boundary).  Conditions constructed over a lock
(``threading.Condition(self._lock)``) alias to that lock, so waiting on
the engine's shared condition is not a nested acquisition.

=======  ==========================================================
LCK001   user-facing callback (policy hook, validator,
         ``set_result``/``set_exception``, ``_resolve_stack``)
         reachable under a lock
LCK002   blocking call (``.result()``, thread ``.join()``, any
         ``sleep``) reachable under a lock
LCK003   nested acquisition of a *different* lock while one is held
LCK004   lock-order cycle across the scanned modules (deadlock risk)
=======  ==========================================================

``Condition.wait`` is exempt (it releases the lock it waits on).  The
call graph is an over-approximation: a finding means "a path the
analyzer cannot rule out", and intentional, ordered nestings are waived
in the baseline with their ordering argument.
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field

from repro.analysis.findings import Finding
from repro.analysis.scan import Module, dotted, terminal_name

#: attribute/variable names that denote a lock-like object
_LOCK_NAME = re.compile(r"lock|mutex|cond|sem|_all_done", re.IGNORECASE)

#: user-facing callbacks: resilience-policy hooks, validators, and
#: future resolution — the things the DFK contract keeps outside locks
CALLBACK_NAMES = frozenset({
    "on_submit", "on_dispatch", "on_running", "on_failure", "on_result",
    "on_tick", "review_decision", "admit_request", "memo_lookup",
    "memo_commit", "memo_invalidate", "bind", "unbind", "validate",
    "set_result", "set_exception", "_resolve_stack",
})

#: call names that block the calling thread outright
_BLOCKING_NAMES = frozenset({"result", "sleep"})

_MAX_DEPTH = 8  # call-graph traversal bound (paths deeper are invisible)


@dataclass
class _FuncSummary:
    """Everything one function does, regardless of its own lock regions."""

    symbol: str
    callbacks: list[tuple[str, int]] = field(default_factory=list)
    blocking: list[tuple[str, int]] = field(default_factory=list)
    acquires: list[tuple[str, int]] = field(default_factory=list)
    calls: list[tuple[str, int]] = field(default_factory=list)  # resolvable keys


def _is_blocking_call(node: ast.Call) -> str | None:
    name = terminal_name(node.func)
    if name in _BLOCKING_NAMES:
        return name
    if name == "join" and isinstance(node.func, ast.Attribute):
        recv = dotted(node.func.value) or ""
        # str.join is ubiquitous; only thread-ish receivers block
        if re.search(r"thread|worker|proc", recv, re.IGNORECASE):
            return "join"
    return None


def _is_callback_call(node: ast.Call) -> str | None:
    name = terminal_name(node.func)
    return name if name in CALLBACK_NAMES else None


class _ModuleLocks:
    """Per-module lock model: aliases, function summaries, lock regions."""

    def __init__(self, mod: Module):
        self.mod = mod
        self.cond_alias: dict[str, str] = {}   # lock-id -> aliased lock-id
        self.funcs: dict[str, _FuncSummary] = {}
        # (lock_id, region stmts, enclosing symbol, with-node) per region
        self.regions: list[tuple[str, list[ast.stmt], str, ast.With]] = []
        self._collect()

    # -- lock identity -------------------------------------------------
    def _lock_id(self, expr: ast.AST, cls: str | None) -> str | None:
        name = dotted(expr)
        if name is None:
            return None
        attr = name.split(".")[-1]
        if not _LOCK_NAME.search(attr):
            return None
        if name.startswith("self.") and cls:
            lid = f"{cls}.{name[len('self.'):]}"
        elif "." not in name:
            lid = f"<module>.{name}"
        else:
            lid = name
        return self.cond_alias.get(lid, lid)

    def _collect_cond_aliases(self) -> None:
        # self._all_done = threading.Condition(self._lock)  =>  alias
        for cls_node in ast.walk(self.mod.tree):
            if not isinstance(cls_node, ast.ClassDef):
                continue
            for node in ast.walk(cls_node):
                if not (isinstance(node, ast.Assign) and len(node.targets) == 1
                        and isinstance(node.value, ast.Call)):
                    continue
                if terminal_name(node.value.func) != "Condition":
                    continue
                if not node.value.args:
                    continue
                tgt = dotted(node.targets[0])
                src = dotted(node.value.args[0])
                if tgt and src and tgt.startswith("self.") and src.startswith("self."):
                    self.cond_alias[f"{cls_node.name}.{tgt[5:]}"] = \
                        f"{cls_node.name}.{src[5:]}"

    # -- function summaries + lock regions ----------------------------
    def _collect(self) -> None:
        self._collect_cond_aliases()
        mod = self

        class V(ast.NodeVisitor):
            def __init__(self) -> None:
                self.cls: str | None = None
                self.func: _FuncSummary | None = None
                self.symbol = "<module>"

            def visit_ClassDef(self, node: ast.ClassDef) -> None:
                prev, self.cls = self.cls, node.name
                self.generic_visit(node)
                self.cls = prev

            def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
                prev_f, prev_s = self.func, self.symbol
                self.symbol = f"{self.cls}.{node.name}" if self.cls else node.name
                self.func = _FuncSummary(symbol=self.symbol)
                mod.funcs[self.symbol] = self.func
                self.generic_visit(node)
                self.func, self.symbol = prev_f, prev_s

            visit_AsyncFunctionDef = visit_FunctionDef

            def visit_With(self, node: ast.With) -> None:
                for item in node.items:
                    lid = mod._lock_id(item.context_expr, self.cls)
                    if lid is not None:
                        mod.regions.append((lid, node.body, self.symbol, node))
                        if self.func is not None:
                            self.func.acquires.append((lid, node.lineno))
                self.generic_visit(node)

            def visit_Call(self, node: ast.Call) -> None:
                if self.func is not None:
                    cb = _is_callback_call(node)
                    if cb:
                        self.func.callbacks.append((cb, node.lineno))
                    blk = _is_blocking_call(node)
                    if blk:
                        self.func.blocking.append((blk, node.lineno))
                    if terminal_name(node.func) == "acquire":
                        recv = node.func.value if isinstance(node.func, ast.Attribute) else None
                        lid = mod._lock_id(recv, self.cls) if recv is not None else None
                        if lid is not None:
                            self.func.acquires.append((lid, node.lineno))
                    key = self._resolve(node)
                    if key is not None:
                        self.func.calls.append((key, node.lineno))
                self.generic_visit(node)

            def _resolve(self, node: ast.Call) -> str | None:
                """Map a call to a same-module function summary key."""
                f = node.func
                if isinstance(f, ast.Attribute):
                    recv = dotted(f.value)
                    if recv == "self" and self.cls:
                        return f"{self.cls}.{f.attr}"
                    return None
                if isinstance(f, ast.Name):
                    return f.id
                return None

        V().visit(self.mod.tree)


def _region_scan(mod: _ModuleLocks, lock_id: str, body: list[ast.stmt],
                 symbol: str, cls: str | None,
                 findings: list[Finding], edges: dict[tuple[str, str], tuple[str, int, str]]) -> None:
    """Scan one held-lock region: direct violations + reachable ones."""
    rel = mod.mod.rel

    def emit(rule: str, line: int, msg: str, hint: str) -> None:
        findings.append(Finding(rule=rule, file=rel, line=line, col=0,
                                symbol=symbol, message=msg, hint=hint))

    direct_calls: list[tuple[str, int]] = []

    class R(ast.NodeVisitor):
        # stay lexical: nested defs run later, not under this lock
        def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
            return
        visit_AsyncFunctionDef = visit_FunctionDef
        visit_Lambda = visit_FunctionDef

        def visit_With(self, node: ast.With) -> None:
            for item in node.items:
                lid = mod._lock_id(item.context_expr, cls)
                if lid is not None and lid != lock_id:
                    emit("LCK003", node.lineno,
                         f"acquires {lid} while holding {lock_id}",
                         "hold one lock at a time, or keep this ordering "
                         "global and baseline it with the ordering argument")
                    edges.setdefault((lock_id, lid), (rel, node.lineno, symbol))
            self.generic_visit(node)

        def visit_Call(self, node: ast.Call) -> None:
            cb = _is_callback_call(node)
            if cb:
                # waiting on the lock's own condition is how the engine
                # sleeps; calling anything user-facing is the violation
                emit("LCK001", node.lineno,
                     f"user-facing callback {cb}() called while holding {lock_id}",
                     "snapshot state under the lock, invoke the callback after release")
            blk = _is_blocking_call(node)
            if blk:
                emit("LCK002", node.lineno,
                     f"blocking call {blk}() while holding {lock_id}",
                     "release the lock before blocking")
            if terminal_name(node.func) == "acquire":
                recv = node.func.value if isinstance(node.func, ast.Attribute) else None
                lid = mod._lock_id(recv, cls) if recv is not None else None
                if lid is not None and lid != lock_id:
                    emit("LCK003", node.lineno,
                         f"acquires {lid} while holding {lock_id}",
                         "hold one lock at a time")
                    edges.setdefault((lock_id, lid), (rel, node.lineno, symbol))
            # record resolvable calls for transitive reachability
            f = node.func
            if isinstance(f, ast.Attribute) and dotted(f.value) == "self" and cls:
                direct_calls.append((f"{cls}.{f.attr}", node.lineno))
            elif isinstance(f, ast.Name) and f.id in mod.funcs:
                direct_calls.append((f.id, node.lineno))
            self.generic_visit(node)

    r = R()
    for stmt in body:
        r.visit(stmt)

    # transitive: anything a called same-module function does, happens
    # under this lock too
    for key, line in direct_calls:
        seen: set[str] = set()
        stack = [(key, [key], 0)]
        while stack:
            cur, path, depth = stack.pop()
            if cur in seen or depth > _MAX_DEPTH or cur not in mod.funcs:
                continue
            seen.add(cur)
            fs = mod.funcs[cur]
            via = " -> ".join(path)
            for cb, _l in fs.callbacks:
                emit("LCK001", line,
                     f"user-facing callback {cb}() reachable under {lock_id} via {via}",
                     "move the callback outside the locked region")
            for blk, _l in fs.blocking:
                emit("LCK002", line,
                     f"blocking call {blk}() reachable under {lock_id} via {via}",
                     "release the lock before blocking")
            for lid, _l in fs.acquires:
                if lid != lock_id:
                    emit("LCK003", line,
                         f"acquires {lid} under {lock_id} via {via}",
                         "keep the lock ordering global, or restructure")
                    edges.setdefault((lock_id, lid), (rel, line, symbol))
            for nxt, _l in fs.calls:
                stack.append((nxt, path + [nxt], depth + 1))


def _find_cycles(edges: dict[tuple[str, str], tuple[str, int, str]]) -> list[list[str]]:
    graph: dict[str, list[str]] = {}
    for a, b in edges:
        graph.setdefault(a, []).append(b)
    cycles: list[list[str]] = []
    seen_cycles: set[tuple[str, ...]] = set()

    def dfs(start: str, cur: str, path: list[str], visited: set[str]) -> None:
        for nxt in graph.get(cur, ()):
            if nxt == start:
                cyc = path[:]
                key = tuple(sorted(cyc))
                if key not in seen_cycles:
                    seen_cycles.add(key)
                    cycles.append(cyc)
            elif nxt not in visited:
                visited.add(nxt)
                dfs(start, nxt, path + [nxt], visited)

    for node in sorted(graph):
        dfs(node, node, [node], {node})
    return cycles


def check_locks(modules: list[Module]) -> list[Finding]:
    findings: list[Finding] = []
    edges: dict[tuple[str, str], tuple[str, int, str]] = {}
    for mod in modules:
        if not mod.sim_reachable:
            continue
        ml = _ModuleLocks(mod)
        for lock_id, body, symbol, node in ml.regions:
            cls = symbol.split(".")[0] if "." in symbol else None
            _region_scan(ml, lock_id, body, symbol, cls, findings, edges)
    for cyc in _find_cycles(edges):
        a = cyc[0]
        b = cyc[1 % len(cyc)]
        rel, line, symbol = edges.get((a, b)) or next(iter(edges.values()))
        order = " -> ".join(cyc + [cyc[0]])
        findings.append(Finding(
            rule="LCK004", file=rel, line=line, col=0, symbol=symbol,
            message=f"lock-order cycle: {order} (deadlock risk)",
            hint="pick one global acquisition order and stick to it"))
    return findings
