"""Shared AST plumbing: module discovery, scope tracking, name resolution.

Checkers operate on :class:`Module` objects — a parsed AST plus a
package-relative path used both for reporting and for scope filters
(clock/lock discipline only applies to sim-reachable packages; loose
files passed explicitly — e.g. test fixtures — are always in scope).
"""
from __future__ import annotations

import ast
from dataclasses import dataclass
from pathlib import Path

#: packages whose code is reachable from the deterministic sim plane —
#: the scope of the clock- and lock-discipline checkers
SIM_REACHABLE = ("engine", "core", "serve", "sim", "train")


@dataclass
class Module:
    path: Path      # absolute filesystem path
    rel: str        # package-relative posix path (or bare filename)
    tree: ast.Module
    sim_reachable: bool  # subject to clock/lock discipline?


def _load(path: Path, rel: str, sim_reachable: bool) -> Module:
    tree = ast.parse(path.read_text(), filename=str(path))
    return Module(path=path, rel=rel, tree=tree, sim_reachable=sim_reachable)


def find_modules(roots: list[Path]) -> list[Module]:
    """Collect modules under each root (package dir or single file).

    For a package root (e.g. ``src/repro``) every ``*.py`` beneath it is
    scanned; ``rel`` is the root-relative path and sim-reachability is
    decided by the top-level package name.  A single-file root is always
    fully in scope (fixture files exercise every checker).
    """
    modules: list[Module] = []
    for root in roots:
        root = root.resolve()
        if root.is_file():
            modules.append(_load(root, root.name, sim_reachable=True))
            continue
        for path in sorted(root.rglob("*.py")):
            rel = path.relative_to(root).as_posix()
            top = rel.split("/", 1)[0]
            # files directly under the root (no package prefix to judge
            # by) are fully in scope, like single-file roots
            in_scope = top in SIM_REACHABLE or "/" not in rel
            modules.append(_load(path, rel, sim_reachable=in_scope))
    return modules


def dotted(node: ast.AST) -> str | None:
    """Render an attribute chain of Names as ``a.b.c`` (else None)."""
    parts: list[str] = []
    cur = node
    while isinstance(cur, ast.Attribute):
        parts.append(cur.attr)
        cur = cur.value
    if not isinstance(cur, ast.Name):
        return None
    parts.append(cur.id)
    return ".".join(reversed(parts))


def terminal_name(node: ast.AST) -> str | None:
    """The last component of a call target: ``a.b.c()`` -> ``c``."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


class ScopedVisitor(ast.NodeVisitor):
    """NodeVisitor that tracks the enclosing class/function qualname."""

    def __init__(self) -> None:
        self._scope: list[str] = []
        self._class_stack: list[str] = []

    @property
    def symbol(self) -> str:
        return ".".join(self._scope) if self._scope else "<module>"

    @property
    def current_class(self) -> str | None:
        return self._class_stack[-1] if self._class_stack else None

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self._scope.append(node.name)
        self._class_stack.append(node.name)
        self.generic_visit(node)
        self._class_stack.pop()
        self._scope.pop()

    def _visit_func(self, node: ast.AST) -> None:
        self._scope.append(node.name)  # type: ignore[attr-defined]
        self.generic_visit(node)
        self._scope.pop()

    visit_FunctionDef = _visit_func
    visit_AsyncFunctionDef = _visit_func


def import_aliases(tree: ast.Module) -> tuple[dict[str, str], dict[str, str]]:
    """Map local names to canonical modules / dotted origins.

    Returns ``(mod_alias, from_alias)``: ``import time as _t`` yields
    ``mod_alias["_t"] == "time"``; ``from time import sleep as zzz``
    yields ``from_alias["zzz"] == "time.sleep"``.
    """
    mod_alias: dict[str, str] = {}
    from_alias: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                mod_alias[a.asname or a.name.split(".")[0]] = a.name
        elif isinstance(node, ast.ImportFrom) and node.module and node.level == 0:
            for a in node.names:
                from_alias[a.asname or a.name] = f"{node.module}.{a.name}"
    return mod_alias, from_alias


def canonical(node: ast.AST, mod_alias: dict[str, str],
              from_alias: dict[str, str]) -> str | None:
    """Canonical dotted origin of a Name/Attribute, through import aliases.

    ``_time.sleep`` -> ``time.sleep``; with ``from datetime import
    datetime``, ``datetime.now`` -> ``datetime.datetime.now``.
    """
    name = dotted(node)
    if name is None:
        return None
    head, _, rest = name.partition(".")
    if head in from_alias:
        base = from_alias[head]
    elif head in mod_alias:
        base = mod_alias[head]
    else:
        return None
    return f"{base}.{rest}" if rest else base
