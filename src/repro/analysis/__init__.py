"""Contract-enforcing static analysis for the WRATH engine.

The engine's resilience guarantees are *contract properties of the
runtime*: byte-identical sim traces require every timestamp to flow
through the injected :class:`~repro.engine.events.Clock`; the real-time
response path requires policy hooks and future resolution to never run
under the DataFlowKernel lock; and the coverage-guided chaos search keys
its n-gram coverage off monitor-event name strings.  This package makes
those contracts machine-checked on every push instead of tribal
knowledge.

Run it like a linter::

    PYTHONPATH=src python -m repro.analysis            # report findings
    PYTHONPATH=src python -m repro.analysis --strict   # fail on non-baselined
    PYTHONPATH=src python -m repro.analysis --update-registry
    PYTHONPATH=src python -m repro.analysis --check-registry

Four checkers, ruff-style ``file:line:col CODE`` findings:

========  ===========================================================
CLK00x    clock discipline: raw ``time.time``/``time.sleep``/
          ``datetime.now``/global ``random.*`` in sim-reachable code
LCK00x    lock discipline: callbacks, blocking calls, and nested lock
          acquisitions reachable while a lock is held; lock-order cycles
EVT00x    event-schema registry: every monitor-event name literal must
          appear in the checked-in ``event_registry``
HOK00x    hook exception-safety: ``ResiliencePolicy`` hooks invoked
          outside the stack's degrade path, hooks that raise
========  ===========================================================

Intentional violations are waived in ``analysis_baseline.json`` with a
one-line justification each; ``--strict`` fails on anything else.
"""
from __future__ import annotations

from repro.analysis.findings import Baseline, Finding
from repro.analysis.scan import Module, find_modules

__all__ = ["Baseline", "Finding", "Module", "find_modules", "run_checks"]


def run_checks(modules: list[Module]) -> list[Finding]:
    """Run every checker over ``modules`` and return sorted findings."""
    from repro.analysis.clock_check import check_clock
    from repro.analysis.event_check import check_events
    from repro.analysis.hook_check import check_hooks
    from repro.analysis.lock_check import check_locks

    findings: list[Finding] = []
    findings += check_clock(modules)
    findings += check_locks(modules)
    findings += check_events(modules)
    findings += check_hooks(modules)
    return sorted(findings, key=lambda f: (f.file, f.line, f.col, f.rule))
