"""Version-portability shims for the JAX API surface this repo touches.

The container pins an older jax (0.4.x) whose public names differ from the
current releases the code was written against.  Rather than sprinkling
``try/except ImportError`` at every call site, the few divergent entry
points live here:

* :func:`tree_flatten_with_path` — ``jax.tree.flatten_with_path`` (new)
  vs. ``jax.tree_util.tree_flatten_with_path`` (always present);
* :func:`tpu_compiler_params` — ``pltpu.CompilerParams`` (new) vs.
  ``pltpu.TPUCompilerParams`` (0.4.x) for Pallas kernel compiler options.

``repro.launch.mesh.make_mesh`` handles the third divergence
(``jax.make_mesh(axis_types=...)``) next to the mesh constants it needs.
"""
from __future__ import annotations

from typing import Any

import jax


def tree_flatten_with_path(tree: Any, is_leaf=None):
    """``jax.tree.flatten_with_path`` on any supported jax version."""
    flatten = getattr(jax.tree, "flatten_with_path", None)
    if flatten is not None:
        return flatten(tree, is_leaf=is_leaf)
    return jax.tree_util.tree_flatten_with_path(tree, is_leaf=is_leaf)


def tpu_compiler_params(**kwargs: Any):
    """Build Pallas-TPU compiler params under either class name."""
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kwargs)
