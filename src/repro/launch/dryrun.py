import os

from repro.launch.xla_flags import merged_flags

os.environ["XLA_FLAGS"] = merged_flags("dryrun", os.environ.get("XLA_FLAGS", ""),
                                       platform="cpu")

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this proves the distribution config is coherent without real
hardware: ``jit(step).lower(**ShapeDtypeStructs).compile()`` must succeed
on the 16×16 single-pod mesh AND the 2×16×16 multi-pod mesh, and the
compiled artifact yields ``memory_analysis()`` (fits-in-HBM proof) and
``cost_analysis()`` + HLO collectives (roofline terms, §Roofline).

The ``XLA_FLAGS`` assignment above MUST stay first (before any jax
import): jax locks the device count on first initialization.  The flag
set itself (``--xla_force_host_platform_device_count=512``) lives in
``repro.launch.xla_flags`` with the other tuned per-platform profiles.

Usage:
    python -m repro.launch.dryrun --arch granite-3-2b --shape train_4k
    python -m repro.launch.dryrun --all --mesh both
    python -m repro.launch.dryrun --arch deepseek-v3-671b --shape train_4k \
        --mesh single --elastic 4     # degraded mesh after losing 4 hosts
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs import ARCH_IDS, get_config
from repro.distributed import (
    ACT_RULES,
    CACHE_RULES,
    PARAM_RULES,
    StepConfig,
    activation_sharding,
    build_prefill_step,
    build_serve_step,
    build_train_step,
    defs_shardings,
    spec_for,
)
from repro.launch.mesh import make_elastic_mesh, make_production_mesh, mesh_chip_count
from repro.launch.shapes import (
    SHAPES,
    batch_axes,
    batch_specs,
    shape_applicable,
)
from repro.models import cache_defs, param_defs
from repro.models.config import ModelConfig
from repro.models.spec import abstract
from repro.optim import OptConfig
from repro.optim.adamw import opt_state_defs
from repro.roofline import analyze

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"

# per-arch step tuning for the train_4k cell: microbatch count + dtypes.
# Chosen so per-device HBM stays under the 16 GB v5e budget (EXPERIMENTS.md
# §Dry-run records the resulting numbers).
TRAIN_TUNING: dict[str, tuple[int, str, str]] = {
    # name: (microbatches, accum_dtype, moment_dtype)
    "deepseek-v3-671b": (8, "bfloat16", "bfloat16"),   # §Perf: halves grad-AR
    "deepseek-67b": (16, "bfloat16", "bfloat16"),
    "llava-next-34b": (8, "bfloat16", "bfloat16"),
    "gemma3-27b": (8, "float32", "float32"),
    "recurrentgemma-9b": (4, "float32", "float32"),
    "minitron-4b": (4, "float32", "float32"),
    "granite-3-2b": (1, "float32", "float32"),   # §Perf: mb=1 + full-DP
    "seamless-m4t-medium": (2, "float32", "float32"),
    "olmoe-1b-7b": (4, "float32", "float32"),
    "mamba2-780m": (2, "float32", "float32"),
}


def step_tuning(cfg: ModelConfig) -> tuple[StepConfig, OptConfig]:
    mb, acc, mom = TRAIN_TUNING.get(cfg.name, (1, "float32", "float32"))
    return (StepConfig(microbatches=mb, remat=True, accum_dtype=acc),
            OptConfig(moment_dtype=mom))


# per-arch activation-rule overrides (EXPERIMENTS.md §Perf).  For small
# dense models, TP all-reduces of activations dominate; sharding the batch
# over (data × model) turns the layout into pure DP/ZeRO-3 (weights
# all-gathered per layer — far fewer bytes than per-layer activation
# all-reduces when params << activations).
ARCH_ACT_OVERRIDES: dict[str, dict] = {
    "granite-3-2b": {"batch": (("pod", "data", "model"), ("pod", "data"),
                               ("data",))},
}


def act_rules_for(cfg: ModelConfig, shape_kind: str):
    if shape_kind == "train" and cfg.name in ARCH_ACT_OVERRIDES:
        return ACT_RULES.replace(**ARCH_ACT_OVERRIDES[cfg.name])
    return ACT_RULES


@dataclasses.dataclass
class CellResult:
    arch: str
    shape: str
    mesh: str
    status: str
    seconds: float = 0.0
    error: str = ""
    memory: dict | None = None
    roofline: dict | None = None
    skip_reason: str = ""


def _memory_dict(compiled) -> dict:
    ma = compiled.memory_analysis()
    d = {k: int(getattr(ma, k)) for k in
         ("argument_size_in_bytes", "output_size_in_bytes",
          "temp_size_in_bytes", "alias_size_in_bytes")}
    d["per_device_total"] = (d["argument_size_in_bytes"]
                             + d["output_size_in_bytes"]
                             + d["temp_size_in_bytes"]
                             - d["alias_size_in_bytes"])
    return d


def run_cell(arch: str, shape: str, mesh_kind: str, *,
             elastic_lost_hosts: int = 0, save: bool = True) -> CellResult:
    cfg = get_config(arch)
    ok, reason = shape_applicable(cfg, shape)
    cell = CellResult(arch=cfg.name, shape=shape, mesh=mesh_kind, status="skip",
                      skip_reason=reason)
    if not ok:
        return cell

    multi = mesh_kind == "multi"
    if elastic_lost_hosts:
        mesh = make_elastic_mesh(elastic_lost_hosts, multi_pod=multi)
        cell.mesh = f"{mesh_kind}-elastic{elastic_lost_hosts}"
    else:
        mesh = make_production_mesh(multi_pod=multi)
    chips = mesh_chip_count(mesh)
    sp = SHAPES[shape]
    step_cfg, opt_cfg = step_tuning(cfg)

    t0 = time.time()
    try:
        pdefs = param_defs(cfg)
        p_sh = defs_shardings(pdefs, PARAM_RULES, mesh)
        p_abs = abstract(pdefs)
        b_specs = batch_specs(cfg, shape)
        b_axes = batch_axes(cfg, shape)
        act_rules = act_rules_for(cfg, sp.kind)
        b_sh = {k: jax.sharding.NamedSharding(
            mesh, spec_for(b_specs[k].shape, b_axes[k], act_rules, mesh))
            for k in b_specs}

        with mesh, activation_sharding(mesh, act_rules):
            if sp.kind == "train":
                odefs = opt_state_defs(pdefs, opt_cfg)
                o_sh = defs_shardings(odefs, PARAM_RULES, mesh)
                o_abs = abstract(odefs)
                step = build_train_step(cfg, opt_cfg, step_cfg)
                jitted = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                                 out_shardings=(p_sh, o_sh, None),
                                 donate_argnums=(0, 1))
                lowered = jitted.lower(p_abs, o_abs, b_specs)
                tokens = sp.global_batch * sp.seq_len
            elif sp.kind == "prefill":
                step = build_prefill_step(cfg, step_cfg)
                cdefs = cache_defs(cfg, sp.global_batch, sp.seq_len)
                c_sh = defs_shardings(cdefs, CACHE_RULES, mesh)
                jitted = jax.jit(step, in_shardings=(p_sh, b_sh),
                                 out_shardings=(None, c_sh))
                lowered = jitted.lower(p_abs, b_specs)
                tokens = sp.global_batch * sp.seq_len
            else:  # decode
                step = build_serve_step(cfg)
                cdefs = cache_defs(cfg, sp.global_batch, sp.seq_len)
                c_sh = defs_shardings(cdefs, CACHE_RULES, mesh)
                c_abs = abstract(cdefs)
                jitted = jax.jit(step, in_shardings=(p_sh, c_sh, b_sh),
                                 out_shardings=(None, c_sh),
                                 donate_argnums=(1,))
                lowered = jitted.lower(p_abs, c_abs, b_specs)
                tokens = sp.global_batch  # one token per sequence

            compiled = lowered.compile()

        mem = _memory_dict(compiled)
        hlo = compiled.as_text()
        report = analyze(
            arch=cfg.name, shape=shape, mesh_name=cell.mesh, chips=chips,
            compiled=compiled, hlo_text=hlo, cfg=cfg, defs=pdefs,
            kind=sp.kind, tokens=tokens,
            per_device_hbm_bytes=mem["per_device_total"])

        cell.status = "ok"
        cell.memory = mem
        cell.roofline = report.row()
        cell.roofline["coll_breakdown"] = dict(report.coll_breakdown)
        cell.roofline["xla_reported_flops"] = f"{report.xla_reported_flops:.3e}"
        cell.seconds = time.time() - t0
        if save:
            RESULTS_DIR.mkdir(parents=True, exist_ok=True)
            out = RESULTS_DIR / f"{cfg.name}__{shape}__{cell.mesh}.json"
            out.write_text(json.dumps(dataclasses.asdict(cell), indent=1))
    except Exception as e:  # noqa: BLE001 - report compile failures as data
        cell.status = "fail"
        cell.error = f"{type(e).__name__}: {e}\n{traceback.format_exc()[-2000:]}"
        cell.seconds = time.time() - t0
        if save:
            RESULTS_DIR.mkdir(parents=True, exist_ok=True)
            out = RESULTS_DIR / f"{cfg.name}__{shape}__{cell.mesh}.json"
            out.write_text(json.dumps(dataclasses.asdict(cell), indent=1))
    return cell


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="architecture id (dashed ok)")
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true", help="all archs × shapes")
    ap.add_argument("--elastic", type=int, default=0,
                    help="lost hosts for the degraded-mesh dry-run")
    ap.add_argument("--no-save", action="store_true")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    rows = []
    for arch in archs:
        for shape in shapes:
            for mk in meshes:
                cell = run_cell(arch, shape, mk,
                                elastic_lost_hosts=args.elastic,
                                save=not args.no_save)
                r = cell.roofline or {}
                print(f"{cell.arch:22s} {shape:12s} {cell.mesh:8s} "
                      f"{cell.status:5s} {cell.seconds:7.1f}s "
                      f"hbm/dev={r.get('per_device_hbm_gb', '-'):>8} "
                      f"dom={r.get('dominant', cell.skip_reason or cell.error[:60])}",
                      flush=True)
                rows.append(cell)
    n_ok = sum(1 for c in rows if c.status == "ok")
    n_skip = sum(1 for c in rows if c.status == "skip")
    n_fail = sum(1 for c in rows if c.status == "fail")
    print(f"\n{n_ok} ok, {n_skip} skipped (noted), {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
