"""WRATH-supervised training launcher.

Single-host execution path (reduced configs, real JAX compute, virtual
hosts with failure injection):

    python -m repro.launch.train --arch granite-3-2b --steps 200 \
        --inject host_down:50:host01 --inject nan:80

For production-mesh work use the dry-run launcher
(``python -m repro.launch.dryrun``), which lowers/compiles the same
``build_train_step`` against the 16×16 / 2×16×16 meshes.
"""
from __future__ import annotations

import argparse
import json

from repro.configs import ARCH_IDS, get_smoke_config
from repro.engine.policies import WrathPolicy, replay
from repro.engine.scheduler import SCHEDULERS, make_scheduler
from repro.launch.xla_flags import apply_xla_flags
from repro.optim import OptConfig
from repro.train import TrainEvent, WrathTrainSupervisor


def parse_event(spec: str) -> TrainEvent:
    """kind:step[:host[:factor]] — e.g. host_down:50:host01, nan:80,
    straggler:100:host02:40"""
    parts = spec.split(":")
    kind, step = parts[0], int(parts[1])
    host = parts[2] if len(parts) > 2 else None
    factor = float(parts[3]) if len(parts) > 3 else 5.0
    return TrainEvent(step=step, kind=kind, host=host, factor=factor)


def main() -> None:
    # tuned compiler flags (repro.launch.xla_flags) must be in the
    # environment before the jax backend initializes — importing jax
    # above does not initialize it, the first computation does
    apply_xla_flags("train")
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b",
                    help=f"one of {', '.join(a.replace('_', '-') for a in ARCH_IDS)}")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--hosts", type=int, default=4)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--d-model", type=int, default=0,
                    help="override the smoke config width")
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/wrath_train")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--inject", action="append", default=[],
                    help="failure event kind:step[:host[:factor]] (repeatable)")
    ap.add_argument("--scheduler", default=None, choices=sorted(SCHEDULERS),
                    help="placement policy for shard->host assignment and "
                         "speculation targets (default: legacy fixed order)")
    ap.add_argument("--replay", type=int, default=0,
                    help="prepend an HPX-style replay(N) policy: every "
                         "shard gets N attempts before WRATH's taxonomy "
                         "is even consulted (0 = WRATH stack only)")
    ap.add_argument("--json", action="store_true", help="machine-readable report")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    overrides = {}
    if args.d_model:
        overrides["d_model"] = args.d_model
    if args.layers:
        overrides["n_layers"] = args.layers
    if overrides:
        cfg = cfg.scaled(**overrides)

    # the training plane runs on the same composable policy stack as the
    # task plane: first decisive decision wins, WRATH is the terminal expert
    policy = ([replay(args.replay, on_exhausted="defer")]
              if args.replay else []) + [WrathPolicy()]
    sup = WrathTrainSupervisor(
        cfg, OptConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                       total_steps=args.steps),
        n_hosts=args.hosts, global_batch=args.global_batch, seq_len=args.seq,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        policy=policy,
        scheduler=make_scheduler(args.scheduler) if args.scheduler else None)
    events = [parse_event(e) for e in args.inject]
    rep = sup.run(args.steps, events=events)

    if args.json:
        print(json.dumps({
            "arch": cfg.name, "steps": rep.steps_completed,
            "loss_first": rep.losses[0] if rep.losses else None,
            "loss_last": rep.losses[-1] if rep.losses else None,
            "restores": rep.restores, "speculations": rep.speculations,
            "denylisted": rep.denylisted, "recoveries": rep.recoveries,
        }, indent=1))
        return
    print(f"{cfg.name}: {rep.steps_completed} steps, "
          f"loss {rep.losses[0]:.3f} -> {rep.losses[-1]:.3f}")
    print(f"restores={rep.restores} speculations={rep.speculations} "
          f"denylisted={rep.denylisted} hosts={rep.final_hosts}")
    for r in rep.recoveries:
        print(f"  step {r['step']:4d} {r['error']:26s} {r['host']:8s} "
              f"-> {r['action']} (rung {r['rung']})")


if __name__ == "__main__":
    main()
