"""Production mesh construction (TPU v5e target).

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state — jax locks the
device count on first initialization, and the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before that.

Hardware constants (v5e): 197 bf16 TFLOP/s per chip, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import jax
import numpy as np

# TPU v5e per-chip constants used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # bytes/s
ICI_BW_PER_LINK = 50e9            # bytes/s/link


def _auto(n: int):
    """``(AxisType.Auto,) * n`` on jax >= 0.5, None on older releases
    (whose ``jax.make_mesh`` has no ``axis_types`` parameter)."""
    try:
        from jax.sharding import AxisType
    except ImportError:
        return None
    return (AxisType.Auto,) * n


def make_mesh(shape, axes):
    """Version-portable ``jax.make_mesh`` with explicit-Auto axis types."""
    axis_types = _auto(len(axes))
    if axis_types is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=axis_types)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_elastic_mesh(n_lost_hosts: int = 0, *, chips_per_host: int = 4,
                      multi_pod: bool = False):
    """Largest divisor-friendly degraded mesh after losing hosts.

    WRATH's environment-layer recovery (DESIGN.md §2): denylisted hosts
    shrink the ``data`` axis to the largest power of two that still fits,
    keeping ``model`` intact so parameter sharding (and thus checkpoint
    layout compatibility) is preserved.
    """
    total = (512 if multi_pod else 256) - n_lost_hosts * chips_per_host
    model = 16
    data = 1 << int(np.floor(np.log2(max(total // model, 1))))
    if multi_pod and data >= 32:
        return make_mesh((2, data // 2, model), ("pod", "data", "model"))
    return make_mesh((data, model), ("data", "model"))


def mesh_chip_count(mesh) -> int:
    return int(np.prod(mesh.devices.shape))
