"""WRATH-supervised serving launcher.

Static batching (the historical baseline)::

    python -m repro.launch.serve --arch olmoe-1b-7b --requests 16 \
        --replicas 3 --kill replica0:5

Continuous batching with SLO admission and autoscaling::

    python -m repro.launch.serve --continuous --arrival-rate 40 \
        --deadline-ms 800 --autoscale 1:6 --scheduler least_loaded

``--decode sim`` swaps the jax model for the deterministic simulated
backend on a virtual clock: a minute of traffic replays byte-identically
in milliseconds, which is how the serving benchmarks and chaos tests run.
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.configs import ARCH_IDS, get_smoke_config
from repro.engine.scheduler import SCHEDULERS, make_scheduler
from repro.launch.xla_flags import apply_xla_flags
from repro.serve import (ReplicaAutoscaler, Request, SLOAdmissionPolicy,
                         WrathServeDriver)


def main() -> None:
    # tuned compiler flags (repro.launch.xla_flags) must be in the
    # environment before the jax backend initializes — importing jax
    # above does not initialize it, the first computation does
    apply_xla_flags("serve")
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b",
                    help=f"one of {', '.join(a.replace('_', '-') for a in ARCH_IDS)}")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=6)
    ap.add_argument("--replicas", type=int, default=3)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--scheduler", default=None, choices=sorted(SCHEDULERS),
                    help="replica-selection policy (default round_robin)")
    ap.add_argument("--kill", default=None,
                    help="replica:step — kill a replica mid-decode")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", action="store_true")
    # -- continuous plane ------------------------------------------------
    ap.add_argument("--continuous", action="store_true",
                    help="continuous batching (queue -> admission -> slots)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request SLO; enables deadline-aware admission")
    ap.add_argument("--arrival-rate", type=float, default=None,
                    help="mean request arrivals per second (default: all "
                         "requests arrive at t=0); implies --continuous")
    ap.add_argument("--autoscale", default=None, metavar="MIN:MAX",
                    help="enable the replica autoscaler, e.g. 1:6; "
                         "implies --continuous")
    ap.add_argument("--decode", default="jax", choices=("jax", "sim"),
                    help="decode backend; 'sim' runs the modeled-cost "
                         "backend on a virtual clock (deterministic)")
    args = ap.parse_args()
    continuous = (args.continuous or args.arrival_rate is not None
                  or args.autoscale is not None)

    cfg = get_smoke_config(args.arch)
    clock = None
    if args.decode == "sim":
        from repro.sim import VirtualClock
        clock = VirtualClock()
    policy = None
    if args.autoscale:
        lo, _, hi = args.autoscale.partition(":")
        from repro.engine.policies import WrathPolicy
        policy = [WrathPolicy(),
                  ReplicaAutoscaler(min_replicas=int(lo or 1),
                                    max_replicas=int(hi or 6))]
    driver = WrathServeDriver(
        cfg, n_replicas=args.replicas, max_batch=args.max_batch,
        seed=args.seed, clock=clock, decode=args.decode, policy=policy,
        scheduler=make_scheduler(args.scheduler) if args.scheduler else None,
        admission=SLOAdmissionPolicy() if args.deadline_ms else None)
    rng = np.random.default_rng(args.seed)
    deadline_s = args.deadline_ms / 1e3 if args.deadline_ms else None
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=args.prompt_len).tolist(),
                    max_new_tokens=args.new_tokens,
                    deadline_s=deadline_s)
            for i in range(args.requests)]
    kill = None
    if args.kill:
        name, _, step = args.kill.partition(":")
        kill = (name, int(step or 5))

    if continuous:
        arrivals = None
        if args.arrival_rate:
            gaps = rng.exponential(1.0 / args.arrival_rate,
                                   size=args.requests)
            arrivals = np.cumsum(gaps).tolist()
        faults = None
        if kill:
            # in the continuous plane the kill is time-based: fire it when
            # roughly that many decode steps have elapsed at nominal cost
            faults = [(0.02 * kill[1], "kill", kill[0])]
        rep = driver.serve_continuous(reqs, arrivals=arrivals, faults=faults)
        driver.shutdown()
    else:
        rep = driver.serve(reqs, kill_replica_at=kill)

    if args.json:
        print(json.dumps({
            "arch": cfg.name, "mode": "continuous" if continuous else "static",
            "completed": rep.completed, "failed": rep.failed,
            "rejected": rep.rejected, "shed": rep.shed,
            "tokens": rep.tokens_generated, "tokens_per_s": rep.tokens_per_s,
            "requests_per_s": rep.requests_per_s,
            "p50_s": rep.p50_s, "p99_s": rep.p99_s,
            "denylisted": rep.denylisted, "recoveries": rep.recoveries,
            "autoscaled_up": rep.autoscaled_up,
            "autoscaled_down": rep.autoscaled_down,
            "replicas_final": rep.replicas_final,
        }, indent=1))
        return
    print(f"{cfg.name}: {rep.completed}/{len(reqs)} requests, "
          f"{rep.tokens_generated} tokens ({rep.tokens_per_s:.1f} tok/s)")
    if continuous:
        print(f"  rps={rep.requests_per_s:.2f} p50={rep.p50_s*1e3:.1f}ms "
              f"p99={rep.p99_s*1e3:.1f}ms rejected={rep.rejected} "
              f"shed={rep.shed} replicas={rep.replicas_final} "
              f"(+{rep.autoscaled_up}/-{rep.autoscaled_down})")
    if rep.denylisted:
        print(f"denylisted replicas: {rep.denylisted}")
    for r in rep.recoveries:
        where = f"step {r['step']}" if "step" in r else f"request {r['rid']}"
        print(f"  recovery: {r['replica']} at {where} -> {r['action']}")


if __name__ == "__main__":
    main()
