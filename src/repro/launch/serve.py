"""WRATH-supervised serving launcher.

    python -m repro.launch.serve --arch olmoe-1b-7b --requests 16 \
        --replicas 3 --kill replica0:5
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.configs import ARCH_IDS, get_smoke_config
from repro.launch.xla_flags import apply_xla_flags
from repro.serve import Request, WrathServeDriver


def main() -> None:
    # tuned compiler flags (repro.launch.xla_flags) must be in the
    # environment before the jax backend initializes — importing jax
    # above does not initialize it, the first computation does
    apply_xla_flags("serve")
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b",
                    help=f"one of {', '.join(a.replace('_', '-') for a in ARCH_IDS)}")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=6)
    ap.add_argument("--replicas", type=int, default=3)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--kill", default=None,
                    help="replica:step — kill a replica mid-decode")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    driver = WrathServeDriver(cfg, n_replicas=args.replicas,
                              max_batch=args.max_batch, seed=args.seed)
    rng = np.random.default_rng(args.seed)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=args.prompt_len).tolist(),
                    max_new_tokens=args.new_tokens)
            for i in range(args.requests)]
    kill = None
    if args.kill:
        name, _, step = args.kill.partition(":")
        kill = (name, int(step or 5))
    rep = driver.serve(reqs, kill_replica_at=kill)

    if args.json:
        print(json.dumps({
            "arch": cfg.name, "completed": rep.completed, "failed": rep.failed,
            "tokens": rep.tokens_generated, "tokens_per_s": rep.tokens_per_s,
            "denylisted": rep.denylisted, "recoveries": rep.recoveries,
        }, indent=1))
        return
    print(f"{cfg.name}: {rep.completed}/{len(reqs)} requests, "
          f"{rep.tokens_generated} tokens ({rep.tokens_per_s:.1f} tok/s)")
    if rep.denylisted:
        print(f"denylisted replicas: {rep.denylisted}")
    for r in rep.recoveries:
        print(f"  recovery: {r['replica']} at step {r['step']} -> {r['action']}")


if __name__ == "__main__":
    main()
