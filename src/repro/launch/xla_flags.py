"""Centralized tuned XLA compiler-flag sets per platform and workload.

The saxml ``llm_xla_flags.py`` idiom: instead of every launcher inlining
its own ``os.environ["XLA_FLAGS"]`` assignment, the tuned flag sets live
in one table keyed by *profile* (train / serve / dryrun) and the
launchers call :func:`apply_xla_flags` before jax initializes its
backend.

Rules:

* This module must never import jax — flags only take effect if they are
  in the environment before the backend initializes, so the callers
  import this first (``dryrun.py`` calls it before ``import jax``).
* Platform-specific flags are applied only on that platform: XLA aborts
  on unrecognized flags, so TPU collective-overlap flags must not reach
  a CPU-backed process.  Detection is environment-based (``JAX_PLATFORMS``
  / libtpu markers) because importing jax to ask is self-defeating.
* User-provided ``XLA_FLAGS`` win: anything already in the variable is
  appended *after* the profile set (XLA's flag parser is last-wins), and
  a flag the user already set is dropped from the profile side.
"""
from __future__ import annotations

import os

__all__ = ["FLAG_SETS", "detect_platform", "flag_string", "merged_flags",
           "apply_xla_flags"]

#: async-collective overlap set shared by the TPU profiles (the saxml
#: serving/training defaults): fuse all-gathers/all-reduces with the
#: compute they overlap, and let data-parallel ops of different sizes
#: share a fusion.
_TPU_OVERLAP = {
    "--xla_tpu_enable_async_collective_fusion": "true",
    "--xla_tpu_enable_async_collective_fusion_fuse_all_gather": "true",
    "--xla_tpu_enable_async_collective_fusion_multiple_steps": "true",
    "--xla_tpu_overlap_compute_collective_tc": "true",
    "--xla_enable_async_all_gather": "true",
    "--xla_tpu_data_parallel_opt_different_sized_ops": "true",
}

#: profile -> platform -> {flag: value}.  Flags are spelled with their
#: leading dashes so the table reads like the command line it becomes.
FLAG_SETS: dict[str, dict[str, dict[str, str]]] = {
    # training: collective overlap + latency-hiding scheduler
    "train": {
        "tpu": {
            **_TPU_OVERLAP,
            "--xla_latency_hiding_scheduler_rerun": "1",
        },
        "cpu": {},
    },
    # serving: overlap plus the unsafe-rng speedup saxml ships for
    # decode (sampling tolerates the relaxed SPMD rng contract)
    "serve": {
        "tpu": {
            **_TPU_OVERLAP,
            "--xla_tpu_spmd_rng_bit_generator_unsafe": "true",
        },
        "cpu": {},
    },
    # compile-only dry-run: fake a 512-chip host topology; jax locks the
    # device count on first initialization, so this must be applied
    # before any jax import in the process
    "dryrun": {
        "cpu": {"--xla_force_host_platform_device_count": "512"},
        "tpu": {},
    },
}


def detect_platform() -> str:
    """Best-effort platform without importing jax: explicit
    ``JAX_PLATFORMS`` wins, then TPU environment markers, else cpu."""
    plats = os.environ.get("JAX_PLATFORMS", "")
    if plats:
        return plats.split(",")[0].strip().lower() or "cpu"
    if os.environ.get("TPU_NAME") or os.path.exists("/dev/accel0"):
        return "tpu"
    return "cpu"


def flag_string(profile: str, *, platform: str | None = None,
                extra: dict[str, str] | None = None) -> str:
    """The ``XLA_FLAGS`` value for ``profile`` on ``platform``."""
    platform = platform or detect_platform()
    try:
        flags = dict(FLAG_SETS[profile].get(platform, {}))
    except KeyError:
        raise ValueError(
            f"unknown XLA flag profile {profile!r}; "
            f"one of {sorted(FLAG_SETS)}") from None
    if extra:
        flags.update(extra)
    return " ".join(f"{k}={v}" for k, v in flags.items())


def merged_flags(profile: str, existing: str = "", *,
                 platform: str | None = None,
                 extra: dict[str, str] | None = None) -> str:
    """Profile flags merged with an ``existing`` XLA_FLAGS value.

    Existing flags are appended after the profile set (last-wins in
    XLA's parser) and suppress the profile's value for the same flag —
    a user override always survives.
    """
    old = existing.split()
    old_names = {tok.split("=", 1)[0] for tok in old}
    ours = [tok for tok in flag_string(profile, platform=platform,
                                       extra=extra).split()
            if tok.split("=", 1)[0] not in old_names]
    return " ".join(ours + old).strip()


def apply_xla_flags(profile: str, *, platform: str | None = None,
                    extra: dict[str, str] | None = None,
                    env: os._Environ | dict = os.environ) -> str:
    """Set ``XLA_FLAGS`` for ``profile``, preserving user-set flags.

    Returns the final string; call before jax's backend initializes.
    """
    merged = merged_flags(profile, env.get("XLA_FLAGS", ""),
                          platform=platform, extra=extra)
    if merged:
        env["XLA_FLAGS"] = merged
    return merged
