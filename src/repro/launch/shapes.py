"""Assigned input-shape presets and ``input_specs`` (ShapeDtypeStruct
stand-ins, weak-type-correct, shardable, zero allocation).

LM transformer shapes are seq_len × global_batch.  ``decode_*`` /
``long_*`` lower ``serve_step`` (one new token with a seq_len cache);
``prefill_*`` lowers ``prefill_step``; ``train_*`` lowers ``train_step``.
``long_500k`` only applies to sub-quadratic archs (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import cache_defs, param_defs
from repro.models.config import ModelConfig
from repro.models.spec import abstract


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def shape_applicable(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    """long_500k needs a sub-quadratic architecture (skip noted in DESIGN.md)."""
    sp = SHAPES[shape]
    if sp.name == "long_500k" and not cfg.subquadratic:
        return False, (f"{cfg.name} is pure full-attention; 500k context is "
                       f"architecturally unsupported (quadratic prefill)")
    return True, ""


def _token_spec(b: int, s: int):
    return jax.ShapeDtypeStruct((b, s), jnp.int32)


def _embed_spec(b: int, s: int, d: int):
    return jax.ShapeDtypeStruct((b, s, d), jnp.bfloat16)


def batch_specs(cfg: ModelConfig, shape: str) -> dict:
    """ShapeDtypeStructs for the *batch* argument of the lowered step."""
    sp = SHAPES[shape]
    b, s = sp.global_batch, sp.seq_len
    if sp.kind in ("train", "prefill"):
        out: dict = {}
        if cfg.encoder_layers:
            out["enc_embeds"] = _embed_spec(b, s, cfg.d_model)
            out["inputs"] = _token_spec(b, s)
        elif cfg.input_kind == "embeds":
            out["embeds"] = _embed_spec(b, s, cfg.d_model)
        else:
            out["inputs"] = _token_spec(b, s)
        if sp.kind == "train":
            out["targets"] = _token_spec(b, s)
        return out
    # decode: one new token
    return {"inputs": _token_spec(b, 1)}


def cache_specs(cfg: ModelConfig, shape: str) -> dict:
    sp = SHAPES[shape]
    return abstract(cache_defs(cfg, sp.global_batch, sp.seq_len))


def param_specs(cfg: ModelConfig) -> dict:
    return abstract(param_defs(cfg))


def batch_axes(cfg: ModelConfig, shape: str) -> dict:
    """Logical axes for each batch leaf (drives input shardings)."""
    sp = SHAPES[shape]
    axes: dict = {}
    if sp.kind in ("train", "prefill"):
        if cfg.encoder_layers:
            axes["enc_embeds"] = ("batch", "seq", "d_model")
            axes["inputs"] = ("batch", "seq")
        elif cfg.input_kind == "embeds":
            axes["embeds"] = ("batch", "seq", "d_model")
        else:
            axes["inputs"] = ("batch", "seq")
        if sp.kind == "train":
            axes["targets"] = ("batch", "seq")
        return axes
    return {"inputs": ("batch", "seq")}
