"""Failure-injection engines (paper §VII-A, Table III).

The paper modifies TaPS with a "Parsl-fail engine" that replaces a
specified fraction of an application's tasks with *failure tasks*.  We do
the same at the :class:`~repro.engine.task.TaskDef` level: an injector
deterministically (seeded) selects task invocations and rewrites them into
one of the Table III failure behaviours.

Two flavours exist, matching how the corresponding real failures arise:

* **function-replacement** failures always fail, wherever they run
  (``zero_division``, ``exception``, ``worker_killed``, ``dependency``) —
  these are the "destined to fail" tasks of the time-to-failure experiment
  (Fig 4);
* **spec-modification** failures rewrite the task's *resource spec* so the
  task fails on inadequate nodes but succeeds on adequate ones
  (``memory`` → needs 200 GB, ``import`` → needs a package, ``ulimit`` →
  opens 1M files) — these are the *resolvable* failures of §VII-C that
  WRATH's hierarchical retry can fix by re-placement.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Any

from repro.engine.cluster import kill_current_worker
from repro.engine.task import TaskDef


def _fail_zero_division(*a: Any, **k: Any) -> Any:
    x = 0
    return 1 / x  # ZeroDivisionError — application-layer logic error


def _fail_exception(*a: Any, **k: Any) -> Any:
    raise RuntimeError("injected failure: runtime exception")


def _fail_worker_killed(*a: Any, **k: Any) -> Any:
    kill_current_worker("injected failure: worker killed")


FN_REPLACEMENT: dict[str, Any] = {
    "zero_division": _fail_zero_division,
    "exception": _fail_exception,
    "worker_killed": _fail_worker_killed,
    # 'dependency' replaces a *parent* with an exception: same fn, but the
    # interesting measurement is on the children that dep-fail.
    "dependency": _fail_exception,
}

# spec-modification failures: (spec field, injected value)
SPEC_MODIFICATION: dict[str, dict[str, Any]] = {
    "memory": {"memory_gb": 200.0},           # > 192 GB small nodes (§VII-C)
    "import": {"packages": ("wrathpkg",)},    # missing on default nodes
    "ulimit": {"open_files": 1_000_000},      # "open 1M files" (Table III)
}

FAILURE_TYPES = tuple(FN_REPLACEMENT) + tuple(SPEC_MODIFICATION)


@dataclass
class FailureInjector:
    """Deterministically replaces a fraction of task invocations.

    ``rate`` is the fraction of invocations selected (paper: 0.1–0.3).
    Selection is a stable hash of ``(seed, app_tag, index)`` so a retried
    task keeps its injected behaviour — "tasks destined to fail" stay
    destined to fail, as in the paper's engine.
    """

    failure_type: str
    rate: float = 0.3
    seed: int = 0
    app_tag: str = ""
    only_parents: bool = False   # for 'dependency': restrict to parent tasks
    injected: list[str] = field(default_factory=list)

    def __post_init__(self) -> None:
        if self.failure_type not in FAILURE_TYPES:
            raise ValueError(
                f"unknown failure type {self.failure_type!r}; "
                f"expected one of {FAILURE_TYPES}")

    # ------------------------------------------------------------------ #
    def _selected(self, index: int) -> bool:
        h = hashlib.sha256(
            f"{self.seed}:{self.app_tag}:{index}".encode()).digest()
        return (int.from_bytes(h[:8], "big") / 2**64) < self.rate

    def maybe(self, td: TaskDef, index: int, *, is_parent: bool = True) -> TaskDef:
        """Return ``td`` unchanged, or its injected-failure variant."""
        if self.only_parents and not is_parent:
            return td
        if not self._selected(index):
            return td
        self.injected.append(f"{td.name}[{index}]")
        if self.failure_type in FN_REPLACEMENT:
            fail_fn = FN_REPLACEMENT[self.failure_type]
            return TaskDef(fail_fn, td.name, td.resources, td.max_retries)
        overrides = SPEC_MODIFICATION[self.failure_type]
        return td.options(**overrides)

    @property
    def count(self) -> int:
        return len(self.injected)


class NoInjector:
    """Null injector: the unmodified application."""

    failure_type = "none"
    rate = 0.0
    injected: list[str] = []
    count = 0

    def maybe(self, td: TaskDef, index: int, *, is_parent: bool = True) -> TaskDef:
        return td
