from repro.injection.engines import (
    FAILURE_TYPES,
    FailureInjector,
    NoInjector,
)

__all__ = ["FailureInjector", "NoInjector", "FAILURE_TYPES"]
