"""Hierarchical monitoring system (paper §IV) — the *streaming* half of the
proactive resilience plane.

Components:

* :class:`MonitoringDatabase` — the centralized monitoring database that
  consolidates task events, failure reports, heartbeats, resource profiles
  and placement history, and answers the queries the resilience module
  needs.  Since the proactive refactor the database no longer hoards raw
  append-only lists: observations stream into bounded ring buffers and into
  *online* per-task-template profiles (:class:`StreamingStats`, Welford
  mean/variance plus a bounded-sample p95) keyed overall, by node and by
  pool, and into per-node health trends (:class:`NodeHealth`: heartbeat
  jitter, memory-growth slope).  The query side — ``expected_duration``,
  ``node_health``, ``duration_stats`` — is what the
  :class:`~repro.core.proactive.ProactiveSentinel`, the straggler watcher,
  the training supervisor's shard sizing and the serve driver's replica
  health gate consume.
* :class:`Radio` — the communication radio.  :class:`InProcRadio` delivers
  messages in-process; :class:`TCPRadio`/:class:`TCPRadioServer` implement
  the paper's TCP transport (JSON lines over a socket) and are exercised by
  tests on localhost.  Both present the same ``send`` interface, so agents
  are transport-agnostic, mirroring the paper's modular database backends
  (local DB / cloud DB / Octopus event fabric).
* :class:`TaskMonitoringAgent` — per-node agent sampling resource usage of
  the running workers (psutil-based, as §VI-B) plus simulated node state.
* :class:`SystemMonitoringAgent` — heartbeat emitter for any component.

Memory bounds: every store (task events per task, system events, failure
reports, resource profiles per node, heartbeat-interval samples) is a ring
capped at ``retention`` entries; streaming profiles are O(1) per key.
"""
from __future__ import annotations

import json
import math
import socket
import socketserver
import threading
from collections import defaultdict, deque
from dataclasses import asdict, dataclass, field, is_dataclass
from typing import Any

try:
    import psutil  # noqa: F401
    _HAS_PSUTIL = True
except Exception:  # pragma: no cover
    _HAS_PSUTIL = False

from repro.core.failures import FailureReport
from repro.engine.events import REAL_CLOCK


# --------------------------------------------------------------------------
# Radio transports
# --------------------------------------------------------------------------


class Radio:
    def send(self, message: dict[str, Any]) -> None:  # pragma: no cover
        raise NotImplementedError


class InProcRadio(Radio):
    """Direct-dispatch radio (default for the simulated cluster)."""

    def __init__(self, db: "MonitoringDatabase"):
        self.db = db

    def send(self, message: dict[str, Any]) -> None:
        self.db.ingest(message)


class TCPRadioServer:
    """JSON-lines-over-TCP sink feeding a MonitoringDatabase (paper §VI-B)."""

    def __init__(self, db: "MonitoringDatabase", host: str = "127.0.0.1", port: int = 0):
        self.db = db
        outer = self

        class Handler(socketserver.StreamRequestHandler):
            def handle(self) -> None:
                for line in self.rfile:
                    line = line.strip()
                    if not line:
                        continue
                    try:
                        outer.db.ingest(json.loads(line.decode()))
                    except Exception:  # noqa: BLE001 - malformed msg dropped
                        pass

        self._server = socketserver.ThreadingTCPServer((host, port), Handler)
        self._server.daemon_threads = True
        self.address = self._server.server_address
        self._thread = threading.Thread(target=self._server.serve_forever,
                                        daemon=True, name="radio-server")

    def start(self) -> "TCPRadioServer":
        self._thread.start()
        return self

    def stop(self) -> None:
        self._server.shutdown()
        self._server.server_close()


class TCPRadio(Radio):
    def __init__(self, address: tuple[str, int]):
        self.address = address
        self._sock: socket.socket | None = None
        self._lock = threading.Lock()

    def _connect(self) -> socket.socket:
        if self._sock is None:
            self._sock = socket.create_connection(self.address, timeout=2.0)
        return self._sock

    def send(self, message: dict[str, Any]) -> None:
        data = (json.dumps(message) + "\n").encode()
        with self._lock:
            try:
                self._connect().sendall(data)
            except OSError:
                self._sock = None
                self._connect().sendall(data)

    def close(self) -> None:
        with self._lock:
            if self._sock is not None:
                self._sock.close()
                self._sock = None


# --------------------------------------------------------------------------
# Streaming statistics
# --------------------------------------------------------------------------


class StreamingStats:
    """Online mean/variance (Welford) plus a bounded-sample p95 estimate.

    O(1) per observation, O(``sample_cap``) memory: the exact quantile of
    the last ``sample_cap`` observations stands in for the stream p95 —
    recency is a feature here (node speed and task mix drift).
    """

    __slots__ = ("n", "_mean", "_m2", "_min", "_max", "_samples", "_sorted")

    def __init__(self, sample_cap: int = 64) -> None:
        self.n = 0
        self._mean = 0.0
        self._m2 = 0.0
        self._min = math.inf
        self._max = -math.inf
        self._samples: deque[float] = deque(maxlen=sample_cap)
        # sorted view of _samples, rebuilt lazily — quantile() is on the
        # straggler watcher's periodic path, so it must not re-sort unless
        # a new observation arrived
        self._sorted: list[float] | None = None

    def push(self, x: float) -> None:
        x = float(x)
        self.n += 1
        d = x - self._mean
        self._mean += d / self.n
        self._m2 += d * (x - self._mean)
        self._min = min(self._min, x)
        self._max = max(self._max, x)
        self._samples.append(x)
        self._sorted = None

    @property
    def mean(self) -> float:
        return self._mean if self.n else 0.0

    @property
    def var(self) -> float:
        return self._m2 / (self.n - 1) if self.n > 1 else 0.0

    @property
    def std(self) -> float:
        return math.sqrt(self.var)

    @property
    def min(self) -> float:
        return self._min if self.n else 0.0

    @property
    def max(self) -> float:
        return self._max if self.n else 0.0

    def quantile(self, q: float) -> float:
        """Quantile over the retained sample window (0 if empty)."""
        if not self._samples:
            return 0.0
        xs = self._sorted
        if xs is None:
            xs = self._sorted = sorted(self._samples)
        idx = min(len(xs) - 1, max(0, int(math.ceil(q * len(xs))) - 1))
        return xs[idx]

    @property
    def p95(self) -> float:
        return self.quantile(0.95)

    def snapshot(self) -> dict[str, float]:
        return {"n": self.n, "mean": self.mean, "std": self.std,
                "min": self.min, "max": self.max, "p95": self.p95}

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (f"<StreamingStats n={self.n} mean={self.mean:.4g} "
                f"std={self.std:.4g} p95={self.p95:.4g}>")


@dataclass
class TemplateProfile:
    """Streaming per-task-template profile: duration and memory."""

    duration: StreamingStats = field(default_factory=StreamingStats)
    memory_gb: StreamingStats = field(default_factory=StreamingStats)


@dataclass
class NodeHealth:
    """Point-in-time health trend of one node (query-side snapshot)."""

    node: str
    last_heartbeat: float = 0.0          # wall-clock ts of last beat (0 = never)
    heartbeat_mean_interval: float = 0.0
    heartbeat_jitter: float = 0.0        # std of inter-heartbeat intervals
    heartbeat_samples: int = 0
    mem_in_use_gb: float = 0.0
    mem_capacity_gb: float = 0.0
    mem_slope_gb_s: float = 0.0          # least-squares slope of recent samples
    profile_samples: int = 0

    def silent_for(self, now: float | None = None) -> float:
        if not self.last_heartbeat:
            return 0.0
        return max(0.0, (now if now is not None else REAL_CLOCK.time()) - self.last_heartbeat)

    def projected_mem_gb(self, horizon_s: float) -> float:
        """Memory in use projected ``horizon_s`` ahead along the trend."""
        return self.mem_in_use_gb + max(self.mem_slope_gb_s, 0.0) * horizon_s

    def trending_oom(self, horizon_s: float) -> bool:
        return (self.mem_capacity_gb > 0 and self.profile_samples >= 3
                and self.mem_slope_gb_s > 0
                and self.projected_mem_gb(horizon_s) > self.mem_capacity_gb)


# --------------------------------------------------------------------------
# Centralized monitoring database
# --------------------------------------------------------------------------


@dataclass
class PlacementStats:
    successes: int = 0
    failures: int = 0
    # accumulated wall time of *successful* attempts, for the
    # HistoryAwareScheduler's "historically fast node" query
    duration_sum: float = 0.0
    duration_n: int = 0

    @property
    def total(self) -> int:
        return self.successes + self.failures

    @property
    def success_rate(self) -> float:
        return self.successes / self.total if self.total else 0.0

    @property
    def avg_duration(self) -> float:
        """Mean successful-attempt duration (0.0 = no timed observations)."""
        return self.duration_sum / self.duration_n if self.duration_n else 0.0


class MonitoringDatabase:
    """Thread-safe centralized store + query API (paper §IV).

    ``retention`` bounds every ring store (events, failures, per-node
    profile samples); streaming profiles are O(1) per (template, node/pool).
    """

    def __init__(self, retention: int = 512, *, clock: Any = None,
                 keep_event_log: bool = False) -> None:
        if retention < 1:
            raise ValueError(f"retention must be >= 1, got {retention}")
        self.retention = retention
        # injected time source (repro.engine.events.Clock); every stored
        # timestamp goes through it so a virtual-clock engine produces
        # virtual-time (and therefore deterministic) monitoring data
        self.clock = clock
        self._time = clock.time if clock is not None else REAL_CLOCK.time
        # optional global ordered log of every task/system event — the
        # deterministic-simulation plane's *event trace*.  Unbounded, so
        # only enabled for finite scenario runs.
        self.event_log: list[dict[str, Any]] | None = ([] if keep_event_log
                                                       else None)
        self._lock = threading.RLock()
        self.task_events: dict[str, deque[dict[str, Any]]] = defaultdict(
            lambda: deque(maxlen=retention))
        self.system_events: deque[dict[str, Any]] = deque(maxlen=retention)
        self.failures: deque[FailureReport] = deque(maxlen=retention)
        self._heartbeats: dict[str, float] = {}
        self._hb_intervals: dict[str, StreamingStats] = defaultdict(
            lambda: StreamingStats(sample_cap=32))
        self.resource_profiles: dict[str, deque[dict[str, float]]] = defaultdict(
            lambda: deque(maxlen=retention))
        # streaming per-template profiles: overall + per-node + per-pool
        self._profiles: dict[str, TemplateProfile] = defaultdict(TemplateProfile)
        self._node_profiles: dict[tuple[str, str], TemplateProfile] = defaultdict(
            TemplateProfile)
        self._pool_profiles: dict[tuple[str, str], TemplateProfile] = defaultdict(
            TemplateProfile)
        # placement history keyed by task *name* (template), then node/pool
        self._node_history: dict[str, dict[str, PlacementStats]] = defaultdict(
            lambda: defaultdict(PlacementStats))
        self._pool_history: dict[str, dict[str, PlacementStats]] = defaultdict(
            lambda: defaultdict(PlacementStats))
        # named scalar gauges (serving-plane queue depth, slot occupancy):
        # streaming stats for the long view + a timestamped ring of recent
        # samples for trend queries ("has the queue grown for K ticks?")
        self._gauges: dict[str, StreamingStats] = defaultdict(
            lambda: StreamingStats(sample_cap=64))
        self._gauge_rings: dict[str, deque[tuple[float, float]]] = defaultdict(
            lambda: deque(maxlen=retention))

    # -- ingest (radio entry point) ----------------------------------------
    def ingest(self, message: dict[str, Any]) -> None:
        kind = message.get("kind")
        if kind == "heartbeat":
            self.heartbeat(message["node"], message.get("time", self._time()))
        elif kind == "task_event":
            self.record_task_event(message["task_id"], message["event"],
                                   **message.get("data", {}))
        elif kind == "resource_profile":
            self.record_resource_profile(message["node"], message.get("profile", {}))
        elif kind == "system_event":
            self.record_system_event(message["event"], **message.get("data", {}))
        elif kind == "placement":
            self.record_task_placement(message["task_name"], message["node"],
                                       message["pool"], ok=message["ok"],
                                       duration=message.get("duration"),
                                       memory_gb=message.get("memory_gb"))
        elif kind == "failure":
            # full-fidelity round trip: everything serialize_report ships is
            # preserved so a TCP-radio report equals an in-proc one
            d = message.get("report", {})
            self.report_failure(FailureReport(
                task_id=d.get("task_id"), exception=None,
                exception_type=d.get("exception_type", ""),
                message=d.get("message", ""), node=d.get("node"),
                pool=d.get("pool"), worker=d.get("worker"),
                resource_profile=dict(d.get("resource_profile") or {}),
                requirements=dict(d.get("requirements") or {}),
                retry_count=int(d.get("retry_count", 0)),
                timestamp=float(d.get("timestamp", 0.0)),
                log_tail=list(d.get("log_tail") or [])))

    # -- writers -----------------------------------------------------------
    def heartbeat(self, node: str, ts: float) -> None:
        with self._lock:
            last = self._heartbeats.get(node)
            if last is not None and ts > last:
                self._hb_intervals[node].push(ts - last)
            self._heartbeats[node] = ts

    def record_task_event(self, task_id: str, event: str, **data: Any) -> None:
        with self._lock:
            entry = {"event": event, "time": self._time(), **data}
            self.task_events[task_id].append(entry)
            if self.event_log is not None:
                self.event_log.append({"scope": "task", "task_id": task_id,
                                       **entry})

    def record_system_event(self, event: str, **data: Any) -> None:
        with self._lock:
            entry = {"event": event, "time": self._time(), **data}
            self.system_events.append(entry)
            if self.event_log is not None:
                self.event_log.append({"scope": "system", **entry})

    def event_sequence(self) -> list[tuple[str, str]]:
        """Ordered ``(scope_class, event)`` pairs from the event log.

        The raw material of trace n-gram coverage
        (:mod:`repro.sim.coverage`): task scopes collapse to the literal
        ``"task"`` — event *kinds* and their order define an engine
        state, task identities are just scenario size.  Requires
        ``keep_event_log=True``.
        """
        if self.event_log is None:
            raise ValueError("monitor was not built with keep_event_log=True")
        with self._lock:
            return [("system" if e["scope"] == "system" else "task",
                     e["event"]) for e in self.event_log]

    def record_resource_profile(self, node: str, profile: dict[str, float]) -> None:
        with self._lock:
            self.resource_profiles[node].append({"time": self._time(), **profile})

    def record_task_placement(self, task_name: str, node: str, pool: str | None,
                              *, ok: bool, duration: float | None = None,
                              memory_gb: float | None = None) -> None:
        with self._lock:
            ns = self._node_history[task_name][node]
            ps = self._pool_history[task_name][pool or "?"]
            if ok:
                ns.successes += 1
                ps.successes += 1
                if duration is not None and duration > 0:
                    for s in (ns, ps):
                        s.duration_sum += duration
                        s.duration_n += 1
                    for prof in (self._profiles[task_name],
                                 self._node_profiles[(task_name, node)],
                                 self._pool_profiles[(task_name, pool or "?")]):
                        prof.duration.push(duration)
                if memory_gb is not None and memory_gb > 0:
                    for prof in (self._profiles[task_name],
                                 self._node_profiles[(task_name, node)],
                                 self._pool_profiles[(task_name, pool or "?")]):
                        prof.memory_gb.push(memory_gb)
            else:
                ns.failures += 1
                ps.failures += 1

    def report_failure(self, report: FailureReport) -> None:
        with self._lock:
            self.failures.append(report)

    def record_gauge(self, name: str, value: float) -> None:
        """Observe one sample of a named scalar gauge (queue depth, slot
        occupancy, live replicas).  O(1); ring-bounded like every store."""
        with self._lock:
            value = float(value)
            self._gauges[name].push(value)
            self._gauge_rings[name].append((self._time(), value))

    # -- queries -------------------------------------------------------------
    def last_heartbeats(self) -> dict[str, float]:
        with self._lock:
            return dict(self._heartbeats)

    def node_history(self, task_name: str) -> dict[str, PlacementStats]:
        with self._lock:
            return {k: PlacementStats(v.successes, v.failures,
                                      v.duration_sum, v.duration_n)
                    for k, v in self._node_history[task_name].items()}

    def pool_history(self, task_name: str) -> dict[str, PlacementStats]:
        with self._lock:
            return {k: PlacementStats(v.successes, v.failures,
                                      v.duration_sum, v.duration_n)
                    for k, v in self._pool_history[task_name].items()}

    def best_historical_node(self, task_name: str,
                             exclude: set[str] = frozenset()) -> str | None:
        """Retry rung 3: where has this task succeeded most often?"""
        hist = self.node_history(task_name)
        best, best_score = None, 0
        for node, stats in hist.items():
            if node in exclude:
                continue
            if stats.successes > best_score:
                best, best_score = node, stats.successes
        return best

    def latest_profile(self, node: str) -> dict[str, float] | None:
        with self._lock:
            rows = self.resource_profiles.get(node)
            return dict(rows[-1]) if rows else None

    def failures_for(self, task_id: str) -> list[FailureReport]:
        with self._lock:
            return [f for f in self.failures if f.task_id == task_id]

    def events_for(self, task_id: str) -> list[dict[str, Any]]:
        with self._lock:
            return list(self.task_events[task_id])

    # -- streaming-profile queries (proactive plane) -----------------------
    def duration_stats(self, task_name: str, *, node: str | None = None,
                       pool: str | None = None) -> StreamingStats | None:
        """Streaming duration profile of a task template (None = no data).

        ``node``/``pool`` narrow the profile to one placement key; at most
        one of the two may be given.
        """
        with self._lock:
            if node is not None:
                prof = self._node_profiles.get((task_name, node))
            elif pool is not None:
                prof = self._pool_profiles.get((task_name, pool))
            else:
                prof = self._profiles.get(task_name)
            return prof.duration if prof is not None and prof.duration.n else None

    def memory_stats(self, task_name: str, *, node: str | None = None,
                     pool: str | None = None) -> StreamingStats | None:
        with self._lock:
            if node is not None:
                prof = self._node_profiles.get((task_name, node))
            elif pool is not None:
                prof = self._pool_profiles.get((task_name, pool))
            else:
                prof = self._profiles.get(task_name)
            return prof.memory_gb if prof is not None and prof.memory_gb.n else None

    def expected_duration(self, task_name: str, *, node: str | None = None,
                          min_samples: int = 3) -> float:
        """Profile-derived duration bound for straggler detection.

        Returns the p95 of observed successful durations (0.0 when fewer
        than ``min_samples`` observations exist) — the dynamic replacement
        for the static user-supplied ``est_duration_s``.
        """
        stats = self.duration_stats(task_name, node=node)
        if stats is None or stats.n < min_samples:
            return 0.0
        return stats.p95

    def gauge_stats(self, name: str) -> StreamingStats | None:
        """Streaming profile of a named gauge (None = never observed)."""
        with self._lock:
            stats = self._gauges.get(name)
            return stats if stats is not None and stats.n else None

    def recent_gauges(self, name: str, k: int = 16) -> list[tuple[float, float]]:
        """Last ``k`` (timestamp, value) samples of a gauge, oldest first.

        The serving autoscaler's trend query: "has the queue depth stayed
        above threshold for the last K observations?" reads this instead
        of keeping private per-policy counters, so any policy (or a test)
        can audit the same evidence the scaling decision used.
        """
        with self._lock:
            ring = self._gauge_rings.get(name)
            if not ring:
                return []
            return list(ring)[-k:]

    def node_health(self, node: str) -> NodeHealth:
        """Heartbeat-trend + memory-trend snapshot for one node."""
        with self._lock:
            h = NodeHealth(node=node,
                           last_heartbeat=self._heartbeats.get(node, 0.0))
            hb = self._hb_intervals.get(node)
            if hb is not None and hb.n:
                h.heartbeat_mean_interval = hb.mean
                h.heartbeat_jitter = hb.std
                h.heartbeat_samples = hb.n
            rows = self.resource_profiles.get(node)
            if rows:
                recent = list(rows)[-32:]
                mem = [(r["time"], r.get("sim_mem_in_use_gb", 0.0))
                       for r in recent]
                h.mem_in_use_gb = mem[-1][1]
                h.mem_capacity_gb = recent[-1].get("sim_mem_capacity_gb", 0.0)
                h.profile_samples = len(mem)
                if len(mem) >= 3:
                    t0 = mem[0][0]
                    xs = [t - t0 for t, _ in mem]
                    ys = [m for _, m in mem]
                    n = len(xs)
                    mx = sum(xs) / n
                    my = sum(ys) / n
                    denom = sum((x - mx) ** 2 for x in xs)
                    if denom > 1e-12:
                        h.mem_slope_gb_s = sum(
                            (x - mx) * (y - my) for x, y in zip(xs, ys)) / denom
            return h

    def all_node_health(self) -> dict[str, NodeHealth]:
        with self._lock:
            nodes = set(self._heartbeats) | set(self.resource_profiles)
        return {n: self.node_health(n) for n in nodes}


# --------------------------------------------------------------------------
# Agents
# --------------------------------------------------------------------------


class SystemMonitoringAgent:
    """Heartbeat emitter for an arbitrary component (paper §IV)."""

    def __init__(self, component: str, radio: Radio, period: float = 0.05,
                 clock: Any = None):
        self.component = component
        self.radio = radio
        self.period = period
        # injected time source for heartbeat stamps (real clock by default)
        self.clock = clock if clock is not None else REAL_CLOCK
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name=f"sysmon-{component}")

    def start(self) -> "SystemMonitoringAgent":
        self._thread.start()
        return self

    def _loop(self) -> None:
        while not self._stop.is_set():
            self.radio.send({"kind": "heartbeat", "node": self.component,
                             "time": self.clock.time()})
            # Event.wait, not a raw sleep: stop() interrupts mid-period
            self._stop.wait(self.period)

    def stop(self) -> None:
        self._stop.set()


class TaskMonitoringAgent:
    """Per-node resource-profile sampler (psutil-based, paper §VI-B).

    Samples the hosting process's CPU/RSS via psutil (real measurements)
    and merges simulated node state (capacity, simulated in-use memory),
    shipping profiles over the radio.
    """

    def __init__(self, node: Any, radio: Radio, period: float = 0.1):
        self.node = node
        self.radio = radio
        self.period = period
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name=f"taskmon-{node.name}")
        self._proc = psutil.Process() if _HAS_PSUTIL else None

    def sample(self) -> dict[str, float]:
        prof: dict[str, float] = {
            "sim_mem_in_use_gb": float(self.node.mem_in_use_gb),
            "sim_mem_capacity_gb": float(self.node.memory_gb),
            "sim_healthy": float(self.node.healthy),
            "sim_queue_depth": float(self.node.task_queue.qsize()),
            "sim_alive_workers": float(sum(1 for w in self.node.workers if w.alive)),
        }
        if self._proc is not None:
            try:
                prof["proc_rss_gb"] = self._proc.memory_info().rss / 2**30
                prof["proc_cpu_pct"] = self._proc.cpu_percent(interval=None)
                prof["proc_open_files"] = float(len(self._proc.open_files()))
            except Exception:  # noqa: BLE001
                pass
        return prof

    def start(self) -> "TaskMonitoringAgent":
        self._thread.start()
        return self

    def _loop(self) -> None:
        while not self._stop.is_set():
            self.radio.send({"kind": "resource_profile", "node": self.node.name,
                             "profile": self.sample()})
            self._stop.wait(self.period)

    def stop(self) -> None:
        self._stop.set()


def serialize_report(report: FailureReport) -> dict[str, Any]:
    """JSON-safe rendering of a FailureReport for radio shipping."""
    d = {k: v for k, v in asdict(report).items() if k != "exception"}
    if is_dataclass(d.get("requirements")):
        d["requirements"] = asdict(d["requirements"])
    return d
