"""Hierarchical monitoring system (paper §IV).

Components:

* :class:`MonitoringDatabase` — the centralized monitoring database that
  consolidates task events, failure reports, heartbeats, resource profiles
  and placement history, and answers the queries the resilience module
  needs (e.g. "where has this task historically succeeded?").
* :class:`Radio` — the communication radio.  :class:`InProcRadio` delivers
  messages in-process; :class:`TCPRadio`/:class:`TCPRadioServer` implement
  the paper's TCP transport (JSON lines over a socket) and are exercised by
  tests on localhost.  Both present the same ``send`` interface, so agents
  are transport-agnostic, mirroring the paper's modular database backends
  (local DB / cloud DB / Octopus event fabric).
* :class:`TaskMonitoringAgent` — per-node agent sampling resource usage of
  the running workers (psutil-based, as §VI-B) plus simulated node state.
* :class:`SystemMonitoringAgent` — heartbeat emitter for any component.
"""
from __future__ import annotations

import json
import socket
import socketserver
import threading
import time
from collections import defaultdict
from dataclasses import asdict, dataclass, is_dataclass
from typing import Any

try:
    import psutil  # noqa: F401
    _HAS_PSUTIL = True
except Exception:  # pragma: no cover
    _HAS_PSUTIL = False

from repro.core.failures import FailureReport


# --------------------------------------------------------------------------
# Radio transports
# --------------------------------------------------------------------------


class Radio:
    def send(self, message: dict[str, Any]) -> None:  # pragma: no cover
        raise NotImplementedError


class InProcRadio(Radio):
    """Direct-dispatch radio (default for the simulated cluster)."""

    def __init__(self, db: "MonitoringDatabase"):
        self.db = db

    def send(self, message: dict[str, Any]) -> None:
        self.db.ingest(message)


class TCPRadioServer:
    """JSON-lines-over-TCP sink feeding a MonitoringDatabase (paper §VI-B)."""

    def __init__(self, db: "MonitoringDatabase", host: str = "127.0.0.1", port: int = 0):
        self.db = db
        outer = self

        class Handler(socketserver.StreamRequestHandler):
            def handle(self) -> None:
                for line in self.rfile:
                    line = line.strip()
                    if not line:
                        continue
                    try:
                        outer.db.ingest(json.loads(line.decode()))
                    except Exception:  # noqa: BLE001 - malformed msg dropped
                        pass

        self._server = socketserver.ThreadingTCPServer((host, port), Handler)
        self._server.daemon_threads = True
        self.address = self._server.server_address
        self._thread = threading.Thread(target=self._server.serve_forever,
                                        daemon=True, name="radio-server")

    def start(self) -> "TCPRadioServer":
        self._thread.start()
        return self

    def stop(self) -> None:
        self._server.shutdown()
        self._server.server_close()


class TCPRadio(Radio):
    def __init__(self, address: tuple[str, int]):
        self.address = address
        self._sock: socket.socket | None = None
        self._lock = threading.Lock()

    def _connect(self) -> socket.socket:
        if self._sock is None:
            self._sock = socket.create_connection(self.address, timeout=2.0)
        return self._sock

    def send(self, message: dict[str, Any]) -> None:
        data = (json.dumps(message) + "\n").encode()
        with self._lock:
            try:
                self._connect().sendall(data)
            except OSError:
                self._sock = None
                self._connect().sendall(data)

    def close(self) -> None:
        with self._lock:
            if self._sock is not None:
                self._sock.close()
                self._sock = None


# --------------------------------------------------------------------------
# Centralized monitoring database
# --------------------------------------------------------------------------


@dataclass
class PlacementStats:
    successes: int = 0
    failures: int = 0
    # accumulated wall time of *successful* attempts, for the
    # HistoryAwareScheduler's "historically fast node" query
    duration_sum: float = 0.0
    duration_n: int = 0

    @property
    def total(self) -> int:
        return self.successes + self.failures

    @property
    def success_rate(self) -> float:
        return self.successes / self.total if self.total else 0.0

    @property
    def avg_duration(self) -> float:
        """Mean successful-attempt duration (0.0 = no timed observations)."""
        return self.duration_sum / self.duration_n if self.duration_n else 0.0


class MonitoringDatabase:
    """Thread-safe centralized store + query API (paper §IV)."""

    def __init__(self) -> None:
        self._lock = threading.RLock()
        self.task_events: dict[str, list[dict[str, Any]]] = defaultdict(list)
        self.system_events: list[dict[str, Any]] = []
        self.failures: list[FailureReport] = []
        self._heartbeats: dict[str, float] = {}
        self.resource_profiles: dict[str, list[dict[str, float]]] = defaultdict(list)
        # placement history keyed by task *name* (template), then node/pool
        self._node_history: dict[str, dict[str, PlacementStats]] = defaultdict(
            lambda: defaultdict(PlacementStats))
        self._pool_history: dict[str, dict[str, PlacementStats]] = defaultdict(
            lambda: defaultdict(PlacementStats))

    # -- ingest (radio entry point) ----------------------------------------
    def ingest(self, message: dict[str, Any]) -> None:
        kind = message.get("kind")
        if kind == "heartbeat":
            self.heartbeat(message["node"], message.get("time", time.time()))
        elif kind == "task_event":
            self.record_task_event(message["task_id"], message["event"],
                                   **message.get("data", {}))
        elif kind == "resource_profile":
            self.record_resource_profile(message["node"], message.get("profile", {}))
        elif kind == "system_event":
            self.record_system_event(message["event"], **message.get("data", {}))
        elif kind == "placement":
            self.record_task_placement(message["task_name"], message["node"],
                                       message["pool"], ok=message["ok"],
                                       duration=message.get("duration"))
        elif kind == "failure":
            d = message.get("report", {})
            self.failures.append(FailureReport(
                task_id=d.get("task_id"), exception=None,
                exception_type=d.get("exception_type", ""),
                message=d.get("message", ""), node=d.get("node"),
                pool=d.get("pool")))

    # -- writers -----------------------------------------------------------
    def heartbeat(self, node: str, ts: float) -> None:
        with self._lock:
            self._heartbeats[node] = ts

    def record_task_event(self, task_id: str, event: str, **data: Any) -> None:
        with self._lock:
            self.task_events[task_id].append(
                {"event": event, "time": time.time(), **data})

    def record_system_event(self, event: str, **data: Any) -> None:
        with self._lock:
            self.system_events.append({"event": event, "time": time.time(), **data})

    def record_resource_profile(self, node: str, profile: dict[str, float]) -> None:
        with self._lock:
            self.resource_profiles[node].append({"time": time.time(), **profile})
            # bound memory: keep last 512 samples per node
            if len(self.resource_profiles[node]) > 512:
                del self.resource_profiles[node][:-512]

    def record_task_placement(self, task_name: str, node: str, pool: str | None,
                              *, ok: bool, duration: float | None = None) -> None:
        with self._lock:
            ns = self._node_history[task_name][node]
            ps = self._pool_history[task_name][pool or "?"]
            if ok:
                ns.successes += 1
                ps.successes += 1
                if duration is not None and duration > 0:
                    for s in (ns, ps):
                        s.duration_sum += duration
                        s.duration_n += 1
            else:
                ns.failures += 1
                ps.failures += 1

    def report_failure(self, report: FailureReport) -> None:
        with self._lock:
            self.failures.append(report)

    # -- queries -------------------------------------------------------------
    def last_heartbeats(self) -> dict[str, float]:
        with self._lock:
            return dict(self._heartbeats)

    def node_history(self, task_name: str) -> dict[str, PlacementStats]:
        with self._lock:
            return {k: PlacementStats(v.successes, v.failures,
                                      v.duration_sum, v.duration_n)
                    for k, v in self._node_history[task_name].items()}

    def pool_history(self, task_name: str) -> dict[str, PlacementStats]:
        with self._lock:
            return {k: PlacementStats(v.successes, v.failures,
                                      v.duration_sum, v.duration_n)
                    for k, v in self._pool_history[task_name].items()}

    def best_historical_node(self, task_name: str,
                             exclude: set[str] = frozenset()) -> str | None:
        """Retry rung 3: where has this task succeeded most often?"""
        hist = self.node_history(task_name)
        best, best_score = None, 0
        for node, stats in hist.items():
            if node in exclude:
                continue
            if stats.successes > best_score:
                best, best_score = node, stats.successes
        return best

    def latest_profile(self, node: str) -> dict[str, float] | None:
        with self._lock:
            rows = self.resource_profiles.get(node)
            return dict(rows[-1]) if rows else None

    def failures_for(self, task_id: str) -> list[FailureReport]:
        with self._lock:
            return [f for f in self.failures if f.task_id == task_id]

    def events_for(self, task_id: str) -> list[dict[str, Any]]:
        with self._lock:
            return list(self.task_events[task_id])


# --------------------------------------------------------------------------
# Agents
# --------------------------------------------------------------------------


class SystemMonitoringAgent:
    """Heartbeat emitter for an arbitrary component (paper §IV)."""

    def __init__(self, component: str, radio: Radio, period: float = 0.05):
        self.component = component
        self.radio = radio
        self.period = period
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name=f"sysmon-{component}")

    def start(self) -> "SystemMonitoringAgent":
        self._thread.start()
        return self

    def _loop(self) -> None:
        while not self._stop.is_set():
            self.radio.send({"kind": "heartbeat", "node": self.component,
                             "time": time.time()})
            time.sleep(self.period)

    def stop(self) -> None:
        self._stop.set()


class TaskMonitoringAgent:
    """Per-node resource-profile sampler (psutil-based, paper §VI-B).

    Samples the hosting process's CPU/RSS via psutil (real measurements)
    and merges simulated node state (capacity, simulated in-use memory),
    shipping profiles over the radio.
    """

    def __init__(self, node: Any, radio: Radio, period: float = 0.1):
        self.node = node
        self.radio = radio
        self.period = period
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name=f"taskmon-{node.name}")
        self._proc = psutil.Process() if _HAS_PSUTIL else None

    def sample(self) -> dict[str, float]:
        prof: dict[str, float] = {
            "sim_mem_in_use_gb": float(self.node.mem_in_use_gb),
            "sim_mem_capacity_gb": float(self.node.memory_gb),
            "sim_healthy": float(self.node.healthy),
            "sim_queue_depth": float(self.node.task_queue.qsize()),
            "sim_alive_workers": float(sum(1 for w in self.node.workers if w.alive)),
        }
        if self._proc is not None:
            try:
                prof["proc_rss_gb"] = self._proc.memory_info().rss / 2**30
                prof["proc_cpu_pct"] = self._proc.cpu_percent(interval=None)
                prof["proc_open_files"] = float(len(self._proc.open_files()))
            except Exception:  # noqa: BLE001
                pass
        return prof

    def start(self) -> "TaskMonitoringAgent":
        self._thread.start()
        return self

    def _loop(self) -> None:
        while not self._stop.is_set():
            self.radio.send({"kind": "resource_profile", "node": self.node.name,
                             "profile": self.sample()})
            time.sleep(self.period)

    def stop(self) -> None:
        self._stop.set()


def serialize_report(report: FailureReport) -> dict[str, Any]:
    """JSON-safe rendering of a FailureReport for radio shipping."""
    d = {k: v for k, v in asdict(report).items() if k != "exception"}
    if is_dataclass(d.get("requirements")):
        d["requirements"] = asdict(d["requirements"])
    return d
