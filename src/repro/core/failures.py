"""Failure hierarchy for WRATH (paper §III, Table I).

Every failure that can surface in a TBPP system is represented as an
exception type tagged with the TBPP layer it originates from.  The
Failure Taxonomy Library (``taxonomy.py``) maps these — plus ordinary
Python exceptions raised by user task code — to categories, retriability
verdicts and policy actions.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Any


class Layer(enum.Enum):
    """The four layers of a TBPP framework (paper Fig. 1)."""

    APPLICATION = "application"
    FRAMEWORK = "framework"
    RUNTIME = "runtime"
    ENVIRONMENT = "environment"


class DetectionStrategy(enum.Enum):
    """How a failure type is detected (paper Table I)."""

    FTL = "failure_taxonomy_library"
    RP = "resource_profiling"
    FTL_RP = "ftl_plus_resource_profiling"
    RC = "root_cause"


class Retriable(enum.Enum):
    YES = "yes"
    NO = "no"
    ROOT_CAUSE = "depends_on_root_cause"


# ---------------------------------------------------------------------------
# Framework-level exception types (raised by the runtime itself, not user code)
# ---------------------------------------------------------------------------


class WrathFailure(Exception):
    """Base class for failures raised by the TBPP substrate itself."""

    layer: Layer = Layer.FRAMEWORK

    def __init__(self, message: str = "", **context: Any):
        super().__init__(message)
        self.context = context


# -- Framework layer (System Failures) --------------------------------------


class MonitorLossError(WrathFailure):
    """The component overseeing task execution became unavailable."""

    layer = Layer.FRAMEWORK


class ManagerLossError(WrathFailure):
    """The central/node manager responsible for tasks failed."""

    layer = Layer.FRAMEWORK


class WorkerLostError(WrathFailure):
    """A worker process died while executing a task (killed / crashed)."""

    layer = Layer.FRAMEWORK


class TaskCancelledError(WrathFailure):
    """The framework cancelled the task before/while it ran.

    Raised into the task's future by the proactive plane's predictive
    fast-fail and by explicit :meth:`DataFlowKernel.cancel_task` — a
    *decision*, not a manifestation, so it never re-enters the retry
    handler.
    """

    layer = Layer.FRAMEWORK


class DependencyError(WrathFailure):
    """A task failed because one of its parent tasks failed.

    Retriability depends on the *root cause* of the parent failure
    (paper Table I, detection strategy RC).
    """

    layer = Layer.FRAMEWORK

    def __init__(self, message: str = "", root_cause: BaseException | None = None, **ctx: Any):
        super().__init__(message, **ctx)
        self.root_cause = root_cause


# -- Runtime layer (Resource Failures) ---------------------------------------


class ResourceStarvationError(WrathFailure):
    """Task did not receive sufficient CPU/memory/storage."""

    layer = Layer.RUNTIME


class UlimitExceededError(ResourceStarvationError):
    """Too many open files / process limits exceeded (Table III 'ulimit')."""

    layer = Layer.RUNTIME


class PilotJobInitError(WrathFailure):
    """The pilot job failed to start or initialize correctly."""

    layer = Layer.RUNTIME


# -- Environment layer (Hardware & Environment Failures) --------------------


class HardwareShutdownError(WrathFailure):
    """A server / storage device / network component powered down."""

    layer = Layer.ENVIRONMENT


class EnvironmentMismatchError(WrathFailure):
    """The software environment on the node does not match requirements.

    The Python-native manifestation is ``ImportError`` /
    ``ModuleNotFoundError``; the simulator raises this subclass so that
    both spellings flow through the same taxonomy entry.
    """

    layer = Layer.ENVIRONMENT

    def __init__(self, message: str = "", missing_packages: tuple[str, ...] = (), **ctx: Any):
        super().__init__(message, **ctx)
        self.missing_packages = missing_packages


class HeartbeatLostError(WrathFailure):
    """A component stopped heartbeating (detected, not raised in-line)."""

    layer = Layer.ENVIRONMENT


# -- Application layer helpers ----------------------------------------------


class RandomSeedError(WrathFailure):
    """Sporadic, seed-dependent user failure (e.g. MolDesign init, §III-A).

    Retriable: re-generation with a fresh seed may succeed.
    """

    layer = Layer.APPLICATION


class NumericalDivergenceError(WrathFailure):
    """Training-plane application failure: loss became NaN/Inf.

    This class has no Parsl analog; it is our training-specific extension
    (DESIGN.md §2).  Retriable with a different data order / restored
    checkpoint, akin to a Random Seed Error.
    """

    layer = Layer.APPLICATION


# ---------------------------------------------------------------------------
# Failure record — what the monitoring system ships to the categorizer
# ---------------------------------------------------------------------------


@dataclass
class FailureReport:
    """Everything known about one observed failure manifestation (§III-B)."""

    task_id: str | None
    exception: BaseException | None
    exception_type: str
    message: str
    node: str | None = None
    pool: str | None = None
    worker: str | None = None
    # resource profile at (or near) failure time, from the task monitor agent
    resource_profile: dict[str, float] = field(default_factory=dict)
    # declared task requirements, for resource-mismatch analysis
    requirements: dict[str, Any] = field(default_factory=dict)
    retry_count: int = 0
    timestamp: float = 0.0
    # log lines captured around failure (stdout/err of the worker)
    log_tail: list[str] = field(default_factory=list)

    @classmethod
    def from_exception(cls, exc: BaseException, **kw: Any) -> "FailureReport":
        return cls(
            task_id=kw.pop("task_id", None),
            exception=exc,
            exception_type=type(exc).__name__,
            message=str(exc),
            **kw,
        )
