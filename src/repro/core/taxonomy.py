"""Failure Taxonomy Library (FTL) — paper §V-A, Table I.

The FTL maps observed failure manifestations (exception types, heartbeat
loss, resource-log anomalies) to taxonomy entries: which TBPP layer the
failure belongs to, whether it is retriable, the detection strategy that
identifies it, and the default policy action.

The library ships with the full Table I taxonomy plus the summarized Python
exception map for application-layer failures (§V-A: "for failures that occur
at the application layer, we summarize the exceptions and errors that may
occur in Python"), and is user-extensible (§VI-B: "users can define custom
rules for failure categorization").
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Type

from repro.core.failures import (
    DependencyError,
    DetectionStrategy,
    EnvironmentMismatchError,
    HardwareShutdownError,
    HeartbeatLostError,
    Layer,
    ManagerLossError,
    MonitorLossError,
    NumericalDivergenceError,
    PilotJobInitError,
    RandomSeedError,
    ResourceStarvationError,
    Retriable,
    UlimitExceededError,
    WorkerLostError,
)


@dataclass(frozen=True)
class TaxonomyEntry:
    """One row of the failure taxonomy (paper Table I)."""

    failure_type: str
    layer: Layer
    retriable: Retriable
    detection: DetectionStrategy
    # default policy action name (resolved by the policy engine)
    default_action: str
    # whether the failure is tied to properties of the node it ran on —
    # if True, retrying *elsewhere* may succeed even though retrying
    # in-place will not (drives the hierarchical retry ladder)
    placement_sensitive: bool = False
    description: str = ""


# --------------------------------------------------------------------------
# Table I, rendered as data
# --------------------------------------------------------------------------

TABLE_I: dict[str, TaxonomyEntry] = {
    e.failure_type: e for e in [
        # -- Application layer (User Failures) ---------------------------
        TaxonomyEntry("syntax_error", Layer.APPLICATION, Retriable.NO,
                      DetectionStrategy.FTL, "terminate",
                      description="Mistakes that violate language syntax."),
        TaxonomyEntry("logic_error", Layer.APPLICATION, Retriable.NO,
                      DetectionStrategy.FTL, "terminate",
                      description="Out-of-bounds indexing, bad types, etc."),
        TaxonomyEntry("random_seed_error", Layer.APPLICATION, Retriable.YES,
                      DetectionStrategy.FTL, "retry_in_place",
                      description="Sporadic seed-dependent failure (MolDesign)."),
        TaxonomyEntry("numerical_divergence", Layer.APPLICATION, Retriable.YES,
                      DetectionStrategy.FTL, "retry_in_place",
                      description="Training-plane NaN/Inf loss (our extension)."),
        # -- Framework layer (System Failures) ---------------------------
        TaxonomyEntry("monitor_loss", Layer.FRAMEWORK, Retriable.YES,
                      DetectionStrategy.FTL, "restart_component",
                      description="Task-overseeing component unavailable."),
        TaxonomyEntry("manager_loss", Layer.FRAMEWORK, Retriable.YES,
                      DetectionStrategy.FTL, "restart_component",
                      description="Central/node manager failed."),
        TaxonomyEntry("worker_lost", Layer.FRAMEWORK, Retriable.YES,
                      DetectionStrategy.FTL, "restart_component",
                      placement_sensitive=True,
                      description="Worker process died mid-task."),
        TaxonomyEntry("dependency_failure", Layer.FRAMEWORK, Retriable.ROOT_CAUSE,
                      DetectionStrategy.RC, "root_cause",
                      description="Parent failure cascaded to child."),
        # -- Runtime layer (Resource Failures) ----------------------------
        TaxonomyEntry("resource_starvation", Layer.RUNTIME, Retriable.YES,
                      DetectionStrategy.RP, "hierarchical_retry",
                      placement_sensitive=True,
                      description="Insufficient CPU/memory/storage."),
        TaxonomyEntry("ulimit_exceeded", Layer.RUNTIME, Retriable.YES,
                      DetectionStrategy.RP, "hierarchical_retry",
                      placement_sensitive=True,
                      description="Open-file / process limits exceeded."),
        TaxonomyEntry("pilot_init_failure", Layer.RUNTIME, Retriable.YES,
                      DetectionStrategy.RP, "hierarchical_retry",
                      placement_sensitive=True,
                      description="Pilot job failed to initialize."),
        # -- Environment layer (Hardware & Environment) --------------------
        TaxonomyEntry("hardware_shutdown", Layer.ENVIRONMENT, Retriable.YES,
                      DetectionStrategy.FTL_RP, "denylist_and_retry",
                      placement_sensitive=True,
                      description="Server/storage/network component failed."),
        TaxonomyEntry("heartbeat_lost", Layer.ENVIRONMENT, Retriable.YES,
                      DetectionStrategy.FTL_RP, "denylist_and_retry",
                      placement_sensitive=True,
                      description="Component stopped heartbeating."),
        TaxonomyEntry("env_mismatch", Layer.ENVIRONMENT, Retriable.NO,
                      DetectionStrategy.FTL, "hierarchical_retry",
                      placement_sensitive=True,
                      description="Missing software/libraries on the node. "
                                  "Non-retriable in place; retriable on a node "
                                  "whose environment matches (paper §VI-B)."),
    ]
}


# --------------------------------------------------------------------------
# Python exception map → taxonomy entries (application-layer FTL, §V-A/§VI-B)
# --------------------------------------------------------------------------

# user-code exceptions that will deterministically recur -> terminate
_LOGIC_ERRORS: tuple[Type[BaseException], ...] = (
    ZeroDivisionError, IndexError, KeyError, TypeError, ValueError,
    AttributeError, AssertionError, NotImplementedError, ArithmeticError,
    OverflowError, RecursionError, UnboundLocalError, NameError,
)
_SYNTAX_ERRORS: tuple[Type[BaseException], ...] = (SyntaxError, IndentationError)

EXCEPTION_MAP: list[tuple[Type[BaseException], str]] = [
    # wrath substrate exceptions first (most specific)
    (UlimitExceededError, "ulimit_exceeded"),
    (ResourceStarvationError, "resource_starvation"),
    (PilotJobInitError, "pilot_init_failure"),
    (EnvironmentMismatchError, "env_mismatch"),
    (HardwareShutdownError, "hardware_shutdown"),
    (HeartbeatLostError, "heartbeat_lost"),
    (WorkerLostError, "worker_lost"),
    (ManagerLossError, "manager_loss"),
    (MonitorLossError, "monitor_loss"),
    (DependencyError, "dependency_failure"),
    (RandomSeedError, "random_seed_error"),
    (NumericalDivergenceError, "numerical_divergence"),
    # plain-Python manifestations
    (MemoryError, "resource_starvation"),
    (ModuleNotFoundError, "env_mismatch"),
    (ImportError, "env_mismatch"),
    (SyntaxError, "syntax_error"),           # also covers IndentationError
    (OSError, "ulimit_exceeded"),            # EMFILE et al. — refined by RP
    (ConnectionError, "manager_loss"),
    (TimeoutError, "heartbeat_lost"),
]
EXCEPTION_MAP += [(t, "logic_error") for t in _LOGIC_ERRORS]


class FailureTaxonomyLibrary:
    """Queryable FTL with user-extensible rules (paper §V-A, §VI-B)."""

    def __init__(self) -> None:
        self.entries: dict[str, TaxonomyEntry] = dict(TABLE_I)
        self._exc_map: list[tuple[Type[BaseException], str]] = list(EXCEPTION_MAP)
        self._message_rules: list[tuple[str, str]] = [
            # substring-of-message rules, applied when the type is ambiguous
            ("too many open files", "ulimit_exceeded"),
            ("out of memory", "resource_starvation"),
            ("cannot allocate", "resource_starvation"),
            ("no module named", "env_mismatch"),
            ("heartbeat", "heartbeat_lost"),
            ("nan", "numerical_divergence"),
        ]

    # -- extension API ----------------------------------------------------
    def register_entry(self, entry: TaxonomyEntry) -> None:
        self.entries[entry.failure_type] = entry

    def register_exception(self, exc_type: Type[BaseException], failure_type: str) -> None:
        if failure_type not in self.entries:
            raise KeyError(f"unknown failure type {failure_type!r}")
        self._exc_map.insert(0, (exc_type, failure_type))

    def register_message_rule(self, substring: str, failure_type: str) -> None:
        self._message_rules.insert(0, (substring.lower(), failure_type))

    # -- lookup -------------------------------------------------------------
    def classify_exception(self, exc: BaseException | None,
                           exc_type_name: str = "", message: str = "") -> TaxonomyEntry:
        """Classify by exception type, falling back to message rules, then
        to the conservative default (logic_error → terminate, the paper's
        'non-Python-package failures are application-layer, non-recoverable,
        require user intervention' rule, §VI-B)."""
        if exc is not None:
            for exc_type, ftype in self._exc_map:
                if isinstance(exc, exc_type):
                    return self.entries[ftype]
            message = message or str(exc)
        msg = (message or "").lower()
        for sub, ftype in self._message_rules:
            if sub in msg:
                return self.entries[ftype]
        if exc_type_name:
            for exc_type, ftype in self._exc_map:
                if exc_type.__name__ == exc_type_name:
                    return self.entries[ftype]
        return self.entries["logic_error"]

    def get(self, failure_type: str) -> TaxonomyEntry:
        return self.entries[failure_type]


DEFAULT_FTL = FailureTaxonomyLibrary()
