"""Resilience Policy Engine (paper §V-B, Fig 2) — WRATH's retry handler.

Maps categorized failures to actions:

* **resource denylist** — components that stopped communicating (or whose
  hardware failed) are denylisted; HTCondor-style, they are removed from
  the list if they later resume heartbeating;
* **immediate termination** — non-recoverable failures terminate the task
  (and thus the application) at once to avoid wasted compute ("fail fast");
* **hierarchical retry** — recoverable failures are replanned by the
  four-rung :class:`~repro.core.retry.HierarchicalRetryPlanner`;
* **restart of failed components** — system failures restart the failed
  worker/manager before the retry (Fig 2, left branch).

The engine is installed into the DFK as ``retry_handler=`` (paper §VI-B:
"We implement the resilience module as a retry handler in Parsl").
"""
from __future__ import annotations


from repro.core.categorization import Categorization, FailureCategorizationEngine
from repro.core.failures import FailureReport
from repro.core.retry import HierarchicalRetryPlanner
from repro.core.taxonomy import DEFAULT_FTL, FailureTaxonomyLibrary
from repro.engine.retry_api import Action, RetryDecision, SchedulingContext


class ResiliencePolicyEngine:
    def __init__(
        self,
        ftl: FailureTaxonomyLibrary | None = None,
        *,
        fail_fast_distinct_nodes: int = 2,
        heartbeat_resume_window: float = 0.5,
    ):
        self.ftl = ftl or DEFAULT_FTL
        self.fail_fast_distinct_nodes = fail_fast_distinct_nodes
        self.heartbeat_resume_window = heartbeat_resume_window
        self.decisions: list[dict] = []   # audit log for tests/benchmarks
        # one categorization engine + planner reused across failures
        # (rebuilt only if the engine context's cluster/monitor changes)
        self._engine: FailureCategorizationEngine | None = None
        self._planner: HierarchicalRetryPlanner | None = None

    # ------------------------------------------------------------------ #
    def _cached(self, ctx: SchedulingContext) -> tuple[
            FailureCategorizationEngine, HierarchicalRetryPlanner]:
        if self._engine is None or self._engine.monitor is not ctx.monitor:
            self._engine = FailureCategorizationEngine(
                self.ftl, ctx.monitor,
                fail_fast_distinct_nodes=self.fail_fast_distinct_nodes)
        if (self._planner is None or self._planner.cluster is not ctx.cluster
                or self._planner.monitor is not ctx.monitor):
            self._planner = HierarchicalRetryPlanner(ctx.cluster, ctx.monitor)
        return self._engine, self._planner

    def __call__(self, record, report: FailureReport,
                 ctx: SchedulingContext) -> RetryDecision:
        engine, planner = self._cached(ctx)

        self._refresh_denylist(ctx)
        cat = engine.categorize(record, report)
        decision = self._decide(record, report, cat, ctx, planner)
        self.decisions.append({
            "task_id": record.task_id,
            "failure_type": cat.entry.failure_type,
            "layer": cat.entry.layer.value,
            "resolvable": cat.resolvable,
            "action": decision.action.value,
            "rung": decision.rung,
            "reason": decision.reason,
        })
        return decision

    # ------------------------------------------------------------------ #
    def _decide(self, record, report: FailureReport, cat: Categorization,
                ctx: SchedulingContext,
                planner: HierarchicalRetryPlanner) -> RetryDecision:
        # Fig 2 step 1: non-recoverable -> immediate termination (fail fast).
        if not cat.resolvable:
            return RetryDecision(Action.FAIL,
                                 reason=f"immediate termination: {cat.explanation}")

        # Denylist malfunctioning components before planning placement.
        if cat.denylist_node and report.node:
            ctx.denylist.add(report.node)
            if ctx.monitor is not None:
                ctx.monitor.record_system_event("denylist_add", node=report.node,
                                                cause=cat.entry.failure_type)

        if record.retry_count >= record.max_retries:
            return RetryDecision(Action.FAIL, reason="retries exhausted")

        placement = planner.plan(record, report, cat, ctx.denylist,
                                 scheduler=getattr(ctx, "scheduler", None))
        if placement is None:
            return RetryDecision(
                Action.FAIL,
                reason=f"no feasible placement anywhere: {cat.explanation}")

        overrides = dict(cat.suggested_overrides)
        action = Action.RETRY
        restart = None
        if cat.restart_component:
            # Fig 2: system failures -> restart failed component, then retry
            action = Action.RESTART_AND_RETRY
            restart = cat.restart_component

        delay = cat.retry_delay_s * (2 ** record.retry_count) if cat.retry_delay_s else 0.0
        return RetryDecision(
            action,
            target_pool=placement.pool,
            target_node=placement.node,
            resource_overrides=overrides,
            restart_component=restart,
            reason=f"{cat.explanation} | {placement.reason}",
            rung=placement.rung,
            delay_s=delay,
        )

    # ------------------------------------------------------------------ #
    def _refresh_denylist(self, ctx: SchedulingContext) -> None:
        """HTCondor-style: resources resuming communication leave the list.

        Nodes the proactive sentinel *drained* are exempt: a draining node
        typically still heartbeats (the drain fired on a trend, before hard
        loss), so the resume rule would immediately re-admit it.  The
        sentinel owns the drained lifecycle and un-denylists on recovery.
        """
        if ctx.monitor is None:
            return
        # SchedulingContext.now() is the contract: clock-aware wall "now"
        # with a REAL_CLOCK fallback — no hasattr hedge, no raw time.time()
        now = ctx.now()
        beats = ctx.monitor.last_heartbeats()
        drained = getattr(ctx, "drained", None) or set()
        # sorted, not set order: denylist_remove events land in the monitor's
        # event log, and the sim plane's trace contract is "same seed =>
        # identical trace on every machine" — hash order is per-process
        for node in sorted(ctx.denylist):
            if node in drained:
                continue
            last = beats.get(node)
            if last is not None and now - last < self.heartbeat_resume_window:
                node_obj = ctx.cluster.find_node(node)
                if node_obj is not None and node_obj.healthy:
                    ctx.denylist.discard(node)
                    ctx.monitor.record_system_event("denylist_remove", node=node)


def wrath_retry_handler(**kwargs) -> ResiliencePolicyEngine:
    """Convenience factory: ``DataFlowKernel(retry_handler=wrath_retry_handler())``."""
    return ResiliencePolicyEngine(**kwargs)
