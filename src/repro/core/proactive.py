"""Proactive resilience sentinel (paper §IV↔§V feedback loop).

WRATH's headline result is that the monitoring system and the resilient
module collaborate *in real time*: tasks destined to fail are identified
and terminated before they burn retries, and nodes trending toward failure
are evacuated before hard loss.  This module is that collaboration: the
:class:`ProactiveSentinel` consumes the :class:`~repro.core.monitoring.
MonitoringDatabase`'s streaming profiles and health trends and emits
proactive decisions into the engine:

* **predictive fast-fail** — a task whose (rung-1-corrected) requirements
  can never fit any live node is failed *now*, at dispatch time or between
  retries, instead of after N doomed attempts;
* **failure-streak fast-fail** — a placement-sensitive framework/application
  failure that has recurred identically on multiple nodes that *did*
  satisfy the task's requirements is declared destined-to-fail: placement
  cannot fix it, so remaining retries are cut short (the single-pool
  generalization of the categorizer's cross-pool fail-fast heuristic);
* **node drain** — a node whose heartbeat is trending toward silence or
  whose memory-growth slope projects OOM within the horizon is drained:
  placement stops (denylist), in-flight tasks are preempted/migrated, and
  the node is released back (undrain) when its trends recover.

The sentinel runs two ways at once: a *periodic event* on the DFK event
loop (:meth:`tick` — drain/undrain sweeps and the queued-task feasibility
sweep) and *inline hooks* the DFK calls on the dispatch and retry paths
(:meth:`check_dispatch`, :meth:`review_retry`) so a destined-to-fail task
never has to wait for the next tick.  All sentinel time is accounted into
``stats["wrath_overhead_s"]`` — it is resilience-module overhead.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Any

from repro.core.failures import Layer
from repro.core.taxonomy import DEFAULT_FTL, FailureTaxonomyLibrary
from repro.engine.retry_api import Action, RetryDecision


@dataclass
class ProactiveConfig:
    """Tunables of the proactive plane."""

    period: float = 0.05               # sentinel tick period (seconds)
    streak_threshold: int = 2          # identical failures on >= N adequate nodes
    oom_horizon_s: float = 1.0         # project memory trends this far ahead
    drain_silence_factor: float = 0.6  # drain at this fraction of the loss threshold
    min_profile_samples: int = 3       # trend/profile confidence floor
    enable_fast_fail: bool = True
    enable_drain: bool = True
    enable_preempt: bool = True


@dataclass
class ProactiveDecision:
    """Audit-log entry for one proactive intervention."""

    kind: str                          # fast_fail | streak_fail | drain | undrain | preempt
    reason: str
    task_id: str | None = None
    node: str | None = None
    action: Action | None = None
    # stamped from the engine's clock in ``_note`` (0.0 = never attached)
    time: float = 0.0


class ProactiveSentinel:
    """Streams monitoring data into proactive engine decisions."""

    def __init__(self, config: ProactiveConfig | None = None,
                 ftl: FailureTaxonomyLibrary | None = None):
        self.config = config or ProactiveConfig()
        self.ftl = ftl or DEFAULT_FTL
        self.decisions: list[ProactiveDecision] = []
        self.dfk: Any = None
        self._event = None
        self._last_cluster_sig: tuple | None = None
        # feasibility verdicts per (spec fingerprint) for the current
        # cluster signature — tasks of one template share a spec, so the
        # per-dispatch check is usually one dict hit.  The lock serializes
        # the sig-check/compute/store sequence across the event-loop thread
        # and worker threads (review_retry) so a verdict computed against a
        # stale node set can never be stored under the new signature.
        self._feas_cache: dict[tuple, str | None] = {}
        self._feas_sig: tuple | None = None
        self._feas_lock = threading.Lock()

    # ------------------------------------------------------------------ #
    # lifecycle
    # ------------------------------------------------------------------ #
    def attach(self, dfk: Any) -> "ProactiveSentinel":
        """Bind to a DataFlowKernel and start the periodic sweep."""
        self.dfk = dfk
        self._event = dfk.events.schedule_periodic(
            self.config.period, self.tick, name="proactive-sentinel")
        return self

    def detach(self) -> None:
        if self._event is not None:
            self._event.cancel()
            self._event = None
        self.dfk = None

    def _note(self, kind: str, reason: str, *, task_id: str | None = None,
              node: str | None = None, action: Action | None = None) -> None:
        decision = ProactiveDecision(
            kind=kind, reason=reason, task_id=task_id, node=node, action=action)
        if self.dfk is not None:
            decision.time = self.dfk.clock.time()
        self.decisions.append(decision)
        if self.dfk is not None and self.dfk.monitor is not None:
            self.dfk.monitor.record_system_event(
                f"proactive_{kind}", task_id=task_id, node=node, reason=reason)

    # ------------------------------------------------------------------ #
    # feasibility analysis
    # ------------------------------------------------------------------ #
    def _live_nodes(self) -> list[Any]:
        dfk = self.dfk
        return [n for n in dfk.cluster.all_nodes()
                if n.healthy and n.name not in dfk.denylist]

    def _cluster_sig(self) -> tuple:
        dfk = self.dfk
        return (tuple(sorted(dfk.denylist)),
                tuple(n.healthy for n in dfk.cluster.all_nodes()))

    _MISS = object()

    def _infeasible_reason(self, spec: Any) -> str | None:
        """Reason string if ``spec`` fits no live node; None when placeable.

        With *zero* live nodes this is not a verdict on the task (nodes may
        resume or be un-denylisted), so no fast-fail is issued.  Verdicts
        are cached per spec fingerprint: a cached *feasible* verdict is
        trusted as-is (the periodic tick invalidates the cache when the
        live-node set changes, and the sweep re-examines stranded tasks),
        while an *infeasible* verdict — the one that fails a task — is
        revalidated against the current cluster signature before acting.
        """
        key = (spec.memory_gb, spec.packages, spec.open_files)
        if self._feas_cache.get(key, self._MISS) is None:
            return None                       # feasible: lock-free dict hit
        with self._feas_lock:
            sig = self._cluster_sig()
            if sig != self._feas_sig:
                self._feas_sig = sig
                self._feas_cache.clear()
            cached = self._feas_cache.get(key, self._MISS)
            if cached is not self._MISS:
                return cached
            nodes = self._live_nodes()
            reason = None
            if nodes and not any(n.satisfies(spec)[0] for n in nodes):
                reason = (f"requirements (mem={spec.memory_gb}GB, "
                          f"pkgs={list(spec.packages)}, fds={spec.open_files}) "
                          f"fit none of {len(nodes)} live nodes")
            self._feas_cache[key] = reason
            return reason

    def _corrected_spec(self, rec: Any, overrides: dict[str, Any] | None = None) -> Any:
        """The task's requirements after rung-1 corrections (and a pending
        decision's overrides), i.e. what any future attempt would demand."""
        spec = rec.effective_resources()
        if overrides:
            d = spec.asdict()
            d.update(overrides)
            d["packages"] = tuple(d["packages"])
            spec = type(spec)(**d)
        return spec

    # ------------------------------------------------------------------ #
    # inline hooks (called by the DFK on its event thread)
    # ------------------------------------------------------------------ #
    def check_dispatch(self, rec: Any) -> str | None:
        """Predictive fast-fail at dispatch time: fail before attempt 1.

        Returns the reason string when the task should be failed now, or
        ``None`` to proceed with dispatch.
        """
        if not self.config.enable_fast_fail:
            return None
        reason = self._infeasible_reason(self._corrected_spec(rec))
        if reason is not None:
            reason = f"predictive fast-fail at dispatch: {reason}"
            self._note("fast_fail", reason, task_id=rec.task_id,
                       action=Action.FAIL)
        return reason

    def review_retry(self, rec: Any, report: Any,
                     decision: RetryDecision) -> RetryDecision:
        """Second opinion on a RETRY decision: veto retries destined to fail."""
        if not self.config.enable_fast_fail or decision.action not in (
                Action.RETRY, Action.RESTART_AND_RETRY, Action.PREEMPT,
                Action.DRAIN):
            return decision

        spec = self._corrected_spec(rec, decision.resource_overrides)
        reason = self._infeasible_reason(spec)
        if reason is not None:
            reason = f"predictive fast-fail: corrected {reason}"
            self._note("fast_fail", reason, task_id=rec.task_id,
                       action=Action.FAIL)
            self.dfk.stats["fast_fails"] += 1
            return RetryDecision(Action.FAIL, reason=reason,
                                 rung=decision.rung)

        streak = self._streak_reason(rec, report, spec)
        if streak is not None:
            self._note("streak_fail", streak, task_id=rec.task_id,
                       action=Action.FAIL)
            self.dfk.stats["fast_fails"] += 1
            return RetryDecision(Action.FAIL, reason=streak,
                                 rung=decision.rung)
        return decision

    def _streak_reason(self, rec: Any, report: Any, spec: Any) -> str | None:
        """Destined-to-fail detection for placement-sensitive failures.

        The reactive categorizer only fail-fasts when a failure recurred
        across >= 2 *pools*; on a single-pool cluster it burns the whole
        retry budget.  The streak rule drops the pool requirement but adds
        a stronger condition: every failing node must have *satisfied* the
        task's corrected requirements — nodes that should have worked,
        didn't, so no placement can fix this task.  Environment-layer
        failures are exempt (the node itself is the cause; denylist +
        placement genuinely fixes them).
        """
        monitor = self.dfk.monitor
        if monitor is None:
            return None
        entry = self.ftl.classify_exception(
            report.exception, exc_type_name=report.exception_type,
            message=report.message)
        if not entry.placement_sensitive or entry.layer not in (
                Layer.FRAMEWORK, Layer.APPLICATION):
            return None
        cluster = self.dfk.cluster
        adequate_nodes: set[str] = set()
        for f in monitor.failures_for(rec.task_id):
            if f.exception_type != report.exception_type or not f.node:
                continue
            node = cluster.find_node(f.node)
            if node is not None and node.satisfies(spec)[0]:
                adequate_nodes.add(f.node)
        if report.node:
            node = cluster.find_node(report.node)
            if node is not None and node.satisfies(spec)[0]:
                adequate_nodes.add(report.node)
        if len(adequate_nodes) >= self.config.streak_threshold:
            return (f"predictive fast-fail: {report.exception_type} recurred "
                    f"on {len(adequate_nodes)} nodes that satisfied the "
                    f"task's requirements — placement cannot fix it")
        return None

    # ------------------------------------------------------------------ #
    # periodic sweep
    # ------------------------------------------------------------------ #
    def tick(self) -> None:
        dfk = self.dfk
        if dfk is None:
            return
        t0 = time.perf_counter()
        try:
            if self.config.enable_fast_fail:
                # feasibility of an in-flight task only changes when the
                # cluster's live-node set does (submission and retry are
                # covered inline) — the O(tasks) sweep runs on transitions
                sig = self._cluster_sig()
                if sig != self._last_cluster_sig:
                    self._last_cluster_sig = sig
                    # cluster changed: drop stale feasibility verdicts so
                    # the inline fast path re-learns the new live-node set
                    with self._feas_lock:
                        if sig != self._feas_sig:
                            self._feas_sig = sig
                            self._feas_cache.clear()
                    self._sweep_infeasible_tasks()
            if self.config.enable_drain and dfk.monitor is not None:
                self._sweep_node_health()
        finally:
            dfk.stats["wrath_overhead_s"] += time.perf_counter() - t0

    def _sweep_infeasible_tasks(self) -> None:
        """Fast-fail queued tasks stranded by cluster-state changes."""
        from repro.engine.task import TaskState

        dfk = self.dfk
        for tid, rec in list(dfk.tasks.items()):
            if rec.cancel_requested or rec.state not in (
                    TaskState.READY, TaskState.SCHEDULED, TaskState.RETRYING):
                continue
            reason = self._infeasible_reason(self._corrected_spec(rec))
            if reason is None:
                continue
            reason = f"predictive fast-fail (sweep): {reason}"
            self._note("fast_fail", reason, task_id=tid, action=Action.FAIL)
            dfk.fast_fail_task(tid, reason)

    def _sweep_node_health(self) -> None:
        dfk = self.dfk
        cfg = self.config
        stale_after = dfk.heartbeat_period * dfk.heartbeat_threshold
        now = dfk.clock.time()
        for node in dfk.cluster.all_nodes():
            health = dfk.monitor.node_health(node.name)
            if node.name in dfk.drained:
                # undrain when the trends that caused the drain recover
                recovered = (node.healthy
                             and health.last_heartbeat
                             and health.silent_for(now) < stale_after * 0.5
                             and not health.trending_oom(cfg.oom_horizon_s))
                if recovered:
                    self._note("undrain", "heartbeat and memory trends "
                               "recovered", node=node.name)
                    dfk.undrain_node(node.name)
                continue
            if not node.healthy or node.name in dfk.denylist:
                continue
            reason = None
            if (health.last_heartbeat
                    and health.silent_for(now) > cfg.drain_silence_factor * stale_after):
                reason = (f"heartbeat trending to silence: "
                          f"{health.silent_for(now):.3f}s since last beat "
                          f"(loss threshold {stale_after:.3f}s)")
            elif health.trending_oom(cfg.oom_horizon_s):
                reason = (f"memory trending to OOM: {health.mem_in_use_gb:.1f}GB "
                          f"in use, slope {health.mem_slope_gb_s:.2f}GB/s, "
                          f"projected {health.projected_mem_gb(cfg.oom_horizon_s):.1f}GB "
                          f"> capacity {health.mem_capacity_gb:.1f}GB")
            if reason is not None:
                self._note("drain", reason, node=node.name, action=Action.DRAIN)
                dfk.drain_node(node.name, reason=reason,
                               preempt=cfg.enable_preempt)


def make_sentinel(proactive: "bool | ProactiveConfig | ProactiveSentinel",
                  ) -> ProactiveSentinel | None:
    """Normalize the DFK's ``proactive=`` argument into a sentinel."""
    if isinstance(proactive, ProactiveSentinel):
        return proactive
    if isinstance(proactive, ProactiveConfig):
        return ProactiveSentinel(proactive)
    return ProactiveSentinel() if proactive else None
