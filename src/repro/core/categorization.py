"""Failure Categorization Engine (paper §V-A).

Combines the Failure Taxonomy Library with a *root cause analyzer* — a
decision tree over monitoring data from all four layers (§VI-B: "The
failure root cause analyzer in WRATH uses a decision tree to classify
errors") — to produce a :class:`Categorization` the policy engine acts on.

The analyzer:
* classifies the exception via the FTL;
* unwraps dependency failures to their root cause (Table I, strategy RC);
* performs **resource analysis** for runtime-layer failures: compares the
  task's declared requirements against the node's capacity/profile to
  decide whether the failure is a *capacity mismatch* (retry elsewhere,
  possibly with corrected requirements) or *transient contention* (retry in
  place);
* performs **environment analysis** for env-mismatch failures: matches the
  task's package requirements against per-node package availability (the
  ``pip freeze`` probe of §VI-B, simulated by node package sets);
* applies **fail-fast heuristics** (§VI-B): a failure type that has recurred
  across distinct nodes despite placement-sensitive retries is declared
  non-recoverable so the application fails fast.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

from repro.core.failures import (
    DependencyError,
    FailureReport,
    Retriable,
)
from repro.core.taxonomy import DEFAULT_FTL, FailureTaxonomyLibrary, TaxonomyEntry


@dataclass
class Categorization:
    entry: TaxonomyEntry
    resolvable: bool
    resource_related: bool = False
    # WRATH rung-1 corrected requirements (e.g. raise memory_gb to observed)
    suggested_overrides: dict[str, Any] = field(default_factory=dict)
    # node-feasibility requirements derived from root-cause analysis
    required_packages: tuple[str, ...] = ()
    required_memory_gb: float = 0.0
    # whether the failed node itself should be denylisted
    denylist_node: bool = False
    # component to restart ("worker:<node>" etc.), for system failures
    restart_component: str | None = None
    # base backoff before the retry (transient contention), scaled
    # exponentially with the retry count by the policy engine
    retry_delay_s: float = 0.0
    # instance-level override: the root-cause analysis concluded the SAME
    # node can work (e.g. transient contention), even if the failure type
    # is placement-sensitive in general
    in_place_ok: bool | None = None
    explanation: str = ""

    @property
    def placement_sensitive(self) -> bool:
        if self.in_place_ok is not None:
            return not self.in_place_ok
        return self.entry.placement_sensitive


class FailureCategorizationEngine:
    def __init__(self, ftl: FailureTaxonomyLibrary | None = None, monitor=None,
                 *, fail_fast_distinct_nodes: int = 2):
        self.ftl = ftl or DEFAULT_FTL
        self.monitor = monitor
        # placement-sensitive failures recurring on >= this many distinct
        # nodes are declared unresolvable (fail-fast heuristic)
        self.fail_fast_distinct_nodes = fail_fast_distinct_nodes

    # ------------------------------------------------------------------ #
    def categorize(self, record, report: FailureReport) -> Categorization:
        exc = report.exception
        # --- Table I strategy RC: unwrap dependency failures -------------
        if isinstance(exc, DependencyError):
            root = exc.root_cause
            root_entry = self.ftl.classify_exception(root) if root is not None \
                else self.ftl.get("dependency_failure")
            if root is None or root_entry.retriable is Retriable.NO:
                return Categorization(
                    entry=self.ftl.get("dependency_failure"), resolvable=False,
                    explanation=f"dependency root cause "
                                f"{type(root).__name__ if root else 'unknown'} "
                                f"is non-retriable -> fail fast")
            # retriable root cause: the parent would have been retried by
            # WRATH already; a *still-failing* parent means its retries are
            # exhausted -> the child cannot succeed either.
            return Categorization(
                entry=self.ftl.get("dependency_failure"), resolvable=False,
                explanation="dependency failed terminally despite retriable "
                            "root cause -> fail fast")

        entry = self.ftl.classify_exception(
            exc, exc_type_name=report.exception_type, message=report.message)

        # --- layer-specific root-cause analysis --------------------------
        if entry.retriable is Retriable.NO and not entry.placement_sensitive:
            return Categorization(entry=entry, resolvable=False,
                                  explanation=f"{entry.failure_type}: "
                                              f"non-retriable user failure")

        cat = Categorization(entry=entry, resolvable=True)
        if entry.failure_type in ("resource_starvation", "ulimit_exceeded"):
            self._analyze_resources(record, report, cat)
        elif entry.failure_type == "env_mismatch":
            self._analyze_environment(record, report, cat)
        elif entry.failure_type in ("hardware_shutdown", "heartbeat_lost"):
            cat.denylist_node = report.node is not None
            cat.explanation = f"environment failure on {report.node}: denylist node"
        elif entry.failure_type in ("worker_lost",):
            cat.restart_component = f"worker:{report.node}" if report.node else None
            cat.explanation = "worker died: restart workers, retry elsewhere"
        elif entry.failure_type in ("manager_loss", "monitor_loss"):
            cat.restart_component = f"manager:{report.node}" if report.node else "manager:"
            cat.explanation = "framework component lost: restart + retry"
        elif entry.failure_type == "pilot_init_failure":
            cat.denylist_node = report.node is not None
            cat.explanation = "pilot init failed: avoid node, retry elsewhere"
        else:
            cat.explanation = f"{entry.failure_type}: retriable ({entry.default_action})"

        # --- fail-fast heuristics (§VI-B) ---------------------------------
        if self._should_fail_fast(record, report, cat):
            cat.resolvable = False
        return cat

    # ------------------------------------------------------------------ #
    def _analyze_resources(self, record, report: FailureReport,
                           cat: Categorization) -> None:
        cat.resource_related = True
        req = report.requirements or {}
        need = float(req.get("memory_gb", 0.0))
        cap = float(report.resource_profile.get("node_memory_gb", 0.0))
        in_use = float(report.resource_profile.get("node_mem_in_use_gb", 0.0))
        if cat.entry.failure_type == "ulimit_exceeded":
            need_files = int(req.get("open_files", 0))
            cat.suggested_overrides = {}
            cat.explanation = (f"ulimit exceeded (needs ~{need_files} fds): "
                               f"retry on node with higher ulimit")
            cat.required_memory_gb = need
            return
        if cap and need > cap:
            # true capacity mismatch: no retry on this class of node can work
            cat.required_memory_gb = need
            cat.explanation = (f"resource starvation: task needs {need}GB, node "
                               f"capacity {cap}GB -> retry on larger-memory node")
        elif cap and need <= cap and in_use > 0:
            # transient contention: the node could fit the task when idle
            cat.required_memory_gb = need
            cat.retry_delay_s = 0.1
            cat.in_place_ok = True
            cat.explanation = (f"transient contention: {in_use:.1f}GB in use of "
                               f"{cap}GB -> retry with backoff (same node ok)")
        else:
            # no profile: be conservative, request feasibility-aware placement
            cat.required_memory_gb = need
            cat.explanation = "resource starvation (no profile): retry feasibly"

    def _analyze_environment(self, record, report: FailureReport,
                             cat: Categorization) -> None:
        missing = tuple(getattr(report.exception, "missing_packages", ()) or ())
        if not missing and report.message:
            # parse "No module named 'x'" manifestations
            msg = report.message
            if "No module named" in msg:
                mod = msg.split("No module named")[-1].strip().strip("'\" ")
                missing = (mod,) if mod else ()
        req_pkgs = tuple(report.requirements.get("packages", ()) or ())
        cat.required_packages = tuple(sorted(set(missing) | set(req_pkgs)))
        cat.explanation = (f"environment mismatch: node lacks "
                           f"{list(missing) or list(req_pkgs)} -> retry on node "
                           f"with matching environment (pip-freeze match)")

    # ------------------------------------------------------------------ #
    def _should_fail_fast(self, record, report: FailureReport,
                          cat: Categorization) -> bool:
        """Heuristic from §VI-B: error type + retry count + node diversity."""
        attempts = getattr(record, "attempts", [])
        same_err_nodes = {a["node"] for a in attempts
                          if a.get("error") == report.exception_type}
        if report.node:
            same_err_nodes.add(report.node)
        if not cat.placement_sensitive:
            # in-place-retriable failure that keeps happening: give it the
            # full retry budget, no early fail-fast (random seed errors may
            # legitimately take several tries)
            return False
        # placement-sensitive: if it failed identically on enough distinct
        # nodes *of distinct pools* we conclude no placement can fix it
        pools_tried = {a["pool"] for a in attempts
                       if a.get("error") == report.exception_type}
        if report.pool:
            pools_tried.add(report.pool)
        if (len(same_err_nodes) >= self.fail_fast_distinct_nodes
                and len(pools_tried) >= 2):
            cat.explanation += (f" | fail-fast: {report.exception_type} recurred on "
                                f"{len(same_err_nodes)} nodes across "
                                f"{len(pools_tried)} pools")
            return True
        return False
