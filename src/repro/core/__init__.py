"""WRATH core: failure taxonomy, monitoring, categorization, policy, retry.

The paper's contribution (§III–§V) as a composable module: plug
:func:`wrath_retry_handler` into a :class:`~repro.engine.dfk.DataFlowKernel`
(task plane) or into the training supervisor (training plane).

Re-exports are lazy (PEP 562) because ``repro.engine`` depends on
``repro.core.failures`` while ``repro.core.retry``/``policy`` depend on
``repro.engine`` — laziness breaks the package-init cycle.
"""
from __future__ import annotations

_EXPORTS = {
    # failures
    "Layer": "repro.core.failures",
    "Retriable": "repro.core.failures",
    "DetectionStrategy": "repro.core.failures",
    "FailureReport": "repro.core.failures",
    "WrathFailure": "repro.core.failures",
    "MonitorLossError": "repro.core.failures",
    "ManagerLossError": "repro.core.failures",
    "WorkerLostError": "repro.core.failures",
    "TaskCancelledError": "repro.core.failures",
    "DependencyError": "repro.core.failures",
    "ResourceStarvationError": "repro.core.failures",
    "UlimitExceededError": "repro.core.failures",
    "PilotJobInitError": "repro.core.failures",
    "HardwareShutdownError": "repro.core.failures",
    "EnvironmentMismatchError": "repro.core.failures",
    "HeartbeatLostError": "repro.core.failures",
    "RandomSeedError": "repro.core.failures",
    "NumericalDivergenceError": "repro.core.failures",
    # taxonomy
    "DEFAULT_FTL": "repro.core.taxonomy",
    "FailureTaxonomyLibrary": "repro.core.taxonomy",
    "TaxonomyEntry": "repro.core.taxonomy",
    "TABLE_I": "repro.core.taxonomy",
    # monitoring
    "MonitoringDatabase": "repro.core.monitoring",
    "StreamingStats": "repro.core.monitoring",
    "NodeHealth": "repro.core.monitoring",
    "TemplateProfile": "repro.core.monitoring",
    "Radio": "repro.core.monitoring",
    "InProcRadio": "repro.core.monitoring",
    "TCPRadio": "repro.core.monitoring",
    "TCPRadioServer": "repro.core.monitoring",
    "SystemMonitoringAgent": "repro.core.monitoring",
    "TaskMonitoringAgent": "repro.core.monitoring",
    # categorization / retry / policy
    "Categorization": "repro.core.categorization",
    "FailureCategorizationEngine": "repro.core.categorization",
    "HierarchicalRetryPlanner": "repro.core.retry",
    "Placement": "repro.core.retry",
    "ResiliencePolicyEngine": "repro.core.policy",
    "wrath_retry_handler": "repro.core.policy",
    # proactive resilience plane
    "ProactiveConfig": "repro.core.proactive",
    "ProactiveDecision": "repro.core.proactive",
    "ProactiveSentinel": "repro.core.proactive",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    import importlib

    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module 'repro.core' has no attribute {name!r}")
    return getattr(importlib.import_module(mod), name)


def __dir__():
    return __all__
