"""Hierarchical retry planner (paper §V-B).

Implements the four-rung retry ladder:

1. retry according to the **resource requirements** provided by the failure
   categorization engine (corrected placement within the current pool);
2. retry on a **different node of the same resource pool**;
3. retry where the task has **historically succeeded** most frequently;
4. retry on a **different resource pool**.

The planner is feasibility-aware: a candidate node must satisfy the task's
(possibly corrected) resource requirements, must be healthy, must not be
denylisted, and — for placement-sensitive failures — must not be a node on
which this task already failed with the same error.

Each rung expresses its placement through the engine's
:class:`~repro.engine.scheduler.Scheduler` when one is provided (via
``SchedulingContext.scheduler``): the rung computes the *feasible candidate
set* and the scheduler picks within it, so retries inherit the engine's
load-/history-awareness.  Without a scheduler the first candidate in pool
order wins (legacy behaviour).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.categorization import Categorization
from repro.core.failures import FailureReport
from repro.engine.cluster import Cluster, Node
from repro.engine.task import ResourceSpec


@dataclass
class Placement:
    pool: str
    node: str | None
    rung: int
    reason: str


class HierarchicalRetryPlanner:
    def __init__(self, cluster: Cluster, monitor=None):
        self.cluster = cluster
        self.monitor = monitor

    # ------------------------------------------------------------------ #
    def plan(self, record, report: FailureReport, cat: Categorization,
             denylist: set[str], scheduler=None) -> Placement | None:
        spec = self._effective_spec(record, cat)
        failed_nodes = {a["node"] for a in record.attempts if not a["ok"]}
        if report.node:
            failed_nodes.add(report.node)
        home_pool = report.pool or (record.attempts[-1]["pool"] if record.attempts else None)

        def ok(node: Node, *, allow_failed_nodes: bool) -> bool:
            if not node.healthy or node.name in denylist:
                return False
            if not allow_failed_nodes and node.name in failed_nodes:
                return False
            sat, _ = node.satisfies(spec)
            return sat

        def choose(candidates: list[Node], pool=None) -> Node | None:
            """Rung placement goes through the engine scheduler when bound."""
            if not candidates:
                return None
            if scheduler is not None:
                picked = scheduler.select(record, candidates, pool=pool)
                if picked is not None:
                    return picked
            return candidates[0]

        # Rung 1: corrected-requirements placement inside the home pool.
        # Meaningful when the categorizer adjusted requirements or when the
        # failure was transient contention (same node may be fine once idle).
        if home_pool and home_pool in self.cluster.pools:
            pool = self.cluster.pools[home_pool]
            allow_same = not cat.placement_sensitive
            node = choose([n for n in pool.nodes
                           if ok(n, allow_failed_nodes=allow_same)], pool)
            if node is not None:
                return Placement(home_pool, node.name, 1,
                                 "rung1: requirement-aware retry in home pool")

        # Rung 2: a different node of the same pool (even one we have not
        # profiled), skipping nodes this task already failed on.
        if home_pool and home_pool in self.cluster.pools:
            pool = self.cluster.pools[home_pool]
            node = choose([n for n in pool.nodes if n.name not in failed_nodes
                           and ok(n, allow_failed_nodes=True)], pool)
            if node is not None:
                return Placement(home_pool, node.name, 2,
                                 "rung2: different node, same pool")

        # Rung 3: historically most-successful node for this task template.
        if self.monitor is not None:
            best = self.monitor.best_historical_node(record.name, exclude=failed_nodes)
            if best:
                node = self.cluster.find_node(best)
                if node is not None and ok(node, allow_failed_nodes=False):
                    return Placement(node.pool.name if node.pool else home_pool or "?",
                                     best, 3, "rung3: historically successful node")

        # Rung 4: a different resource pool, preferring pools with the best
        # historical success rate for this task template.
        pools = [p for name, p in self.cluster.pools.items() if name != home_pool]
        if self.monitor is not None:
            hist = self.monitor.pool_history(record.name)
            pools.sort(key=lambda p: hist.get(p.name).success_rate
                       if hist.get(p.name) else 0.0, reverse=True)
        for pool in pools:
            node = choose([n for n in pool.nodes
                           if ok(n, allow_failed_nodes=False)], pool)
            if node is not None:
                return Placement(pool.name, node.name, 4,
                                 f"rung4: different pool {pool.name!r}")
        # last resort: any feasible node anywhere, even previously failed,
        # for non-placement-sensitive failures (pure re-execution semantics)
        if not cat.placement_sensitive:
            for pool in self.cluster.pools.values():
                node = choose([n for n in pool.nodes
                               if ok(n, allow_failed_nodes=True)], pool)
                if node is not None:
                    return Placement(pool.name, node.name, 1,
                                     "rung1: re-execute (transient failure)")
        return None

    # ------------------------------------------------------------------ #
    def _effective_spec(self, record, cat: Categorization) -> ResourceSpec:
        d = record.effective_resources().asdict()
        if cat.suggested_overrides:
            d.update(cat.suggested_overrides)
        if cat.required_memory_gb:
            d["memory_gb"] = max(d["memory_gb"], cat.required_memory_gb)
        if cat.required_packages:
            d["packages"] = sorted(set(d["packages"]) | set(cat.required_packages))
        d["packages"] = tuple(d["packages"])
        return ResourceSpec(**d)
