"""Continuous (dynamic) batching: slot-structured decode state per replica.

The saxml servable-model idiom: each replica runs ONE padded decode
program at a fixed batch size (``max_batch``).  The program's cost is set
by the padding, not the occupancy, so the throughput lever is *slot
utilization*: a finished request vacates its slot at the step boundary
and the next queued request moves in immediately — no waiting for the
rest of the batch, no head-of-line blocking behind the longest request.

Two decode backends share the slot protocol:

* :class:`JaxDecodeBackend` — the real model: one device-resident KV
  cache per replica sized ``(max_batch, max_len)``, one jitted
  ``decode_step`` program reused every step (ring-buffer cache, so the
  program never recompiles as requests come and go).  A request joining
  mid-flight is teacher-forced through its prompt (plus any tokens
  recovered from a lost replica) inside the shared program — the
  reproduction-scale stand-in for a prefill/generate split.
* :class:`SimDecodeBackend` — the deterministic stand-in for the
  simulation plane: tokens are a pure function of (rid, position), and
  the step *cost* is a modeled virtual duration (scaled by replica
  speed), so sustained-load and chaos scenarios run byte-identically
  under :class:`~repro.sim.VirtualClock` at microsecond wall cost.
"""
from __future__ import annotations

from typing import Any

from repro.core.failures import HardwareShutdownError
from repro.serve.queue import ServeRequest


class ReplicaSlots:
    """Slot occupancy of one replica's in-flight continuous batch."""

    def __init__(self, max_batch: int):
        self.max_batch = max_batch
        self.slots: list[ServeRequest | None] = [None] * max_batch

    def occupants(self) -> list[ServeRequest]:
        return [r for r in self.slots if r is not None]

    def free_count(self) -> int:
        return sum(1 for r in self.slots if r is None)

    def admit(self, req: ServeRequest) -> int:
        """Seat ``req`` in the first free slot; returns the slot index."""
        for i, r in enumerate(self.slots):
            if r is None:
                # (re)start the token feed: teacher-force the prompt plus
                # everything already generated (failover recovery replays
                # recovered tokens, so no generated token is ever lost)
                req.feed = list(req.prompt) + list(req.generated)
                req.pos = 0
                req.status = "running"
                self.slots[i] = req
                return i
        raise RuntimeError("no free slot")  # pragma: no cover - guarded

    def vacate(self, i: int) -> None:
        self.slots[i] = None

    def evict_all(self) -> list[ServeRequest]:
        """Clear every slot (replica loss); returns the evicted requests."""
        out = self.occupants()
        self.slots = [None] * self.max_batch
        return out


def advance_slots(slots: ReplicaSlots, next_tokens: list[int]) -> list[ServeRequest]:
    """Apply one decode step's outputs to every occupied slot.

    ``next_tokens[i]`` is the model's prediction after consuming slot
    ``i``'s current feed token.  While the feed still has tokens ahead
    (teacher-forced prefill/replay) the prediction is discarded; once the
    feed is exhausted the prediction is the next generated token and is
    appended to both ``generated`` and the feed (it is the next step's
    input).  Returns the requests that finished this step.
    """
    finished: list[ServeRequest] = []
    for i, req in enumerate(slots.slots):
        if req is None:
            continue
        tok = next_tokens[i]
        req.pos += 1
        if req.pos >= len(req.feed) and not req.done:
            req.generated.append(int(tok))
            req.feed.append(int(tok))
        if req.done:
            finished.append(req)
            slots.vacate(i)
    return finished


class DecodeBackend:
    """Decode executor protocol shared by the real and simulated planes."""

    name = "base"

    def start_replica(self, replica: Any) -> None:
        """Allocate per-replica decode state (KV cache)."""

    def drop_replica(self, name: str) -> None:
        """Release a (lost or scaled-down) replica's decode state."""

    def step(self, replica: Any, inputs: list[int | None]) -> list[int]:
        """One decode step: per-slot input token (None = free slot) →
        per-slot next token.  Raises
        :class:`~repro.core.failures.HardwareShutdownError` if the
        replica's hardware is down."""
        raise NotImplementedError

    def step_cost_s(self, replica: Any) -> float | None:
        """Modeled step duration (virtual clocks); ``None`` = measure
        wall time (real clocks)."""
        return None


class JaxDecodeBackend(DecodeBackend):
    """Real decode: one padded program + one resident cache per replica."""

    name = "jax"

    def __init__(self, cfg: Any, *, max_batch: int, seed: int = 0,
                 max_len: int = 64):
        import jax

        from repro.models import decode_step, materialize, param_defs

        self.cfg = cfg
        self.max_batch = max_batch
        self.max_len = max_len
        self.params = materialize(param_defs(cfg), jax.random.PRNGKey(seed))
        # ONE program for every replica and every occupancy: shapes are
        # pinned to (max_batch, 1), so slot churn never recompiles
        self._decode = jax.jit(lambda p, c, b: decode_step(p, c, b, cfg))
        self._caches: dict[str, Any] = {}

    def start_replica(self, replica: Any) -> None:
        import jax

        from repro.models import cache_defs, materialize

        self._caches[replica.name] = materialize(
            cache_defs(self.cfg, self.max_batch, self.max_len),
            jax.random.PRNGKey(0))

    def drop_replica(self, name: str) -> None:
        self._caches.pop(name, None)

    def step(self, replica: Any, inputs: list[int | None]) -> list[int]:
        import jax.numpy as jnp
        import numpy as np

        if not replica.healthy:
            raise HardwareShutdownError(
                f"replica {replica.name} is down", node=replica.name)
        cache = self._caches.get(replica.name)
        if cache is None:  # pragma: no cover - start_replica guards this
            raise HardwareShutdownError(
                f"replica {replica.name} has no decode state",
                node=replica.name)
        toks = np.zeros((self.max_batch, 1), np.int32)
        for i, tok in enumerate(inputs):
            if tok is not None:
                toks[i, 0] = tok
        logits, cache = self._decode(self.params, cache,
                                     {"inputs": jnp.asarray(toks)})
        self._caches[replica.name] = cache
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        return [int(nxt[i]) for i in range(self.max_batch)]


class SimDecodeBackend(DecodeBackend):
    """Deterministic simulated decode for ``repro.sim`` serving scenarios.

    The next token is a pure function of the input token and the slot's
    request id, so same-seed scenarios produce byte-identical token
    streams; the modeled step cost is ``step_s`` scaled down by replica
    speed (a 0.25× replica decodes 4× slower), feeding the monitoring
    profile exactly like a measured duration would.
    """

    name = "sim"

    def __init__(self, *, step_s: float = 0.02, vocab_size: int = 256):
        self.step_s = step_s
        self.vocab_size = vocab_size
        self._started: set[str] = set()

    def start_replica(self, replica: Any) -> None:
        self._started.add(replica.name)

    def drop_replica(self, name: str) -> None:
        self._started.discard(name)

    def step(self, replica: Any, inputs: list[int | None]) -> list[int]:
        if not replica.healthy:
            raise HardwareShutdownError(
                f"replica {replica.name} is down", node=replica.name)
        return [((tok * 1009 + 101) % self.vocab_size) if tok is not None
                else 0 for tok in inputs]

    def step_cost_s(self, replica: Any) -> float:
        return self.step_s / max(getattr(replica, "speed", 1.0), 1e-6)
