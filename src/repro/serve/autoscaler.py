"""Replica autoscaling as a resilience policy.

Scaling is a *policy decision*, so it rides the same middleware protocol
as retries and admission: :class:`ReplicaAutoscaler` is a
:class:`~repro.engine.policies.ResiliencePolicy` whose ``on_tick`` hook
reads the monitoring database's ``serve.queue_depth`` gauge trend (the
driver records one sample per tick) and grows or shrinks the serve pool
through the driver's ``add_replica`` / ``remove_replica`` plumbing.

Signals, deliberately simple and observable:

* **grow** — the queue has held above ``grow_queue_per_slot`` requests
  per live decode slot for ``patience`` consecutive gauge samples
  (sustained backlog, not a blip), and the pool is below
  ``max_replicas``.  One replica per decision, followed by a
  ``cooldown_ticks`` quiet period (default = ``patience``) so the next
  decision only ever reads gauge samples taken *after* the last one —
  scaling reacts at tick cadence but never oscillates step-to-step.
* **shrink** — the queue has been empty and at least one replica fully
  idle for ``idle_ticks`` consecutive ticks, and the pool is above
  ``min_replicas``.  Only an idle replica is retired (no in-flight
  request is ever evicted by scale-down).
* **replace** — live replicas dropped below ``min_replicas`` (chaos
  kill, denylist): grow immediately, no patience, because this is
  capacity *repair* rather than load-following.

Every decision is recorded as an ``autoscale_grow`` / ``autoscale_shrink``
system event, so scaling shows up in canonical traces and the chaos
benchmark can assert on it deterministically.
"""
from __future__ import annotations

from typing import Any

from repro.engine.policies import ResiliencePolicy
from repro.engine.retry_api import SchedulingContext

#: gauge the serving driver samples once per policy tick
QUEUE_DEPTH_GAUGE = "serve.queue_depth"


class ReplicaAutoscaler(ResiliencePolicy):
    """Grow/shrink the serve pool from queue-depth and idleness trends."""

    serve_plane_aware = True

    def __init__(self, *, min_replicas: int = 1, max_replicas: int = 8,
                 grow_queue_per_slot: float = 1.0, patience: int = 3,
                 idle_ticks: int = 5, cooldown_ticks: int | None = None):
        if min_replicas < 1:
            raise ValueError("min_replicas must be >= 1")
        if max_replicas < min_replicas:
            raise ValueError("max_replicas must be >= min_replicas")
        self.min_replicas = min_replicas
        self.max_replicas = max_replicas
        self.grow_queue_per_slot = grow_queue_per_slot
        self.patience = patience
        self.idle_ticks = idle_ticks
        # post-decision cooldown: the queue-depth gauge window still holds
        # pre-decision samples on the tick after a scale action, so acting
        # again immediately would react to a world that no longer exists
        # (the documented "never oscillating step-to-step" contract).
        # Defaults to `patience` — exactly long enough for the window to
        # refill with post-decision samples.
        self.cooldown_ticks = patience if cooldown_ticks is None \
            else cooldown_ticks
        self.plane: Any = None
        self._idle_streak = 0
        self._cooldown = 0
        self.grown = 0
        self.shrunk = 0

    def bind(self, plane: Any) -> None:
        self.plane = plane

    def unbind(self) -> None:
        self.plane = None

    # ------------------------------------------------------------------ #
    def on_tick(self, ctx: SchedulingContext) -> None:
        plane = self.plane
        if plane is None:
            return
        live = plane.live_replicas()
        n_live = len(live)

        # capacity repair: below the floor (replica loss) -> grow now
        # (repair ignores cooldown — availability beats smoothing — but
        # arms it, so the next *load-following* decision waits out the
        # stale gauge window)
        if n_live < self.min_replicas:
            if plane.add_replica(reason="below min_replicas") is not None:
                self.grown += 1
            self._idle_streak = 0
            self._cooldown = self.cooldown_ticks
            return

        # cooling down after a scale action: the gauge window still shows
        # the pre-decision world; skip load-following until it refills
        if self._cooldown > 0:
            self._cooldown -= 1
            self._idle_streak = 0
            return

        # sustained backlog -> grow
        if n_live < self.max_replicas and ctx.monitor is not None:
            recent = ctx.monitor.recent_gauges(QUEUE_DEPTH_GAUGE,
                                               k=self.patience)
            slots = max(plane.total_slots(), 1)
            threshold = self.grow_queue_per_slot * slots
            if (len(recent) >= self.patience
                    and all(depth > threshold for _, depth in recent)):
                if plane.add_replica(reason="sustained backlog") is not None:
                    self.grown += 1
                self._idle_streak = 0
                self._cooldown = self.cooldown_ticks
                return

        # sustained idleness -> shrink one idle replica
        idle = [r for r in live if plane.replica_idle(r)]
        if plane.queue.depth() == 0 and idle and n_live > self.min_replicas:
            self._idle_streak += 1
            if self._idle_streak >= self.idle_ticks:
                if plane.remove_replica(idle[-1].name,
                                        reason="sustained idle"):
                    self.shrunk += 1
                self._idle_streak = 0
                self._cooldown = self.cooldown_ticks
        else:
            self._idle_streak = 0

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (f"<ReplicaAutoscaler [{self.min_replicas},"
                f"{self.max_replicas}]>")
