"""Request plane: clock-driven queue + SLO-aware admission control.

The front half of the serving subsystem (queue → admission → batcher →
replicas).  A :class:`ServeRequest` carries its SLO (``deadline_s``,
relative to arrival); the :class:`RequestQueue` stamps arrivals on the
engine's :class:`~repro.engine.events.Clock`, runs every push through the
driver's :class:`~repro.engine.policies.PolicyStack` ``admit_request``
hook, and sheds queued requests whose deadline expires before a decode
slot frees up.

:class:`SLOAdmissionPolicy` is the WRATH fast-fail idea applied to the
request plane: instead of letting a request that *cannot* meet its
deadline consume decode steps and fail late, admission projects its
completion time from the monitoring database's streaming decode-step
profile (p95) plus the current queue backlog, and rejects it at the door.
Rejection is cheap (no slot, no decode step, no KV cache); the client
gets an immediate signal to back off or route elsewhere — the serving
analog of the paper's "immediate termination to avoid wasted compute".
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Any

from repro.engine.events import REAL_CLOCK, Clock
from repro.engine.policies import ResiliencePolicy
from repro.engine.retry_api import SchedulingContext

#: terminal request states
TERMINAL_STATUSES = ("done", "failed", "rejected", "shed")


@dataclass
class ServeRequest:
    """One generation request with its SLO.

    ``deadline_s`` is the request's latency budget relative to arrival
    (``None`` = best-effort, never rejected or shed on time).  Timing
    fields are stamped on the serving driver's clock (virtual-time-exact
    under ``repro.sim``).
    """

    rid: int
    prompt: list[int]
    max_new_tokens: int = 8
    deadline_s: float | None = None
    generated: list[int] = field(default_factory=list)

    # -- lifecycle (stamped by the queue/batcher on the driver's clock) --
    status: str = "new"          # new|queued|running|done|failed|rejected|shed
    reason: str = ""             # rejection/shed/failure detail
    arrival_t: float = 0.0
    first_token_t: float = 0.0
    finish_t: float = 0.0
    #: replica failovers this request survived
    recoveries: int = 0
    # -- batcher slot state (owned by repro.serve.batcher) ---------------
    feed: list[int] = field(default_factory=list, repr=False)
    pos: int = 0
    _rec: Any = field(default=None, repr=False)

    @property
    def steps_total(self) -> int:
        """Decode steps this request needs from (re)admission, derived
        from its replay state: the batcher teacher-forces the prompt plus
        every token recovered from a lost replica (``generated``), then
        decodes the remaining new tokens — the final step both consumes
        the last feed position and emits the last token, hence the -1."""
        remaining_new = self.max_new_tokens - len(self.generated)
        if remaining_new <= 0:
            return 0
        feed_len = len(self.prompt) + len(self.generated)
        return feed_len + remaining_new - 1

    @property
    def steps_remaining(self) -> int:
        """Steps still owed by an *in-flight* slot occupant, from its
        live batcher state (feed position + tokens still to generate).
        Queued requests have no slot state — use :attr:`steps_total`."""
        remaining_new = self.max_new_tokens - len(self.generated)
        if remaining_new <= 0:
            return 0
        return max(len(self.feed) - self.pos, 0) + remaining_new - 1

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens

    @property
    def terminal(self) -> bool:
        return self.status in TERMINAL_STATUSES

    @property
    def latency_s(self) -> float:
        """Arrival→finish latency (0 while not finished)."""
        if not self.finish_t:
            return 0.0
        return max(0.0, self.finish_t - self.arrival_t)

    def deadline_at(self) -> float | None:
        """Absolute clock deadline (None = best-effort)."""
        if self.deadline_s is None:
            return None
        return self.arrival_t + self.deadline_s


class RequestQueue:
    """FIFO admission queue in front of the continuous batcher.

    ``push`` is the admission point: the driver's policy stack gets one
    ``admit_request`` veto per request *before* it is enqueued, and a
    bounded ``capacity`` sheds overflow instead of growing without bound
    (overload must degrade by rejecting cheap, not by queueing forever).
    ``pop_ready`` is the slot-refill point: requests whose deadline has
    already passed are shed there — a request that waited too long must
    not waste the decode slot it was waiting for.
    """

    def __init__(self, *, clock: Clock | None = None,
                 capacity: int | None = None,
                 monitor: Any = None):
        self.clock = clock or REAL_CLOCK
        self.capacity = capacity
        self.monitor = monitor
        self._items: deque[ServeRequest] = deque()
        self.stats = {"arrived": 0, "admitted": 0, "rejected": 0, "shed": 0}
        self.peak_depth = 0

    def __len__(self) -> int:
        return len(self._items)

    def depth(self) -> int:
        return len(self._items)

    def queued(self) -> tuple[ServeRequest, ...]:
        """Snapshot of waiting requests (head first)."""
        return tuple(self._items)

    def _event(self, event: str, req: ServeRequest, **data: Any) -> None:
        if self.monitor is not None:
            self.monitor.record_system_event(event, rid=req.rid, **data)

    def push(self, req: ServeRequest, *, stack: Any = None,
             ctx: SchedulingContext | None = None,
             front: bool = False) -> bool:
        """Admit ``req`` (stamping arrival) or reject it; returns admitted.

        ``front=True`` requeues a recovered in-flight request at the head
        (failover path — it already waited its turn once).  Recovered
        requests skip admission: the policy already decided to retry them.
        """
        now = self.clock.now()
        if not front:
            req.arrival_t = now
            self.stats["arrived"] += 1
            reason = None
            if self.capacity is not None and len(self._items) >= self.capacity:
                reason = f"queue full ({self.capacity})"
            elif stack is not None and ctx is not None:
                reason = stack.admit_request(req, ctx)
            if reason is not None:
                req.status = "rejected"
                req.reason = reason
                req.finish_t = now
                self.stats["rejected"] += 1
                self._event("request_rejected", req, reason=reason)
                return False
            self.stats["admitted"] += 1
            self._event("request_admitted", req,
                        depth=len(self._items),
                        deadline_s=req.deadline_s)
        req.status = "queued"
        if front:
            self._items.appendleft(req)
        else:
            self._items.append(req)
        self.peak_depth = max(self.peak_depth, len(self._items))
        return True

    def pop_ready(self, n: int) -> list[ServeRequest]:
        """Up to ``n`` requests for free slots, shedding expired ones."""
        out: list[ServeRequest] = []
        now = self.clock.now()
        while self._items and len(out) < n:
            req = self._items.popleft()
            deadline = req.deadline_at()
            if deadline is not None and now > deadline:
                req.status = "shed"
                req.reason = (f"deadline blown in queue "
                              f"(+{now - deadline:.3f}s)")
                req.finish_t = now
                self.stats["shed"] += 1
                self._event("request_shed", req, reason="deadline")
                continue
            out.append(req)
        return out

    def drain(self, reason: str = "shutdown") -> list[ServeRequest]:
        """Shed everything still queued (horizon/shutdown path)."""
        out = []
        now = self.clock.now()
        while self._items:
            req = self._items.popleft()
            req.status = "shed"
            req.reason = reason
            req.finish_t = now
            self.stats["shed"] += 1
            self._event("request_shed", req, reason=reason)
            out.append(req)
        return out


class SLOAdmissionPolicy(ResiliencePolicy):
    """Deadline-aware admission: reject requests that cannot make their SLO.

    Projected completion = estimated queue delay + the request's own
    service time, both derived from the monitoring database's streaming
    ``decode_step`` latency profile (p95 once ``min_samples`` steps have
    been observed, ``default_step_s`` before that).  Queue delay models
    the backlog draining through every live decode slot at that step
    cadence.  If the projection overshoots the deadline, the request is
    rejected *at admission* — before it holds a queue position, a batch
    slot or a single decode step.

    ``safety`` scales the projection (>1 rejects earlier, trading
    goodput for tail-latency headroom).  Installed automatically by
    :class:`~repro.serve.driver.WrathServeDriver` when admission control
    is enabled; composes with any user stack (first veto wins).
    """

    serve_plane_aware = True

    def __init__(self, *, default_step_s: float = 0.02,
                 min_samples: int = 3, safety: float = 1.0):
        self.default_step_s = default_step_s
        self.min_samples = min_samples
        self.safety = safety
        self.plane: Any = None

    def bind(self, plane: Any) -> None:
        self.plane = plane

    def unbind(self) -> None:
        self.plane = None

    # ------------------------------------------------------------------ #
    def step_estimate_s(self, monitor: Any) -> float:
        """p95 decode-step latency from the streaming profile."""
        if monitor is not None:
            stats = monitor.duration_stats("decode_step")
            if stats is not None and stats.n >= self.min_samples:
                return stats.p95
        return self.default_step_s

    def admit_request(self, req: Any, ctx: SchedulingContext) -> str | None:
        deadline = getattr(req, "deadline_s", None)
        if deadline is None:
            return None
        step_s = self.step_estimate_s(ctx.monitor)
        service_s = req.steps_total * step_s
        queued = backlog_steps = 0
        slots: int | None = None
        if self.plane is not None:
            queued = self.plane.queue.depth()
            slots = self.plane.total_slots()
            backlog_steps = self.plane.backlog_steps()
        if slots == 0:
            # total replica outage: zero live decode slots means nothing
            # drains and no completion time can be projected — any
            # deadline is infeasible until capacity returns (the old
            # max(slots, 1) floor projected one phantom slot and admitted
            # everything mid-outage)
            return ("SLO infeasible: no live decode slots (replica "
                    f"outage); deadline {deadline:.3f}s cannot be met")
        queue_delay_s = (backlog_steps * step_s / (slots or 1)
                         if queued or backlog_steps else 0.0)
        projected = self.safety * (queue_delay_s + service_s)
        if projected > deadline:
            return (f"SLO infeasible: projected {projected:.3f}s "
                    f"(queue {queue_delay_s:.3f}s + service {service_s:.3f}s)"
                    f" > deadline {deadline:.3f}s")
        return None

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"<SLOAdmissionPolicy safety={self.safety}>"
