"""Production serving plane: queue -> admission -> batcher -> replicas."""
from repro.serve.autoscaler import QUEUE_DEPTH_GAUGE, ReplicaAutoscaler
from repro.serve.batcher import (DecodeBackend, JaxDecodeBackend,
                                 ReplicaSlots, SimDecodeBackend,
                                 advance_slots)
from repro.serve.driver import Request, ServeReport, WrathServeDriver
from repro.serve.queue import (RequestQueue, ServeRequest,
                               SLOAdmissionPolicy)

__all__ = [
    "WrathServeDriver", "Request", "ServeReport",
    "ServeRequest", "RequestQueue", "SLOAdmissionPolicy",
    "ReplicaAutoscaler", "QUEUE_DEPTH_GAUGE",
    "DecodeBackend", "JaxDecodeBackend", "SimDecodeBackend",
    "ReplicaSlots", "advance_slots",
]
