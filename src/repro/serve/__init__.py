from repro.serve.driver import Request, ServeReport, WrathServeDriver

__all__ = ["WrathServeDriver", "Request", "ServeReport"]
