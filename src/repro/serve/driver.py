"""WRATH-supervised serving driver: static batches or a continuous plane.

Serving plane of the reproduction: requests are batched and decoded
token-by-token on a pool of *replicas* (virtual serving hosts, an
``engine.cluster`` pool).  WRATH supervises replica health exactly as it
supervises tasks: a replica lost mid-decode (environment layer) is
denylisted and its in-flight requests are retried on a healthy replica —
generated tokens are replayed by teacher-forcing, so none are lost
(atomic-step semantics, the serving analog of the paper's atomic tasks).

Two serving modes share the replica pool, scheduler, policy stack and
monitoring plumbing:

* :meth:`WrathServeDriver.serve` — the **static batcher** baseline: form
  a batch, run it to the *longest* member's completion, then form the
  next one.  Simple, synchronous, and pays head-of-line blocking twice
  (short requests wait for long slot-mates; the queue waits for the
  whole batch).
* :meth:`WrathServeDriver.serve_continuous` — the **production plane**:
  a clock-driven :class:`~repro.serve.queue.RequestQueue` feeds replica
  slots at every step boundary (continuous batching — a finished request
  vacates its slot and the next queued request takes it immediately),
  the policy stack's ``admit_request`` hook applies SLO-aware admission
  control before a request ever holds a slot, and a periodic policy tick
  lets a :class:`~repro.serve.autoscaler.ReplicaAutoscaler` grow or
  shrink the pool from monitored queue-depth trends.

All time flows through an injected :class:`~repro.engine.events.Clock`
(default :data:`~repro.engine.events.REAL_CLOCK`).  With a
:class:`repro.sim.VirtualClock` and the simulated decode backend the
whole plane — arrivals, decode steps, chaos faults, deadlines, autoscale
ticks — executes deterministically inline via the event loop's
``run_until``: a minute of traffic replays byte-identically in
milliseconds.

Replica selection goes through the pluggable
:class:`~repro.engine.scheduler.Scheduler` interface
(``WrathServeDriver(scheduler=...)``), and failover decisions flow
through the composable :class:`~repro.engine.policies.PolicyStack`
(``policy=...``, default a single
:class:`~repro.engine.policies.WrathPolicy`).  The serving loop drives
the decision subset of the policy protocol — ``on_submit``,
``on_failure``, ``review_decision``, ``admit_request``, ``on_tick``.
Engine-execution policies (``replicate``'s racing copies) need the
DataFlowKernel's copy machinery and are inert here.
"""
from __future__ import annotations

import dataclasses

from repro.core import MonitoringDatabase
from repro.core.failures import FailureReport, HardwareShutdownError
from repro.engine.cluster import Cluster, Node, ResourcePool
from repro.engine.events import REAL_CLOCK, Clock, EventLoop
from repro.engine.policies import PolicyStack, WrathPolicy, normalize_policies
from repro.engine.retry_api import Action, RetryDecision, SchedulingContext
from repro.engine.scheduler import RoundRobinScheduler, Scheduler
from repro.engine.task import ResourceSpec, TaskDef, new_task_record
from repro.models.config import ModelConfig
from repro.serve.batcher import (DecodeBackend, JaxDecodeBackend,
                                 ReplicaSlots, SimDecodeBackend,
                                 advance_slots)
from repro.serve.queue import RequestQueue, ServeRequest, SLOAdmissionPolicy

#: back-compat alias — the request type grew SLO fields and moved to
#: repro.serve.queue
Request = ServeRequest


def _quantile(sorted_vals: list[float], q: float) -> float:
    """Nearest-rank quantile of an ascending-sorted sample."""
    if not sorted_vals:
        return 0.0
    i = min(len(sorted_vals) - 1, max(0, int(q * len(sorted_vals))))
    return sorted_vals[i]


@dataclasses.dataclass
class ServeReport:
    completed: int
    failed: int
    tokens_generated: int
    recoveries: list[dict]
    denylisted: list[str]
    wall_s: float
    # per-replica health snapshot from the monitoring database's streaming
    # profiles (success rate + decode-duration mean/p95)
    replica_health: dict[str, dict] = dataclasses.field(default_factory=dict)
    # -- continuous-plane extensions (zero in static mode) ---------------
    rejected: int = 0            # refused at admission (no decode steps)
    shed: int = 0                # expired in queue / drained at horizon
    decode_steps: int = 0
    queue_peak: int = 0
    p50_s: float = 0.0           # arrival -> finish latency percentiles
    p99_s: float = 0.0
    autoscaled_up: int = 0
    autoscaled_down: int = 0
    replicas_final: int = 0

    @property
    def tokens_per_s(self) -> float:
        return self.tokens_generated / max(self.wall_s, 1e-9)

    @property
    def requests_per_s(self) -> float:
        return self.completed / max(self.wall_s, 1e-9)

    @property
    def shed_rate(self) -> float:
        """Fraction of arrivals refused or expired before completion."""
        total = self.completed + self.failed + self.rejected + self.shed
        return (self.rejected + self.shed) / max(total, 1)


class WrathServeDriver:
    """Replica-pool serving with WRATH failover, admission and autoscale.

    ``decode`` selects the execution backend: ``"jax"`` (default, the
    real model via :class:`~repro.serve.batcher.JaxDecodeBackend`),
    ``"sim"`` (modeled step costs, deterministic tokens — pairs with a
    :class:`repro.sim.VirtualClock`), or any
    :class:`~repro.serve.batcher.DecodeBackend` instance.

    ``admission=True`` installs an
    :class:`~repro.serve.queue.SLOAdmissionPolicy` after the user stack
    (pass an instance to tune it).  Policies with a true
    ``serve_plane_aware`` attribute (the admission policy, the
    autoscaler) are bound to this driver at construction.
    """

    def __init__(self, cfg: ModelConfig, *, n_replicas: int = 3,
                 max_batch: int = 4, seed: int = 0,
                 scheduler: Scheduler | None = None,
                 policy: object = None,
                 health_gate: bool = True,
                 clock: Clock | None = None,
                 monitor: MonitoringDatabase | None = None,
                 decode: str | DecodeBackend = "jax",
                 admission: object = None,
                 queue_capacity: int | None = None,
                 max_len: int = 64):
        self.cfg = cfg
        self.max_batch = max_batch
        self.health_gate = health_gate
        self.clock = clock or REAL_CLOCK
        nodes = [Node(f"replica{i}", workers_per_node=1)
                 for i in range(n_replicas)]
        self._replica_seq = n_replicas
        self.cluster = Cluster([ResourcePool("serve", nodes)])
        self.monitor = monitor if monitor is not None else \
            MonitoringDatabase(clock=clock)
        # policy=None -> WRATH default; an explicit empty stack ([]) is a
        # valid choice meaning Parsl-style baseline retry only
        stack = tuple(normalize_policies(policy) if policy is not None
                      else (WrathPolicy(),))
        if admission is True:
            stack += (SLOAdmissionPolicy(),)
        elif admission:
            stack += (admission,)
        self.policies = PolicyStack(stack, on_error=self._policy_error)
        self.scheduler = (scheduler or RoundRobinScheduler()).bind(
            cluster=self.cluster, monitor=self.monitor)
        self.denylist: set[str] = set()
        if isinstance(decode, DecodeBackend):
            self.backend = decode
        elif decode == "sim":
            self.backend = SimDecodeBackend()
        else:
            self.backend = JaxDecodeBackend(cfg, max_batch=max_batch,
                                            seed=seed, max_len=max_len)
        # -- continuous plane state ------------------------------------
        self.queue = RequestQueue(clock=self.clock, capacity=queue_capacity,
                                  monitor=self.monitor)
        self.events: EventLoop | None = None
        self._slots: dict[str, ReplicaSlots] = {}
        for n in nodes:
            self.backend.start_replica(n)
            self._slots[n.name] = ReplicaSlots(max_batch)
        self._step_scheduled: set[str] = set()
        self._requests: list[ServeRequest] = []
        self.recoveries: list[dict] = []
        self.decode_steps = 0
        self.autoscaled_up = 0
        self.autoscaled_down = 0
        # bind serve-plane-aware policies (admission, autoscaler)
        for p in self.policies.policies:
            if getattr(p, "serve_plane_aware", False):
                p.bind(self)

    # -- lifecycle ------------------------------------------------------ #
    def __enter__(self) -> "WrathServeDriver":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()

    def shutdown(self) -> None:
        if self.events is not None:
            self.events.stop()
            self.events.join(timeout=2.0)
            self.events = None

    def _ensure_loop(self) -> EventLoop:
        if self.events is None:
            self.events = EventLoop("serve-events", clock=self.clock,
                                    on_error=self._loop_error).start()
        return self.events

    def _loop_error(self, name: str, err: BaseException) -> None:
        self.monitor.record_system_event(
            "serve_event_error", source=name, error=type(err).__name__,
            message=str(err))

    def _policy_error(self, hook: str, err: BaseException) -> None:
        """Swallowed policy-hook exceptions stay visible as system events."""
        self.monitor.record_system_event(
            "policy_error", hook=hook, error=type(err).__name__,
            message=str(err))

    def _ctx(self) -> SchedulingContext:
        return SchedulingContext(cluster=self.cluster, monitor=self.monitor,
                                 denylist=self.denylist, default_pool="serve",
                                 scheduler=self.scheduler, clock=self.clock)

    # -- replica pool ---------------------------------------------------- #
    def replicas(self) -> list[Node]:
        return [n for n in self.cluster.pools["serve"].nodes
                if n.healthy and n.name not in self.denylist]

    def live_replicas(self) -> list[Node]:
        """Replicas with decode state attached (the continuous plane's
        serving set) — healthy, not denylisted, not retired."""
        return [n for n in self.replicas() if n.name in self._slots]

    def total_slots(self) -> int:
        return sum(self._slots[n.name].max_batch
                   for n in self.live_replicas())

    def backlog_steps(self) -> int:
        """Decode steps owed to queued + in-flight requests (admission's
        queue-delay estimator).  Queued requests are counted from their
        replay state (failover requeues owe prompt + recovered tokens),
        in-flight occupants from their live slot state — both via the
        request's own step accounting, which ends on the step that emits
        the final token (the old inline formula double-counted that
        boundary step for every occupant)."""
        steps = sum(r.steps_total for r in self.queue.queued())
        for n in self.live_replicas():
            steps += sum(r.steps_remaining
                         for r in self._slots[n.name].occupants())
        return steps

    def replica_idle(self, node: Node) -> bool:
        slots = self._slots.get(node.name)
        return (slots is not None and not slots.occupants()
                and node.name not in self._step_scheduled)

    def add_replica(self, *, reason: str = "") -> Node | None:
        """Grow the serve pool by one replica (autoscaler/ops entry)."""
        name = f"replica{self._replica_seq}"
        self._replica_seq += 1
        node = Node(name, workers_per_node=1)
        self.cluster.pools["serve"].add_node(node)
        self.backend.start_replica(node)
        self._slots[name] = ReplicaSlots(self.max_batch)
        self.autoscaled_up += 1
        self.monitor.record_system_event(
            "autoscale_grow", node=name, reason=reason,
            replicas=len(self.live_replicas()))
        if self.events is not None:
            self.events.call_soon(self._pump, name="pump")
        return node

    def remove_replica(self, name: str, *, reason: str = "") -> bool:
        """Retire an *idle* replica (refuses while requests are in
        flight — scale-down never evicts work)."""
        slots = self._slots.get(name)
        if slots is None or slots.occupants() or name in self._step_scheduled:
            return False
        del self._slots[name]
        self.backend.drop_replica(name)
        pool = self.cluster.pools["serve"]
        pool.nodes = [n for n in pool.nodes if n.name != name]
        self.autoscaled_down += 1
        self.monitor.record_system_event(
            "autoscale_shrink", node=name, reason=reason,
            replicas=len(self.live_replicas()))
        return True

    def _pick_replica(self, rec, exclude: str | None = None) -> Node | None:
        """Scheduler-driven replica selection over the healthy serve pool.

        With ``health_gate`` the monitoring database's placement profile is
        consulted first: a replica that has only ever failed batches
        (>= 2 failures, 0 successes) is skipped while healthier candidates
        exist — the serving analog of the proactive plane's "stop placing
        on a node trending toward failure".
        """
        pool = self.cluster.pools["serve"]
        candidates = [n for n in self.replicas() if n.name != exclude]
        if self.health_gate and candidates:
            hist = self.monitor.node_history("decode_batch")

            def suspect(n: Node) -> bool:
                s = hist.get(n.name)
                return s is not None and s.failures >= 2 and s.successes == 0

            vetted = [n for n in candidates if not suspect(n)]
            candidates = vetted or candidates
        return self.scheduler.select(rec, candidates or self.replicas(),
                                     pool=pool)

    def _apply_denylist(self, replica: Node, decision: RetryDecision) -> None:
        """Driver-owned denylisting of a lost replica.

        Historically only :class:`~repro.core.policy.WrathPolicy`'s engine
        updated the denylist (it mutates ``ctx.denylist`` directly), so a
        custom stack — ``policy=[replay(3)]`` — silently kept routing
        retries at the dead replica.  The driver now denylists on the
        *decision*: the replica is down, or the policy explicitly moved
        the work elsewhere.  Guarded so WrathPolicy's own denylist event
        is not duplicated.
        """
        if replica.name in self.denylist:
            return
        moved = bool(decision.target_node
                     and decision.target_node != replica.name)
        if not replica.healthy or moved:
            self.denylist.add(replica.name)
            self.monitor.record_system_event(
                "denylist_add", node=replica.name, source="serve_driver")

    def replica_health(self) -> dict[str, dict]:
        """Streaming-profile health snapshot of every replica."""
        hist = self.monitor.node_history("decode_batch")
        out: dict[str, dict] = {}
        for n in self.cluster.pools["serve"].nodes:
            stats = hist.get(n.name)
            dur = self.monitor.duration_stats("decode_batch", node=n.name)
            out[n.name] = {
                "live": n.healthy and n.name not in self.denylist,
                "batches": stats.total if stats else 0,
                "success_rate": stats.success_rate if stats else None,
                "decode_s_mean": dur.mean if dur else None,
                "decode_s_p95": dur.p95 if dur else None,
            }
        return out

    # ================== static batcher (baseline) ===================== #
    def serve(self, requests: list[ServeRequest], *,
              kill_replica_at: tuple[str, int] | None = None) -> ServeReport:
        """Static batching: fixed batches run to the longest member.

        Optionally kills a replica after N decode calls (chaos hook for
        the failover tests)."""
        t0 = self.clock.now()
        recoveries: list[dict] = []
        completed = failed = tokens = 0
        decode_calls = 0
        queue = list(requests)
        while queue:
            batch_reqs = queue[:self.max_batch]
            queue = queue[len(batch_reqs):]
            # one task record per batch: retry budget and attempt history
            # are tracked across replica failovers of the same batch
            rec = new_task_record(
                TaskDef(lambda: None, "decode_batch", ResourceSpec(), 2),
                (), {}, default_retries=2)
            # full middleware protocol: on_submit lets policies set up
            # per-record state (e.g. deferred replay's budget extension)
            self.policies.on_submit(rec, self._ctx())
            replica = self._pick_replica(rec)
            if replica is None:
                failed += len(batch_reqs)
                for r in batch_reqs:
                    r.status, r.reason = "failed", "no live replica"
                continue
            # a scratch slot frame per batch: static mode never refills a
            # vacated slot, so the batch steps until its longest member
            slots = ReplicaSlots(self.max_batch)
            for r in batch_reqs:
                slots.admit(r)
            batch_t0 = self.clock.now()
            step = 0
            while slots.occupants():
                if kill_replica_at and decode_calls == kill_replica_at[1]:
                    victim = self.cluster.find_node(kill_replica_at[0])
                    if victim is not None:
                        victim.shutdown_hardware()
                inputs = [r.feed[r.pos] if r is not None else None
                          for r in slots.slots]
                try:
                    nxt = self.backend.step(replica, inputs)
                except HardwareShutdownError as err:
                    rec.record_attempt(node=replica.name, pool="serve",
                                       worker="-", ok=False,
                                       error=type(err).__name__,
                                       duration=self.clock.now() - batch_t0,
                                       now=self.clock.time())
                    self.monitor.record_task_placement(
                        "decode_batch", replica.name, "serve", ok=False)
                    report = FailureReport.from_exception(
                        err, task_id=rec.task_id, node=replica.name,
                        pool="serve")
                    decision = self.policies.decide(rec, report, self._ctx())
                    self._apply_denylist(replica, decision)
                    recoveries.append({
                        "replica": replica.name, "step": step,
                        "action": decision.action.value,
                        "rung": decision.rung})
                    survivors = slots.evict_all()
                    if decision.action is Action.FAIL or not self.replicas():
                        failed += len(survivors)
                        for r in survivors:
                            r.status, r.reason = "failed", "replica lost"
                        break
                    rec.retry_count += 1
                    replica = (self.cluster.find_node(decision.target_node)
                               or self._pick_replica(rec,
                                                     exclude=replica.name))
                    if replica is None:
                        failed += len(survivors)
                        for r in survivors:
                            r.status, r.reason = "failed", "no live replica"
                        break
                    # recovery: teacher-forced replay of prompt+generated
                    # on the rescuer — no generated token is lost
                    for r in survivors:
                        r.recoveries += 1
                        slots.admit(r)
                    batch_t0 = self.clock.now()  # rescuer timed from takeover
                    continue
                decode_calls += 1
                cost = self.backend.step_cost_s(replica)
                if cost is not None and self.clock.virtual:
                    self.clock.advance(cost)  # type: ignore[attr-defined]
                for r in advance_slots(slots, nxt):
                    r.status = "done"
                    r.finish_t = self.clock.now()
                    tokens += len(r.generated)
                    completed += 1
                step += 1
            else:
                self.monitor.record_task_placement(
                    "decode_batch", replica.name, "serve", ok=True,
                    duration=self.clock.now() - batch_t0)
        return ServeReport(completed=completed, failed=failed,
                           tokens_generated=tokens, recoveries=recoveries,
                           denylisted=sorted(self.denylist),
                           wall_s=self.clock.now() - t0,
                           replica_health=self.replica_health(),
                           decode_steps=decode_calls,
                           replicas_final=len(self.replicas()))

    # ================== continuous plane =============================== #
    def submit(self, req: ServeRequest) -> bool:
        """Admit one request into the continuous plane; False = rejected.

        Admission (capacity + the policy stack's ``admit_request`` veto)
        happens here — a rejected request never holds a queue position,
        a batch slot, or a decode step.
        """
        self._ensure_loop()
        self._requests.append(req)
        rec = new_task_record(
            TaskDef(lambda: None, "serve_request", ResourceSpec(), 2),
            (), {}, default_retries=2)
        req._rec = rec
        ok = self.queue.push(req, stack=self.policies, ctx=self._ctx())
        if ok:
            self.policies.on_submit(rec, self._ctx())
            self.events.call_soon(self._pump, name="pump")
        return ok

    def _pump(self) -> None:
        """Refill free slots from the queue (the continuous-batching core).

        Runs on the event loop whenever capacity may have appeared: a
        request finished, a replica joined, a request arrived.  Each
        pulled request is placed by the scheduler among replicas that
        currently have a free slot and joins that replica's in-flight
        batch at its next step boundary.
        """
        while True:
            candidates = [n for n in self.live_replicas()
                          if self._slots[n.name].free_count() > 0]
            if not candidates:
                return
            free = sum(self._slots[n.name].free_count() for n in candidates)
            batch = self.queue.pop_ready(free)
            if not batch:
                return
            for req in batch:
                candidates = [n for n in self.live_replicas()
                              if self._slots[n.name].free_count() > 0]
                if not candidates:  # pragma: no cover - free counted above
                    self.queue.push(req, front=True)
                    return
                node = self.scheduler.select(
                    req._rec, candidates, pool=self.cluster.pools["serve"])
                if node is None:
                    node = candidates[0]
                self._slots[node.name].admit(req)
                self._schedule_step(node)

    def _schedule_step(self, node: Node) -> None:
        """Arm the next decode step for ``node`` (one in flight at most)."""
        name = node.name
        if name in self._step_scheduled or name not in self._slots:
            return
        if not self._slots[name].occupants():
            return
        self._step_scheduled.add(name)
        cost = self.backend.step_cost_s(node)
        if cost is None:
            self.events.call_soon(self._step, name, name="decode_step")
        else:
            # the step completes cost seconds from now (modeled decode)
            self.events.call_later(cost, self._step, name,
                                   name="decode_step")

    def _step(self, name: str) -> None:
        """One decode step on one replica: the padded program ticks, every
        occupant advances one token, finished occupants vacate."""
        self._step_scheduled.discard(name)
        node = self.cluster.find_node(name)
        slots = self._slots.get(name)
        if node is None or slots is None:
            return
        occ = slots.occupants()
        if not occ:
            return
        inputs = [r.feed[r.pos] if r is not None else None
                  for r in slots.slots]
        t0 = self.clock.now()
        try:
            nxt = self.backend.step(node, inputs)
        except HardwareShutdownError as err:
            self._on_replica_loss(node, slots, err)
            self._pump()
            return
        cost = self.backend.step_cost_s(node)
        duration = cost if cost is not None else (self.clock.now() - t0)
        self.decode_steps += 1
        # the streaming decode_step profile drives admission's p95 estimate
        self.monitor.record_task_placement("decode_step", name, "serve",
                                           ok=True, duration=duration)
        finished = advance_slots(slots, nxt)
        now = self.clock.now()
        for req in occ:
            if req.generated and not req.first_token_t:
                req.first_token_t = now
        for req in finished:
            req.status = "done"
            req.finish_t = now
            if req._rec is not None:
                req._rec.record_attempt(node=name, pool="serve", worker="-",
                                        ok=True, error=None,
                                        duration=req.latency_s,
                                        now=self.clock.time())
            self.monitor.record_system_event(
                "request_done", rid=req.rid, node=name,
                latency_s=round(req.latency_s, 6))
        if finished:
            self._pump()
        self._schedule_step(node)

    def _on_replica_loss(self, node: Node, slots: ReplicaSlots,
                         err: HardwareShutdownError) -> None:
        """Failover: evict occupants, consult the policy stack per request,
        requeue survivors at the head (they already waited their turn)."""
        evicted = slots.evict_all()
        self._slots.pop(node.name, None)
        self.backend.drop_replica(node.name)
        self.monitor.record_system_event("replica_lost", node=node.name,
                                         in_flight=len(evicted))
        now = self.clock.now()
        for req in evicted:
            rec = req._rec
            rec.record_attempt(node=node.name, pool="serve", worker="-",
                               ok=False, error=type(err).__name__,
                               duration=now - req.arrival_t,
                               now=self.clock.time())
            self.monitor.record_task_placement("decode_step", node.name,
                                               "serve", ok=False)
            report = FailureReport.from_exception(
                err, task_id=rec.task_id, node=node.name, pool="serve")
            decision = self.policies.decide(rec, report, self._ctx())
            self._apply_denylist(node, decision)
            self.recoveries.append({
                "replica": node.name, "rid": req.rid,
                "action": decision.action.value, "rung": decision.rung})
            if (decision.action is Action.FAIL
                    or rec.retry_count >= rec.max_retries
                    or not self.live_replicas()):
                req.status = "failed"
                req.reason = f"replica {node.name} lost"
                req.finish_t = now
                continue
            rec.retry_count += 1
            req.recoveries += 1
            self.queue.push(req, front=True)

    def _tick(self) -> None:
        """Periodic policy tick: sample serve gauges, run ``on_tick``."""
        slots_total = self.total_slots()
        occupied = sum(len(self._slots[n.name].occupants())
                       for n in self.live_replicas())
        self.monitor.record_gauge("serve.queue_depth", self.queue.depth())
        self.monitor.record_gauge("serve.slot_occupancy",
                                  occupied / max(slots_total, 1))
        self.policies.on_tick(self._ctx())

    def inject_fault(self, kind: str, name: str) -> None:
        """Chaos hook: ``kill`` or ``restore`` a replica by name."""
        node = self.cluster.find_node(name)
        if node is None:
            return
        if kind == "kill":
            node.shutdown_hardware()
            self.monitor.record_system_event("fault_injected", node=name,
                                             kind="kill")
            slots = self._slots.get(name)
            if slots is not None and not slots.occupants():
                # idle victim: no pending step will trip over it, so
                # retire its decode state directly
                self._slots.pop(name, None)
                self.backend.drop_replica(name)
                self.monitor.record_system_event("replica_lost", node=name,
                                                 in_flight=0)
        elif kind == "restore":
            node.restore_hardware()
            self.denylist.discard(name)
            if name not in self._slots:
                self.backend.start_replica(node)
                self._slots[name] = ReplicaSlots(self.max_batch)
            self.monitor.record_system_event("fault_injected", node=name,
                                             kind="restore")
            if self.events is not None:
                self.events.call_soon(self._pump, name="pump")

    def serve_continuous(self, requests: list[ServeRequest], *,
                         arrivals: list[float] | None = None,
                         faults: list[tuple[float, str, str]] | None = None,
                         horizon: float = 60.0,
                         tick_period: float = 0.25,
                         drain_s: float = 0.0) -> ServeReport:
        """Run the continuous plane over a request window.

        ``arrivals[i]`` is request i's arrival offset in seconds from the
        start (default: everything arrives at t=0); ``faults`` are
        ``(offset_s, "kill"|"restore", replica_name)`` chaos events.  The
        call returns when every request in the window is terminal or the
        ``horizon`` elapses (stragglers are then shed/failed, never left
        dangling); ``drain_s`` keeps the policy tick running that much
        longer after the last request settles, giving the autoscaler its
        idle window to scale back down.  Under a virtual clock the whole
        window executes deterministically inline.
        """
        events = self._ensure_loop()
        t_start = self.clock.now()
        window = list(requests)
        for i, req in enumerate(window):
            at = arrivals[i] if arrivals else 0.0
            events.call_at(t_start + at, self.submit, req, name="arrival")
        for at, kind, victim in faults or ():
            events.call_at(t_start + at, self.inject_fault, kind, victim,
                           name="fault")
        tick = events.schedule_periodic(tick_period, self._tick,
                                        name="policy_tick")

        def settled() -> bool:
            return all(r.terminal for r in window)

        if self.clock.virtual:
            events.run_until(settled, deadline=t_start + horizon)
            if drain_s > 0:
                events.run_until(deadline=self.clock.now() + drain_s)
        else:
            while not settled() and self.clock.now() < t_start + horizon:
                self.clock.sleep(0.001)
            if drain_s > 0:
                self.clock.sleep(drain_s)
        tick.cancel()
        now = self.clock.now()
        for req in self.queue.drain("horizon reached"):
            pass
        for req in window:
            if not req.terminal:  # still seated in a slot at the horizon
                req.status, req.reason = "failed", "horizon reached"
                req.finish_t = now
        return self._report(window, wall_s=now - t_start)

    def _report(self, window: list[ServeRequest], *,
                wall_s: float) -> ServeReport:
        done = [r for r in window if r.status == "done"]
        lat = sorted(r.latency_s for r in done)
        return ServeReport(
            completed=len(done),
            failed=sum(1 for r in window if r.status == "failed"),
            tokens_generated=sum(len(r.generated) for r in window),
            recoveries=list(self.recoveries),
            denylisted=sorted(self.denylist),
            wall_s=wall_s,
            replica_health=self.replica_health(),
            rejected=sum(1 for r in window if r.status == "rejected"),
            shed=sum(1 for r in window if r.status == "shed"),
            decode_steps=self.decode_steps,
            queue_peak=self.queue.peak_depth,
            p50_s=_quantile(lat, 0.50),
            p99_s=_quantile(lat, 0.99),
            autoscaled_up=self.autoscaled_up,
            autoscaled_down=self.autoscaled_down,
            replicas_final=len(self.live_replicas()),
        )
