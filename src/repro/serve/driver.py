"""WRATH-supervised batched serving driver.

Serving plane of the reproduction: requests are batched and decoded
token-by-token on a pool of *replicas* (virtual serving hosts, an
``engine.cluster`` pool).  WRATH supervises replica health exactly as it
supervises tasks: a replica lost mid-decode (environment layer) is
denylisted and the in-flight batch is retried on a healthy replica — the
decode state is recovered from the last per-step state snapshot, so no
generated tokens are lost (atomic-step semantics, the serving analog of
the paper's atomic tasks).

Replica selection goes through the same pluggable
:class:`~repro.engine.scheduler.Scheduler` interface as the task plane
(``WrathServeDriver(scheduler=...)``): the default round-robin spreads
successive batches across healthy replicas instead of hammering the first
one, and a least-loaded or history-aware scheduler can be dropped in
unchanged.  Per-batch placements (and decode wall time) are recorded in
the monitoring database, so the history-aware scheduler learns fast
replicas over time.

Failover decisions flow through the same composable
:class:`~repro.engine.policies.PolicyStack` as the task plane
(``WrathServeDriver(policy=...)``, default a single
:class:`~repro.engine.policies.WrathPolicy`): the first decisive
:class:`~repro.engine.retry_api.RetryDecision` wins, so e.g.
``policy=[replay(5), WrathPolicy()]`` gives every batch five replica
attempts regardless of the taxonomy's verdict.

The serving loop drives the *decision* subset of the policy protocol —
``on_submit``, ``on_failure``, ``review_decision``.  Engine-execution
policies (``replicate``'s racing copies, ``StragglerPolicy``'s periodic
sweep) need the DataFlowKernel's copy/tick machinery and are inert here;
use them on the task plane.
"""
from __future__ import annotations

import dataclasses
import time
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import MonitoringDatabase
from repro.core.failures import FailureReport, HardwareShutdownError
from repro.engine.cluster import Cluster, Node, ResourcePool
from repro.engine.policies import PolicyStack, WrathPolicy, normalize_policies
from repro.engine.retry_api import Action, SchedulingContext
from repro.engine.scheduler import RoundRobinScheduler, Scheduler
from repro.engine.task import ResourceSpec, TaskDef, new_task_record
from repro.models import cache_defs, decode_step, materialize, param_defs
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int = 8
    generated: list[int] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class ServeReport:
    completed: int
    failed: int
    tokens_generated: int
    recoveries: list[dict]
    denylisted: list[str]
    wall_s: float
    # per-replica health snapshot from the monitoring database's streaming
    # profiles (success rate + decode-duration mean/p95)
    replica_health: dict[str, dict] = dataclasses.field(default_factory=dict)

    @property
    def tokens_per_s(self) -> float:
        return self.tokens_generated / max(self.wall_s, 1e-9)


class WrathServeDriver:
    def __init__(self, cfg: ModelConfig, *, n_replicas: int = 3,
                 max_batch: int = 4, seed: int = 0,
                 scheduler: Scheduler | None = None,
                 policy: object = None,
                 health_gate: bool = True):
        self.cfg = cfg
        self.max_batch = max_batch
        self.health_gate = health_gate
        nodes = [Node(f"replica{i}", workers_per_node=1)
                 for i in range(n_replicas)]
        self.cluster = Cluster([ResourcePool("serve", nodes)])
        self.monitor = MonitoringDatabase()
        # policy=None -> WRATH default; an explicit empty stack ([]) is a
        # valid choice meaning Parsl-style baseline retry only
        self.policies = PolicyStack(
            normalize_policies(policy) if policy is not None
            else (WrathPolicy(),),
            on_error=self._policy_error)
        self.scheduler = (scheduler or RoundRobinScheduler()).bind(
            cluster=self.cluster, monitor=self.monitor)
        self.denylist: set[str] = set()
        self.params = materialize(param_defs(cfg), jax.random.PRNGKey(seed))
        self._decode = jax.jit(lambda p, c, b: decode_step(p, c, b, cfg))

    def _policy_error(self, hook: str, err: BaseException) -> None:
        """Swallowed policy-hook exceptions stay visible as system events."""
        self.monitor.record_system_event(
            "policy_error", event=hook, error=type(err).__name__,
            message=str(err))

    def _ctx(self) -> SchedulingContext:
        return SchedulingContext(cluster=self.cluster, monitor=self.monitor,
                                 denylist=self.denylist, default_pool="serve",
                                 scheduler=self.scheduler)

    def replicas(self) -> list[Node]:
        return [n for n in self.cluster.pools["serve"].nodes
                if n.healthy and n.name not in self.denylist]

    def _pick_replica(self, rec, exclude: str | None = None) -> Node | None:
        """Scheduler-driven replica selection over the healthy serve pool.

        With ``health_gate`` the monitoring database's placement profile is
        consulted first: a replica that has only ever failed batches
        (>= 2 failures, 0 successes) is skipped while healthier candidates
        exist — the serving analog of the proactive plane's "stop placing
        on a node trending toward failure".
        """
        pool = self.cluster.pools["serve"]
        candidates = [n for n in self.replicas() if n.name != exclude]
        if self.health_gate and candidates:
            hist = self.monitor.node_history("decode_batch")

            def suspect(n: Node) -> bool:
                s = hist.get(n.name)
                return s is not None and s.failures >= 2 and s.successes == 0

            vetted = [n for n in candidates if not suspect(n)]
            candidates = vetted or candidates
        return self.scheduler.select(rec, candidates or self.replicas(),
                                     pool=pool)

    def replica_health(self) -> dict[str, dict]:
        """Streaming-profile health snapshot of every replica."""
        hist = self.monitor.node_history("decode_batch")
        out: dict[str, dict] = {}
        for n in self.cluster.pools["serve"].nodes:
            stats = hist.get(n.name)
            dur = self.monitor.duration_stats("decode_batch", node=n.name)
            out[n.name] = {
                "live": n.healthy and n.name not in self.denylist,
                "batches": stats.total if stats else 0,
                "success_rate": stats.success_rate if stats else None,
                "decode_s_mean": dur.mean if dur else None,
                "decode_s_p95": dur.p95 if dur else None,
            }
        return out

    # ------------------------------------------------------------------ #
    def _decode_on(self, replica: Node, state: dict, batch: dict):
        if not replica.healthy:
            raise HardwareShutdownError(f"replica {replica.name} is down",
                                        node=replica.name)
        return self._decode(self.params, state, batch)

    def serve(self, requests: list[Request], *,
              kill_replica_at: tuple[str, int] | None = None) -> ServeReport:
        """Process requests; optionally kill a replica after N decode steps."""
        t0 = time.time()
        recoveries: list[dict] = []
        completed = failed = tokens = 0
        decode_calls = 0
        queue = list(requests)
        while queue:
            batch_reqs = queue[:self.max_batch]
            queue = queue[len(batch_reqs):]
            b = len(batch_reqs)
            maxlen = max(len(r.prompt) for r in batch_reqs) + \
                max(r.max_new_tokens for r in batch_reqs)
            state = materialize(cache_defs(self.cfg, b, maxlen),
                                jax.random.PRNGKey(0))
            # one task record per batch: retry budget and attempt history
            # are tracked across replica failovers of the same batch
            rec = new_task_record(
                TaskDef(lambda: None, "decode_batch", ResourceSpec(), 2),
                (), {}, default_retries=2)
            # full middleware protocol: on_submit lets policies set up
            # per-record state (e.g. deferred replay's budget extension)
            self.policies.on_submit(rec, self._ctx())
            replica = self._pick_replica(rec)
            if replica is None:
                failed += b
                continue
            batch_t0 = time.time()
            # prefill: feed prompt tokens one by one (tiny models; a real
            # deployment uses prefill_forward)
            steps = max(len(r.prompt) for r in batch_reqs) + \
                max(r.max_new_tokens for r in batch_reqs)
            toks = np.zeros((b, 1), np.int32)
            for i, r in enumerate(batch_reqs):
                toks[i, 0] = r.prompt[0]
            snapshot = jax.tree.map(lambda x: x, state)
            t = 0
            while t < steps - 1:
                if kill_replica_at and decode_calls == kill_replica_at[1]:
                    victim = self.cluster.find_node(kill_replica_at[0])
                    if victim is not None:
                        victim.shutdown_hardware()
                try:
                    logits, state = self._decode_on(
                        replica, state, {"inputs": jnp.asarray(toks)})
                    decode_calls += 1
                except HardwareShutdownError as err:
                    rec.record_attempt(node=replica.name, pool="serve",
                                       worker="-", ok=False,
                                       error=type(err).__name__,
                                       duration=time.time() - batch_t0)
                    self.monitor.record_task_placement(
                        "decode_batch", replica.name, "serve", ok=False)
                    report = FailureReport.from_exception(
                        err, task_id=rec.task_id, node=replica.name,
                        pool="serve")
                    decision = self.policies.decide(rec, report, self._ctx())
                    recoveries.append({
                        "replica": replica.name, "step": t,
                        "action": decision.action.value,
                        "rung": decision.rung})
                    if decision.action is Action.FAIL or not self.replicas():
                        failed += b
                        batch_reqs = []
                        break
                    rec.retry_count += 1
                    replica = (self.cluster.find_node(decision.target_node)
                               or self._pick_replica(rec, exclude=replica.name))
                    if replica is None:
                        failed += b
                        batch_reqs = []
                        break
                    state = jax.tree.map(lambda x: x, snapshot)  # state recovery
                    batch_t0 = time.time()  # rescuer is timed from takeover
                    continue
                snapshot = state
                nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
                for i, r in enumerate(batch_reqs):
                    t_next = t + 1
                    if t_next < len(r.prompt):
                        toks[i, 0] = r.prompt[t_next]       # teacher-forced prefill
                    else:
                        toks[i, 0] = int(nxt[i])
                        if len(r.generated) < r.max_new_tokens:
                            r.generated.append(int(nxt[i]))
                            tokens += 1
                t += 1
            if batch_reqs:
                self.monitor.record_task_placement(
                    "decode_batch", replica.name, "serve", ok=True,
                    duration=time.time() - batch_t0)
            completed += len(batch_reqs)
        return ServeReport(completed=completed, failed=failed,
                           tokens_generated=tokens, recoveries=recoveries,
                           denylisted=sorted(self.denylist),
                           wall_s=time.time() - t0,
                           replica_health=self.replica_health())
