"""Composable resilience policies: the middleware layer of the public API.

Historically the engine exposed three *disjoint* resilience mechanisms,
each with its own kwarg and its own code path through the
:class:`~repro.engine.dfk.DataFlowKernel`:

* ``retry_handler=`` — one global callable deciding every retry;
* ``proactive=`` — the :class:`~repro.core.proactive.ProactiveSentinel`
  with its inline dispatch check + retry review + periodic sweep;
* ``speculative_execution=`` — the straggler watcher.

This module unifies all three behind one abstraction: a
:class:`ResiliencePolicy` is ordered middleware with lifecycle hooks
(``on_submit``, ``on_dispatch``, ``on_running``, ``on_failure``,
``on_result``, ``on_tick`` and the ``review_decision`` second-opinion
pass), and a :class:`PolicyStack` composes policies so the *first
decisive* :class:`~repro.engine.retry_api.RetryDecision` wins.  Stacks
are resolved per task invocation: per-call policies (``TaskDef.options
(policy=...)``) run first, then the enclosing
:class:`~repro.engine.workflow.Workflow` chain (innermost scope first),
then the engine-level stack, with Parsl's baseline retry-in-place as the
terminal fallback.

HPX-style task-level combinators (Gupta et al., *Implementing Software
Resiliency in HPX*) are built on the same machinery: :func:`replay`
re-executes a failed task up to *n* times, :func:`replicate` races *n*
concurrent copies of the task (via the engine's speculative-copy
mechanism) and accepts the first result that passes ``validate``.
"""
from __future__ import annotations

from typing import Any, Callable, Iterable

from repro.core.failures import DependencyError, FailureReport
from repro.engine.retry_api import (
    Action,
    RetryDecision,
    SchedulingContext,
    baseline_retry_handler,
)


class ResiliencePolicy:
    """One layer of resilience middleware.

    Subclasses override any subset of the hooks; every hook has a no-op
    default so a policy states only what it cares about.  Hooks must be
    fast and must not block — ``on_dispatch``/``on_failure`` run on the
    engine's event thread, ``on_running``/``on_result`` on worker
    threads.

    Hook contract:

    ``on_submit(rec, ctx)``
        Task invocation entered the engine.  May annotate the record
        (e.g. :class:`ReplicatePolicy` requests racing copies here).
    ``on_dispatch(rec, ctx) -> str | None``
        About to place the task.  A non-``None`` reason string vetoes
        the dispatch: the task is fast-failed with that reason.
    ``on_running(rec, ctx)``
        A worker picked the task up.
    ``on_failure(rec, report, ctx) -> RetryDecision | None``
        The task failed.  Return a decision to *decide* (stops the
        chain), or ``None`` to pass to the next policy.
    ``review_decision(rec, report, decision, ctx) -> RetryDecision``
        Second-opinion pass over the decisive decision (every policy
        sees it, in stack order).  Used e.g. by :class:`ProactivePolicy`
        to veto retries destined to fail.
    ``on_result(rec, result, ctx) -> BaseException | None``
        The task produced a result.  Return an exception to *invalidate*
        it — the result is discarded and the exception routed through
        the failure path (this is how ``replicate(validate=)`` rejects
        bad replicas).
    ``on_tick(ctx)``
        Periodic heartbeat on the engine's event loop.
    ``admit_request(req, ctx) -> str | None``
        Serving-plane admission check, called by the
        :class:`~repro.serve.queue.RequestQueue` before a request is
        enqueued.  A non-``None`` reason string *rejects* the request up
        front (it never reaches a decode slot) — the request-plane analog
        of ``on_dispatch``'s predictive fast-fail.  Overridden by
        :class:`~repro.serve.queue.SLOAdmissionPolicy`.
    ``memo_lookup(rec, ctx) -> (hit, value)``
        Checkpoint hook, called at dispatch once dependencies resolved:
        a ``(True, value)`` return short-circuits execution — the engine
        resolves the future with ``value`` and never places the task.
        Overridden by :class:`~repro.checkpoint.task_store.
        CheckpointPolicy`.
    ``memo_commit(rec, result, ctx)``
        Persist a successful result.  Fired only for the attempt that
        won the task (post duplicate-completion guard), never for a
        discarded racing copy.
    ``memo_invalidate(rec, reason) -> removed keys``
        Dependency-aware rollback, fired when a memoized result fails
        the stack's ``on_result`` validation: drop the cached entry and
        its descendants so the lineage re-executes.
    """

    def bind(self, dfk: Any) -> None:
        """Attach to a running engine (idempotent)."""

    def unbind(self) -> None:
        """Detach from the engine at shutdown."""

    def on_submit(self, rec: Any, ctx: SchedulingContext) -> None: ...

    def on_dispatch(self, rec: Any, ctx: SchedulingContext) -> str | None:
        return None

    def on_running(self, rec: Any, ctx: SchedulingContext) -> None: ...

    def on_failure(self, rec: Any, report: FailureReport,
                   ctx: SchedulingContext) -> RetryDecision | None:
        return None

    def review_decision(self, rec: Any, report: FailureReport,
                        decision: RetryDecision,
                        ctx: SchedulingContext) -> RetryDecision:
        return decision

    def on_result(self, rec: Any, result: Any,
                  ctx: SchedulingContext) -> BaseException | None:
        return None

    def on_tick(self, ctx: SchedulingContext) -> None: ...

    def admit_request(self, req: Any, ctx: SchedulingContext) -> str | None:
        return None

    def memo_lookup(self, rec: Any, ctx: SchedulingContext) -> tuple[bool, Any]:
        return (False, None)

    def memo_commit(self, rec: Any, result: Any,
                    ctx: SchedulingContext) -> None: ...

    def memo_invalidate(self, rec: Any, reason: str = "") -> list[str]:
        return []

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"<{type(self).__name__}>"


def normalize_policies(policy: Any) -> tuple[ResiliencePolicy, ...]:
    """Coerce the public ``policy=`` argument into a policy tuple.

    Accepts ``None``, a single :class:`ResiliencePolicy`, a
    :class:`PolicyStack`, a bare retry-handler callable (wrapped in
    :class:`RetryHandlerPolicy`), or an iterable mixing any of these.
    """
    if policy is None:
        return ()
    if isinstance(policy, PolicyStack):
        return policy.policies
    if isinstance(policy, ResiliencePolicy):
        return (policy,)
    if isinstance(policy, type) and issubclass(policy, ResiliencePolicy):
        # the class itself (missing parens) is callable, so without this
        # check it would be silently wrapped as a broken retry handler
        raise TypeError(
            f"{policy.__name__} is a policy class, not an instance — "
            f"did you mean {policy.__name__}()?")
    if callable(policy):
        return (RetryHandlerPolicy(policy),)
    if isinstance(policy, (str, bytes)):
        # a str is an Iterable of 1-char strs: recursing would blow the
        # stack instead of reaching the descriptive error below
        raise TypeError(f"cannot interpret {policy!r} as a resilience policy")
    if isinstance(policy, Iterable):
        out: list[ResiliencePolicy] = []
        for p in policy:
            out.extend(normalize_policies(p))
        return tuple(out)
    raise TypeError(f"cannot interpret {policy!r} as a resilience policy")


class PolicyStack(ResiliencePolicy):
    """An ordered composition of policies; itself a policy.

    ``on_dispatch`` returns the first veto; ``on_failure`` returns the
    first decisive decision (falling back to
    :func:`~repro.engine.retry_api.baseline_retry_handler` when no
    policy decides), then runs every policy's ``review_decision`` over
    it in stack order.  A policy whose ``on_failure`` raises produces a
    terminal FAIL (a buggy decider must not hang the task); a raising
    ``review_decision`` is ignored (the decision stands) — both match
    the engine's historical contract for ``retry_handler`` /
    ``ProactiveSentinel`` bugs.  Swallowed hook exceptions are surfaced
    through ``on_error`` (the engine wires its system-event reporter in)
    so a misbehaving policy degrades resilience *visibly*.
    """

    def __init__(self, policies: Any = (),
                 on_error: Callable[[str, BaseException], Any] | None = None):
        self.policies = normalize_policies(policies)
        self.on_error = on_error
        base = ResiliencePolicy
        # precomputed per-hook subsets: the hot paths (dispatch, running,
        # result) skip policies that kept the no-op default
        self._dispatchers = tuple(
            p for p in self.policies if type(p).on_dispatch is not base.on_dispatch)
        self._submitters = tuple(
            p for p in self.policies if type(p).on_submit is not base.on_submit)
        self._runners = tuple(
            p for p in self.policies if type(p).on_running is not base.on_running)
        self._deciders = tuple(
            p for p in self.policies if type(p).on_failure is not base.on_failure)
        self._reviewers = tuple(
            p for p in self.policies
            if type(p).review_decision is not base.review_decision)
        self._validators = tuple(
            p for p in self.policies if type(p).on_result is not base.on_result)
        self._tickers = tuple(
            p for p in self.policies if type(p).on_tick is not base.on_tick)
        self._admitters = tuple(
            p for p in self.policies
            if type(p).admit_request is not base.admit_request)
        self._checkpointers = tuple(
            p for p in self.policies
            if type(p).memo_lookup is not base.memo_lookup
            or type(p).memo_commit is not base.memo_commit
            or type(p).memo_invalidate is not base.memo_invalidate)

    # -- composition -----------------------------------------------------
    def __iter__(self):
        return iter(self.policies)

    def __len__(self) -> int:
        return len(self.policies)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        inner = ", ".join(type(p).__name__ for p in self.policies)
        return f"<PolicyStack [{inner}]>"

    @property
    def wants_running(self) -> bool:
        return bool(self._runners)

    # -- lifecycle -------------------------------------------------------
    def bind(self, dfk: Any) -> None:
        for p in self.policies:
            p.bind(dfk)

    def unbind(self) -> None:
        for p in self.policies:
            p.unbind()

    def _report(self, policy: ResiliencePolicy, hook: str,
                err: BaseException) -> None:
        """Surface a swallowed hook exception (engine system event)."""
        if self.on_error is not None:
            try:
                self.on_error(f"policy-{hook}:{type(policy).__name__}", err)
            except Exception:  # noqa: BLE001 - reporter bugs stay contained
                pass

    # -- hooks -----------------------------------------------------------
    def on_submit(self, rec: Any, ctx: SchedulingContext) -> None:
        for p in self._submitters:
            try:
                p.on_submit(rec, ctx)
            except Exception as err:  # noqa: BLE001 - must not block submission
                self._report(p, "on_submit", err)

    def on_dispatch(self, rec: Any, ctx: SchedulingContext) -> str | None:
        for p in self._dispatchers:
            try:
                reason = p.on_dispatch(rec, ctx)
            except Exception as err:  # noqa: BLE001 - must not block dispatch
                self._report(p, "on_dispatch", err)
                continue
            if reason is not None:
                return reason
        return None

    def on_running(self, rec: Any, ctx: SchedulingContext) -> None:
        for p in self._runners:
            try:
                p.on_running(rec, ctx)
            except Exception as err:  # noqa: BLE001
                self._report(p, "on_running", err)

    def on_failure(self, rec: Any, report: FailureReport,
                   ctx: SchedulingContext) -> RetryDecision | None:
        for p in self._deciders:
            try:
                decision = p.on_failure(rec, report, ctx)
            except Exception as err:  # noqa: BLE001 - decider bug = fail the task
                return RetryDecision(
                    Action.FAIL,
                    reason=f"policy {type(p).__name__} error: {err!r}")
            if decision is not None:
                return decision
        return None

    def review_decision(self, rec: Any, report: FailureReport,
                        decision: RetryDecision,
                        ctx: SchedulingContext) -> RetryDecision:
        for p in self._reviewers:
            try:
                decision = p.review_decision(rec, report, decision, ctx)
            except Exception as err:  # noqa: BLE001 - reviewer bug = keep the decision
                self._report(p, "review_decision", err)
                continue
        return decision

    def on_result(self, rec: Any, result: Any,
                  ctx: SchedulingContext) -> BaseException | None:
        for p in self._validators:
            try:
                exc = p.on_result(rec, result, ctx)
            except Exception as err:  # noqa: BLE001 - validator raising = invalid
                return err
            if exc is not None:
                return exc
        return None

    def on_tick(self, ctx: SchedulingContext) -> None:
        for p in self._tickers:
            try:
                p.on_tick(ctx)
            except Exception as err:  # noqa: BLE001
                self._report(p, "on_tick", err)

    def admit_request(self, req: Any, ctx: SchedulingContext) -> str | None:
        """First rejection wins; a raising admitter degrades to "admit"
        (a buggy admission policy must shed resilience, not traffic)."""
        for p in self._admitters:
            try:
                reason = p.admit_request(req, ctx)
            except Exception as err:  # noqa: BLE001 - admitter bug => admit
                self._report(p, "admit_request", err)
                continue
            if reason is not None:
                return reason
        return None

    def memo_lookup(self, rec: Any, ctx: SchedulingContext) -> tuple[bool, Any]:
        """First checkpoint hit wins; a raising store degrades to a miss
        (memoization must never be able to wedge dispatch)."""
        for p in self._checkpointers:
            try:
                hit, value = p.memo_lookup(rec, ctx)
            except Exception as err:  # noqa: BLE001 - store bug => execute
                self._report(p, "memo_lookup", err)
                continue
            if hit:
                return True, value
        return False, None

    def memo_commit(self, rec: Any, result: Any,
                    ctx: SchedulingContext) -> None:
        """Commit fans out to every checkpoint store in the stack."""
        for p in self._checkpointers:
            try:
                p.memo_commit(rec, result, ctx)
            except Exception as err:  # noqa: BLE001 - a failed commit only
                self._report(p, "memo_commit", err)  # costs a future memo hit

    def memo_invalidate(self, rec: Any, reason: str = "") -> list[str]:
        """Rollback fans out to *every* checkpoint store in the stack: an
        invalid cached result must not survive anywhere."""
        removed: list[str] = []
        for p in self._checkpointers:
            try:
                removed.extend(p.memo_invalidate(rec, reason=reason))
            except Exception as err:  # noqa: BLE001
                self._report(p, "memo_invalidate", err)
        return removed

    # -- the full failure-routing protocol -------------------------------
    def decide(self, rec: Any, report: FailureReport,
               ctx: SchedulingContext) -> RetryDecision:
        """First decisive ``on_failure`` (baseline fallback), then review."""
        decision = self.on_failure(rec, report, ctx)
        if decision is None:
            decision = baseline_retry_handler(rec, report, ctx)
        return self.review_decision(rec, report, decision, ctx)


# --------------------------------------------------------------------- #
# adapters: today's three mechanisms as stack members
# --------------------------------------------------------------------- #
class RetryHandlerPolicy(ResiliencePolicy):
    """Adapter: a legacy ``retry_handler`` callable as a stack member.

    The handler's decision is always decisive (legacy handlers never
    abstain) — install it last if other policies should get a say first.
    """

    def __init__(self, handler: Callable[..., RetryDecision]):
        self.handler = handler

    def on_failure(self, rec: Any, report: FailureReport,
                   ctx: SchedulingContext) -> RetryDecision | None:
        return self.handler(rec, report, ctx)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        h = getattr(self.handler, "__name__", type(self.handler).__name__)
        return f"<RetryHandlerPolicy {h}>"


class WrathPolicy(RetryHandlerPolicy):
    """WRATH's resilience module (§V) as a policy: taxonomy-driven
    categorization + denylist + hierarchical four-rung retry."""

    def __init__(self, **kwargs: Any):
        from repro.core.policy import ResiliencePolicyEngine
        super().__init__(ResiliencePolicyEngine(**kwargs))

    @property
    def engine(self):
        return self.handler

    @property
    def decisions(self) -> list[dict]:
        return self.handler.decisions


class ProactivePolicy(ResiliencePolicy):
    """The proactive sentinel (§IV↔§V feedback loop) as a policy.

    ``on_dispatch`` is the sentinel's predictive fast-fail check;
    ``review_decision`` is its retry review (vetoing retries destined to
    fail).  The sentinel's periodic drain/feasibility sweep is scheduled
    by the sentinel itself when the stack binds to the engine.
    """

    def __init__(self, proactive: Any = True):
        # lazy import: repro.core.proactive imports repro.engine.retry_api,
        # which initializes this package — a module-level import would cycle
        from repro.core.proactive import ProactiveSentinel, make_sentinel
        self.sentinel: ProactiveSentinel = (
            make_sentinel(proactive) or make_sentinel(True))

    def bind(self, dfk: Any) -> None:
        if self.sentinel.dfk is None:
            self.sentinel.attach(dfk)

    def unbind(self) -> None:
        self.sentinel.detach()

    def on_dispatch(self, rec: Any, ctx: SchedulingContext) -> str | None:
        return self.sentinel.check_dispatch(rec)

    def review_decision(self, rec: Any, report: FailureReport,
                        decision: RetryDecision,
                        ctx: SchedulingContext) -> RetryDecision:
        if decision.action is Action.FAIL:
            return decision
        return self.sentinel.review_retry(rec, report, decision)


class StragglerPolicy(ResiliencePolicy):
    """Speculative re-execution of stragglers as a policy.

    Each tick, tasks running beyond ``factor`` × their expected duration
    (profile-derived p95, ``est_duration_s`` fallback) get a backup copy
    on another node; first finisher wins.  ``scope`` restricts the watch
    to one workflow's subtree (``None`` = every task on the engine).
    """

    def __init__(self, factor: float = 3.0, *, scope: Any = None):
        self.factor = factor
        self.scope = scope
        self.dfk: Any = None

    def bind(self, dfk: Any) -> None:
        self.dfk = dfk

    def unbind(self) -> None:
        self.dfk = None

    def on_tick(self, ctx: SchedulingContext) -> None:
        if self.dfk is not None:
            self.dfk.check_stragglers(factor=self.factor, scope=self.scope)


# --------------------------------------------------------------------- #
# HPX-style combinators (async_replay / async_replicate analogs)
# --------------------------------------------------------------------- #
class ReplayPolicy(ResiliencePolicy):
    """``replay(n)``: re-execute a failed task until *n* total attempts.

    The HPX ``async_replay`` analog: any failure (other than a terminal
    dependency failure) is retried — on a scheduler-chosen node — until
    the task has executed ``n`` times.  What happens then is
    ``on_exhausted``: ``"fail"`` (default, HPX semantics) terminates the
    task decisively — exactly *n* attempts, overriding every policy
    below; ``"defer"`` abstains so deeper stack members (e.g.
    :class:`WrathPolicy`) take over once the replay budget is spent.
    """

    def __init__(self, n: int, on_exhausted: str = "fail"):
        if n < 1:
            raise ValueError(f"replay count must be >= 1, got {n}")
        if on_exhausted not in ("fail", "defer"):
            raise ValueError(
                f"on_exhausted must be 'fail' or 'defer', got {on_exhausted!r}")
        self.n = n
        self.on_exhausted = on_exhausted

    def on_submit(self, rec: Any, ctx: SchedulingContext) -> None:
        if self.on_exhausted == "defer":
            # replay attempts must not eat the deeper policies' retry
            # budget: a handler below would otherwise see retry_count >=
            # max_retries the moment replay defers and fail immediately
            # instead of performing its advertised recovery
            rec.max_retries += self.n - 1

    def on_failure(self, rec: Any, report: FailureReport,
                   ctx: SchedulingContext) -> RetryDecision | None:
        if isinstance(report.exception, DependencyError):
            return RetryDecision(Action.FAIL,
                                 reason="dependency failed (dep_fail)")
        attempt = rec.retry_count + 1          # attempts executed so far
        if attempt < self.n:
            return RetryDecision(
                Action.RETRY,
                reason=f"replay attempt {attempt + 1}/{self.n}")
        if self.on_exhausted == "defer":
            return None                        # hand over to deeper policies
        return RetryDecision(
            Action.FAIL, reason=f"replay budget exhausted ({self.n} attempts)")

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"<ReplayPolicy n={self.n} then={self.on_exhausted}>"


class ReplicationError(RuntimeError):
    """A replicated task's result failed its ``validate`` predicate."""


class ReplicatePolicy(ResiliencePolicy):
    """``replicate(n, validate=)``: race *n* concurrent copies of a task.

    The HPX ``async_replicate`` analog, built on the engine's
    speculative-copy machinery (shared future, winner-takes-all,
    losers cancelled).  ``on_submit`` requests ``n - 1`` racing copies
    (launched right after the original is placed); ``on_result``
    applies ``validate`` so an invalid result — from *any* replica — is
    discarded instead of winning the race.
    """

    def __init__(self, n: int, validate: Callable[[Any], bool] | None = None):
        if n < 1:
            raise ValueError(f"replica count must be >= 1, got {n}")
        self.n = n
        self.validate = validate

    def on_submit(self, rec: Any, ctx: SchedulingContext) -> None:
        rec.replicas = max(rec.replicas, self.n - 1)

    def on_result(self, rec: Any, result: Any,
                  ctx: SchedulingContext) -> BaseException | None:
        if self.validate is None:
            return None
        try:
            ok = bool(self.validate(result))
        except Exception as err:  # noqa: BLE001 - validator raising = invalid
            return ReplicationError(
                f"replica validator raised {type(err).__name__}: {err}")
        if not ok:
            return ReplicationError(
                f"replica result {result!r} rejected by validator")
        return None

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"<ReplicatePolicy n={self.n}>"


def replay(n: int, on_exhausted: str = "fail") -> ReplayPolicy:
    """HPX-style ``async_replay``: retry a failed task up to ``n`` total
    attempts (``replay(1)`` = fail fast on first failure).
    ``on_exhausted="defer"`` hands over to deeper policies instead of
    failing when the budget runs out."""
    return ReplayPolicy(n, on_exhausted)


def replicate(n: int, validate: Callable[[Any], bool] | None = None) -> ReplicatePolicy:
    """HPX-style ``async_replicate``: run ``n`` racing copies, accept the
    first result that passes ``validate`` (``None`` = first finisher)."""
    return ReplicatePolicy(n, validate)


# --------------------------------------------------------------------- #
# deprecation shims
# --------------------------------------------------------------------- #
def shim_legacy_kwargs(*, retry_handler: Any = None, proactive: Any = False,
                       speculative_execution: bool = False,
                       straggler_factor: float = 3.0,
                       warn: bool = True) -> tuple[ResiliencePolicy, ...]:
    """Adapt the pre-stack DFK kwargs into an equivalent policy tuple.

    Emits one :class:`DeprecationWarning` per legacy kwarg used (``warn=
    False`` for internal compat callers that already announced it).
    """
    import warnings

    parts: list[ResiliencePolicy] = []
    if retry_handler is not None:
        if warn:
            warnings.warn(
                "DataFlowKernel(retry_handler=...) is deprecated; pass "
                "policy=[RetryHandlerPolicy(handler)] (or the handler in a "
                "policy list) instead", DeprecationWarning, stacklevel=3)
        parts.append(RetryHandlerPolicy(retry_handler))
    if proactive:
        if warn:
            warnings.warn(
                "DataFlowKernel(proactive=...) is deprecated; pass "
                "policy=[..., ProactivePolicy()] instead",
                DeprecationWarning, stacklevel=3)
        parts.append(ProactivePolicy(proactive))
    if speculative_execution:
        if warn:
            warnings.warn(
                "DataFlowKernel(speculative_execution=True) is deprecated; "
                "pass policy=[..., StragglerPolicy(factor)] instead",
                DeprecationWarning, stacklevel=3)
        parts.append(StragglerPolicy(straggler_factor))
    return tuple(parts)
