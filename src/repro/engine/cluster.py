"""Simulated heterogeneous cluster (Runtime + Environment layers).

A :class:`Cluster` is a set of :class:`ResourcePool`\\ s (Parsl executors map
1:1 onto pools); each pool holds :class:`Node`\\ s with *distinct* memory
capacities, package environments, ulimits, health and speed — the
heterogeneity that WRATH's hierarchical retry exploits (paper §VII-C).

Execution follows the pilot-job model (paper §II-A): starting a pool runs a
*node manager* per node which spawns worker threads; workers pull tasks
from the node queue and push results back.  Node managers heartbeat to the
monitoring system; a hardware shutdown silences the heartbeat and kills the
node's in-flight tasks, exactly the manifestation chain of §III-B.

Resource enforcement: before running a task the worker checks the task's
:class:`~repro.engine.task.ResourceSpec` against the node — missing
packages raise :class:`EnvironmentMismatchError` (the ImportError
manifestation), insufficient memory raises :class:`MemoryError` (the OOM
manifestation), exceeded ulimits raise :class:`UlimitExceededError`.  This
is how the paper's "200 GB task on a 192 GB node" scenario arises naturally
rather than being scripted.
"""
from __future__ import annotations

import queue
import threading
import time
import traceback
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable

from repro.core.failures import (
    EnvironmentMismatchError,
    HardwareShutdownError,
    PilotJobInitError,
    UlimitExceededError,
    WorkerLostError,
)
from repro.engine.events import REAL_CLOCK
from repro.engine.task import TaskRecord, TaskState

# thread-local handle letting task code discover which node it runs on
# (used by ``simwork`` for speed-scaled sleeps, and by tests)
_current = threading.local()


def current_node() -> "Node | None":
    return getattr(_current, "node", None)


def current_worker() -> "Worker | None":
    return getattr(_current, "worker", None)


def simwork(seconds: float) -> None:
    """Sleep ``seconds`` of *nominal* work, scaled by the node's speed.

    A straggler node (speed < 1) takes proportionally longer — the hook used
    by straggler-mitigation tests and benchmarks.
    """
    node = current_node()
    speed = node.speed if node is not None else 1.0
    time.sleep(seconds / max(speed, 1e-6))


class _WorkerKilled(BaseException):
    """Internal control-flow signal: the injected failure killed the worker."""


def enforce_and_reserve(node: "Node", spec) -> float:
    """The environment-enforcement chain run at task pickup.

    Raises the matching Table III manifestation — hardware down, missing
    package (ImportError analog), exceeded ulimit, OOM — or reserves the
    task's memory on the node and returns the reserved GB (caller
    releases it when the task finishes).  Shared by the real
    :class:`Worker` and the simulation plane's ``SimExecutor`` so the two
    can never diverge on how failures manifest.
    """
    if not node.healthy:
        raise HardwareShutdownError(
            f"node {node.name} hardware is down", node=node.name)
    if spec.packages:
        # only build the sets when the spec actually declares packages —
        # a no-requirement task cannot be missing anything
        missing = set(spec.packages) - set(node.packages)
        if missing:
            raise EnvironmentMismatchError(
                f"No module named {sorted(missing)[0]!r} on {node.name}",
                missing_packages=tuple(sorted(missing)),
                node=node.name,
            )
    if spec.open_files > node.ulimit_files:
        raise UlimitExceededError(
            f"OSError: [Errno 24] Too many open files "
            f"(need {spec.open_files}, ulimit {node.ulimit_files})",
            node=node.name,
        )
    if not spec.memory_gb:
        # a zero-GB request can neither overcommit nor need releasing;
        # skip the reservation lock on the pickup hot path
        return 0.0
    with node._mem_lock:
        if node.mem_in_use_gb + spec.memory_gb > node.memory_gb:
            # the OS would OOM-kill: manifest as MemoryError
            raise MemoryError(
                f"cannot allocate {spec.memory_gb}GB on {node.name} "
                f"({node.mem_in_use_gb}GB in use of {node.memory_gb}GB)")
        node.mem_in_use_gb += spec.memory_gb
    return spec.memory_gb


def kill_current_worker(msg: str = "worker killed by injected failure") -> None:
    """Called from *inside* a task to simulate the worker process dying
    (Table III 'Worker-killed').  Raises a BaseException subclass so user
    ``except Exception`` blocks cannot swallow it, mirroring a SIGKILL."""
    raise _WorkerKilled(msg)


class RunQueue:
    """Per-node run queue: FIFO for the owning node, stealable at the tail.

    Replaces ``queue.Queue`` on :class:`Node` with the same blocking
    ``get`` / ``queue.Empty`` surface the workers use, plus the two
    operations the engine layers need that a ``queue.Queue`` cannot do
    without draining and re-queueing the whole backlog:

    * :meth:`steal_tail` — remove and return the *newest* record passing a
      predicate.  Work stealing takes from the tail, leaving the oldest
      entries to the owner: a stolen task is by construction one nobody
      has started, which is what keeps the recovery semantics of a
      migrated task identical to a freshly-placed one;
    * :meth:`remove` — pull one specific queued record (real
      cancellation) with a single O(n) scan, no drain/requeue churn;
    * O(1) :meth:`qsize` — the queue-depth half of the scheduler's
      incrementally-maintained load index.
    """

    __slots__ = ("_items", "_mutex", "_cond", "_waiting")

    def __init__(self) -> None:
        self._items: deque = deque()
        # hold the raw lock directly on the hot paths: `with self._mutex`
        # enters the C lock without the extra Condition.__enter__ frame,
        # while the condition (sharing the same lock) serves blocking get
        self._mutex = threading.Lock()
        self._cond = threading.Condition(self._mutex)
        # consumers currently blocked in get(); put() only pays for a
        # notify when somebody is actually waiting (the sim plane never
        # blocks, so its puts skip it every time)
        self._waiting = 0

    def put(self, item: "TaskRecord | None") -> None:
        with self._mutex:
            self._items.append(item)
            if self._waiting:
                self._cond.notify()

    def get(self, timeout: float | None = None) -> "TaskRecord | None":
        """Pop the oldest entry; raises ``queue.Empty`` on timeout."""
        with self._mutex:
            if not self._items:
                self._waiting += 1
                try:
                    if timeout is None:
                        while not self._items:
                            self._cond.wait()
                    else:
                        deadline = time.monotonic() + timeout
                        while not self._items:
                            remaining = deadline - time.monotonic()
                            if remaining <= 0:
                                raise queue.Empty
                            self._cond.wait(remaining)
                finally:
                    self._waiting -= 1
            return self._items.popleft()

    def get_nowait(self) -> "TaskRecord | None":
        with self._mutex:
            if not self._items:
                raise queue.Empty
            return self._items.popleft()

    def steal_tail(self, stealable: Callable[["TaskRecord"], bool]
                   ) -> "TaskRecord | None":
        """Remove and return the newest record passing ``stealable``
        (poison pills are never stolen); ``None`` if nothing qualifies."""
        with self._mutex:
            items = self._items
            for i in range(len(items) - 1, -1, -1):
                rec = items[i]
                if rec is not None and stealable(rec):
                    del items[i]
                    return rec
        return None

    def remove(self, task_id: str) -> "TaskRecord | None":
        """Pull one specific queued record off (real cancellation)."""
        with self._mutex:
            items = self._items
            for i, rec in enumerate(items):
                if rec is not None and rec.task_id == task_id:
                    del items[i]
                    return rec
        return None

    def qsize(self) -> int:
        return len(self._items)

    def empty(self) -> bool:
        return not self._items


@dataclass
class Node:
    """One compute node (Environment layer)."""

    name: str
    memory_gb: float = 192.0
    packages: frozenset[str] = frozenset({"numpy", "jax"})
    ulimit_files: int = 1024
    speed: float = 1.0           # relative execution speed (stragglers < 1)
    workers_per_node: int = 2
    healthy: bool = True

    # runtime state ------------------------------------------------------
    pool: "ResourcePool | None" = field(default=None, repr=False)
    task_queue: RunQueue = field(default_factory=RunQueue, repr=False)
    workers: list["Worker"] = field(default_factory=list, repr=False)
    manager: "NodeManager | None" = field(default=None, repr=False)
    mem_in_use_gb: float = 0.0
    # busy half of the O(1) load index: maintained by the pickup/release
    # paths (real and sim workers) instead of rescanning the worker list
    busy_workers: int = field(default=0, repr=False)
    _mem_lock: threading.Lock = field(default_factory=threading.Lock, repr=False)

    def satisfies(self, spec) -> tuple[bool, str]:
        """Static check: could this node *ever* run a task with ``spec``?"""
        missing = set(spec.packages) - set(self.packages)
        if missing:
            return False, f"missing packages {sorted(missing)}"
        if spec.memory_gb > self.memory_gb:
            return False, f"needs {spec.memory_gb}GB > capacity {self.memory_gb}GB"
        if spec.open_files > self.ulimit_files:
            return False, f"needs {spec.open_files} fds > ulimit {self.ulimit_files}"
        return True, ""

    def shutdown_hardware(self) -> None:
        """Simulate a hardware shutdown (Environment-layer failure)."""
        self.healthy = False

    def restore_hardware(self) -> None:
        self.healthy = True

    def adjust_busy(self, delta: int) -> None:
        """Maintain the busy-worker count of the load index (clamped so a
        double release can never drive the reported load negative)."""
        with self._mem_lock:
            self.busy_workers = max(0, self.busy_workers + delta)

    def remove_queued(self, task_id: str) -> TaskRecord | None:
        """Pull one queued (not yet picked up) record off this node's queue.

        The real-cancellation primitive of the proactive plane: a queued
        task can be preempted/cancelled without ever running.  Returns the
        removed record, or ``None`` if no queued record matches (e.g. a
        worker grabbed it first — callers fall back to the running-task
        path).
        """
        return self.task_queue.remove(task_id)


@dataclass
class ResourcePool:
    """A pool of nodes = one Parsl executor's resources."""

    name: str
    nodes: list[Node] = field(default_factory=list)

    def __post_init__(self) -> None:
        for n in self.nodes:
            n.pool = self

    def healthy_nodes(self) -> list[Node]:
        return [n for n in self.nodes if n.healthy]

    def add_node(self, node: Node) -> None:
        node.pool = self
        self.nodes.append(node)

    def remove_node(self, name: str) -> Node | None:
        """Elastic membership: detach a node from the pool (scheduling
        stops seeing it immediately).  Queued/running work on the node is
        the caller's problem — the DFK's leave path sweeps it through the
        normal failure routing before calling this."""
        for i, n in enumerate(self.nodes):
            if n.name == name:
                del self.nodes[i]
                n.pool = None
                return n
        return None


class Worker:
    """A worker process analog: one thread pulling tasks off the node queue."""

    _ids = 0

    def __init__(self, node: Node, on_result: Callable[[TaskRecord, Any, BaseException | None, "Worker"], None],
                 clock: Any = None):
        Worker._ids += 1
        self.worker_id = f"{node.name}/w{Worker._ids:04d}"
        self.node = node
        self.on_result = on_result
        # injected time source for attempt start/end stamps
        self.clock = clock if clock is not None else REAL_CLOCK
        self.alive = True
        self.busy = False  # True while executing a task (load metric input)
        self._thread = threading.Thread(target=self._loop, name=self.worker_id, daemon=True)

    def start(self) -> None:
        self._thread.start()

    def _loop(self) -> None:
        _current.node = self.node
        _current.worker = self
        while self.alive:
            try:
                rec = self.node.task_queue.get(timeout=0.1)
            except queue.Empty:
                if not self.node.healthy:
                    self.alive = False
                    continue
                # idle with an empty queue: try to pull the newest queued
                # record off a loaded sibling (decentralized work stealing;
                # a no-op unless the executor enabled it)
                mgr = self.node.manager
                rec = mgr.try_steal() if mgr is not None else None
                if rec is None:
                    continue
            if rec is None:  # poison pill
                self.alive = False
                break
            if rec.cancel_requested:
                # cancelled while queued: drop without executing — the DFK
                # already resolved (or re-dispatched) the task
                continue
            self.busy = True
            self.node.adjust_busy(+1)
            try:
                self._run_one(rec)
            finally:
                self.busy = False
                self.node.adjust_busy(-1)

    # -- execution with environment enforcement -------------------------
    def _run_one(self, rec: TaskRecord) -> None:
        node = self.node
        spec = rec.effective_resources()
        rec.start_time = self.clock.time()
        # task-state lifecycle: the worker, not the executor, marks RUNNING —
        # the straggler watcher and node-loss sweep key off this transition.
        # READY is accepted too: under batched dispatch a worker can win the
        # race with the drain loop's SCHEDULED bookkeeping write.
        if rec.state in (TaskState.READY, TaskState.SCHEDULED,
                         TaskState.RETRYING):
            rec.state = TaskState.RUNNING
            if rec.on_running is not None:
                try:
                    rec.on_running(rec)
                except Exception:  # noqa: BLE001 - a policy bug must not kill the worker
                    pass
        err: BaseException | None = None
        result: Any = None
        try:
            reserved = enforce_and_reserve(node, spec)
            try:
                result = rec.fn(*rec.args, **rec.kwargs)
            finally:
                with node._mem_lock:
                    node.mem_in_use_gb -= reserved
        except _WorkerKilled as wk:
            # the "process" died: this worker stops pulling tasks
            self.alive = False
            err = WorkerLostError(str(wk), node=node.name, worker=self.worker_id)
        except BaseException as e:  # noqa: BLE001 - we must capture everything
            err = e
            err._wrath_traceback = traceback.format_exc()  # type: ignore[attr-defined]
        rec.end_time = self.clock.time()
        self.on_result(rec, result, err, self)


class NodeManager:
    """Pilot-job node manager: spawns workers and heartbeats (paper §VI-A)."""

    def __init__(self, node: Node, on_result, heartbeat: Callable[[str, float], None] | None,
                 heartbeat_period: float = 0.05, clock: Any = None,
                 steal_source: Callable[[Node], "TaskRecord | None"] | None = None):
        self.node = node
        self.on_result = on_result
        self.heartbeat = heartbeat
        self.heartbeat_period = heartbeat_period
        # executor-provided hook (thief_node) -> record: the idle-worker
        # steal path; None when work stealing is disabled
        self.steal_source = steal_source
        # heartbeat timestamps go through the engine clock so watchers
        # comparing "now - last beat" agree on the timebase
        self.clock = clock
        self._stop = threading.Event()
        self._hb_paused = threading.Event()
        self._hb_thread = threading.Thread(
            target=self._hb_loop, name=f"hb-{node.name}", daemon=True)

    def start(self) -> None:
        if not self.node.healthy:
            raise PilotJobInitError(
                f"pilot job failed to initialize on {self.node.name}",
                node=self.node.name)
        for _ in range(self.node.workers_per_node):
            self.spawn_worker()
        self._hb_thread.start()

    def spawn_worker(self) -> Worker:
        w = Worker(self.node, self.on_result, clock=self.clock)
        self.node.workers.append(w)
        w.start()
        return w

    def alive_workers(self) -> list[Worker]:
        return [w for w in self.node.workers if w.alive]

    def restart_dead_workers(self) -> int:
        """WRATH 'restart failed component' action for lost workers."""
        n = 0
        self.node.workers = [w for w in self.node.workers if w.alive]
        while len(self.node.workers) < self.node.workers_per_node:
            self.spawn_worker()
            n += 1
        return n

    def cancel(self, task_id: str) -> TaskRecord | None:
        """Remove a queued task from this node (real cancellation path)."""
        return self.node.remove_queued(task_id)

    def try_steal(self) -> TaskRecord | None:
        """Ask the executor for a stolen record on behalf of this node."""
        if self.steal_source is None or not self.node.healthy:
            return None
        return self.steal_source(self.node)

    def pause_heartbeats(self) -> None:
        """Silence the heartbeat while workers keep running — the 'node
        trending toward silence' scenario the proactive drain detects."""
        self._hb_paused.set()

    def resume_heartbeats(self) -> None:
        self._hb_paused.clear()

    def _hb_loop(self) -> None:
        while not self._stop.is_set():
            if self.node.healthy:
                if self.heartbeat is not None and not self._hb_paused.is_set():
                    now = (self.clock if self.clock is not None else REAL_CLOCK).time()
                    self.heartbeat(self.node.name, now)
                # pilot-job managers track worker processes and respawn the
                # dead (tasks queued behind a killed worker must not orphan)
                self.restart_dead_workers()
            # Event.wait, not a raw sleep: stop() interrupts mid-period
            self._stop.wait(self.heartbeat_period)

    def stop(self) -> None:
        self._stop.set()
        for w in self.node.workers:
            w.alive = False
        # poison pills to unblock queue waits
        for _ in self.node.workers:
            self.node.task_queue.put(None)


class Cluster:
    """The full simulated machine: pools of heterogeneous nodes."""

    def __init__(self, pools: list[ResourcePool]):
        self.pools = {p.name: p for p in pools}
        if len(self.pools) != len(pools):
            raise ValueError("duplicate pool names")

    def pool(self, name: str) -> ResourcePool:
        return self.pools[name]

    def all_nodes(self) -> list[Node]:
        return [n for p in self.pools.values() for n in p.nodes]

    def find_node(self, name: str) -> Node | None:
        for n in self.all_nodes():
            if n.name == name:
                return n
        return None

    # convenience constructors -----------------------------------------
    @staticmethod
    def homogeneous(n_nodes: int = 4, *, pool_name: str = "default",
                    memory_gb: float = 192.0,
                    packages: frozenset[str] = frozenset({"numpy", "jax"}),
                    workers_per_node: int = 2) -> "Cluster":
        nodes = [Node(name=f"{pool_name}-n{i:03d}", memory_gb=memory_gb,
                      packages=packages, workers_per_node=workers_per_node)
                 for i in range(n_nodes)]
        return Cluster([ResourcePool(pool_name, nodes)])

    @staticmethod
    def paper_testbed(small_nodes: int = 4, big_nodes: int = 1, *,
                      with_pkg_pool: bool = False,
                      package: str = "scipy",
                      workers_per_node: int = 2) -> "Cluster":
        """The §VII-C two-executor setup: 192 GB nodes vs 6 TB nodes, and
        optionally a with-package vs without-package pool pair."""
        base_pkgs = frozenset({"numpy", "jax"})
        pools = [
            ResourcePool("small-mem", [
                Node(name=f"small-n{i:03d}", memory_gb=192.0, packages=base_pkgs,
                     workers_per_node=workers_per_node)
                for i in range(small_nodes)]),
            ResourcePool("big-mem", [
                Node(name=f"big-n{i:03d}", memory_gb=6144.0, packages=base_pkgs,
                     workers_per_node=workers_per_node)
                for i in range(big_nodes)]),
        ]
        if with_pkg_pool:
            pools = [
                ResourcePool("no-pkg", [
                    Node(name=f"nopkg-n{i:03d}", memory_gb=192.0,
                         packages=base_pkgs, workers_per_node=workers_per_node)
                    for i in range(small_nodes)]),
                ResourcePool("with-pkg", [
                    Node(name=f"pkg-n{i:03d}", memory_gb=192.0,
                         packages=base_pkgs | {package},
                         workers_per_node=workers_per_node)
                    for i in range(big_nodes)]),
            ]
        return Cluster(pools)
