"""Workflow scopes: the task-hierarchy layer of the public API.

The paper's core observation is that TBPP workloads are *hierarchies* —
applications contain workflows contain sub-workflows contain tasks — and
resilience decisions should follow that structure (§III, §V).  A
:class:`Workflow` makes the hierarchy first-class: it is a named scope
created from a :class:`~repro.engine.dfk.DataFlowKernel`, tasks invoked
inside its ``with`` block (or routed via ``TaskDef.options(workflow=...)``)
become members, and scopes nest arbitrarily deep.

Per scope you get:

* **defaults** — ``pool=`` / ``retries=`` / ``node=`` apply to member
  tasks that didn't pin their own, resolved innermost-scope-first up the
  ancestor chain;
* **policies** — ``policy=`` pushes resilience middleware
  (:mod:`repro.engine.policies`) onto member tasks' stacks, between their
  per-call policies and the engine-level stack;
* **scope-wide control** — :meth:`cancel` kills every queued *and*
  running task in the subtree (descendant scopes included, sibling scopes
  untouched), :meth:`wait` blocks on the subtree, :meth:`stats`
  aggregates it;
* **failure propagation** — ``propagate="none"`` (default) contains a
  member's terminal failure to that task; ``"siblings"`` fast-fails the
  rest of this scope's subtree; ``"ancestors"`` fast-fails the entire
  ancestor chain's subtree (the whole workflow tree this scope belongs
  to).  The *innermost* scope owning the failed task decides.
"""
from __future__ import annotations

import threading
from concurrent.futures import wait as _futures_wait
from typing import TYPE_CHECKING, Any, Iterator

from repro.engine.policies import ResiliencePolicy, normalize_policies
from repro.engine.task import TaskRecord, TaskState

if TYPE_CHECKING:  # pragma: no cover - import cycle guard
    from repro.engine.dfk import DataFlowKernel

PROPAGATE_MODES = ("none", "siblings", "ancestors")

_TERMINAL = (TaskState.COMPLETED, TaskState.FAILED, TaskState.DEP_FAILED)


class Workflow:
    """A named scope of tasks within a DataFlowKernel session."""

    _tls = threading.local()

    def __init__(self, name: str, *, dfk: "DataFlowKernel | None" = None,
                 parent: "Workflow | None" = None, pool: str | None = None,
                 retries: int | None = None, node: str | None = None,
                 policy: Any = None, propagate: str = "none",
                 checkpoint: Any = None):
        if propagate not in PROPAGATE_MODES:
            raise ValueError(
                f"propagate must be one of {PROPAGATE_MODES}, got {propagate!r}")
        if parent is None and dfk is None:
            parent = Workflow.current()
        if dfk is None:
            if parent is not None:
                dfk = parent.dfk
            else:
                from repro.engine.dfk import DataFlowKernel
                dfk = DataFlowKernel.current()
        if dfk is None:
            raise RuntimeError(
                f"workflow {name!r} created outside a DataFlowKernel session; "
                "pass dfk= or create it inside `with DataFlowKernel(...)`")
        self.name = name
        self.dfk = dfk
        self.parent = parent
        self.pool = pool
        self.retries = retries
        self.node = node
        self.policies: tuple[ResiliencePolicy, ...] = normalize_policies(policy)
        if checkpoint is not None:
            # scope-level checkpoint/restart: member tasks memoize into the
            # given TaskStore (path / store / policy), joining the scope's
            # policy chain after any explicit policies
            from repro.checkpoint.task_store import as_checkpoint_policy
            self.policies = self.policies + (as_checkpoint_policy(checkpoint),)
        self.propagate = propagate
        self.children: list["Workflow"] = []
        self._records: list[TaskRecord] = []
        self._lock = threading.Lock()
        self._cancelled = False
        self.cancel_reason: str = ""
        if parent is not None:
            parent.children.append(self)
            if parent._cancelled:   # born into a killed tree: born cancelled
                self._cancelled = True
                self.cancel_reason = parent.cancel_reason
        dfk._register_workflow(self)

    # ------------------------------------------------------------------ #
    # scoping
    # ------------------------------------------------------------------ #
    @classmethod
    def current(cls) -> "Workflow | None":
        stack = getattr(cls._tls, "stack", None)
        return stack[-1] if stack else None

    def __enter__(self) -> "Workflow":
        stack = getattr(Workflow._tls, "stack", None)
        if stack is None:
            stack = Workflow._tls.stack = []
        stack.append(self)
        return self

    def __exit__(self, *exc) -> None:
        stack = getattr(Workflow._tls, "stack", [])
        if stack and stack[-1] is self:
            stack.pop()

    def workflow(self, name: str, **kwargs: Any) -> "Workflow":
        """Create a nested sub-workflow of this scope."""
        return Workflow(name, parent=self, **kwargs)

    @property
    def path(self) -> str:
        """Hierarchy-qualified name, e.g. ``"pipeline/stage2/shard3"``."""
        if self.parent is None:
            return self.name
        return f"{self.parent.path}/{self.name}"

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        flags = f" propagate={self.propagate}" if self.propagate != "none" else ""
        return f"<Workflow {self.path!r} tasks={len(self._records)}{flags}>"

    # ------------------------------------------------------------------ #
    # membership & scope defaults
    # ------------------------------------------------------------------ #
    def _add(self, rec: TaskRecord) -> None:
        with self._lock:
            self._records.append(rec)

    @property
    def cancelled(self) -> bool:
        """True when this scope — or any ancestor — was cancelled.

        The ancestor walk covers sub-scopes created *after* their parent
        was cancelled: they must not become an escape hatch for new work
        inside a killed tree.
        """
        return any(wf._cancelled for wf in self._chain())

    def _chain(self) -> Iterator["Workflow"]:
        """This scope, then its ancestors, innermost first."""
        wf: Workflow | None = self
        while wf is not None:
            yield wf
            wf = wf.parent

    def effective_pool(self) -> str | None:
        return next((w.pool for w in self._chain() if w.pool), None)

    def effective_retries(self) -> int | None:
        return next((w.retries for w in self._chain()
                     if w.retries is not None), None)

    def effective_node(self) -> str | None:
        return next((w.node for w in self._chain() if w.node), None)

    def chain_policies(self) -> tuple[ResiliencePolicy, ...]:
        """Policy middleware contributed by the scope chain, innermost
        scope's policies first (they shadow ancestors')."""
        out: list[ResiliencePolicy] = []
        for wf in self._chain():
            out.extend(wf.policies)
        return tuple(out)

    # ------------------------------------------------------------------ #
    # subtree views
    # ------------------------------------------------------------------ #
    def subtree(self) -> Iterator["Workflow"]:
        """This scope and every descendant scope (pre-order)."""
        yield self
        for child in list(self.children):
            yield from child.subtree()

    def tasks(self) -> list[TaskRecord]:
        """Every member task record in the subtree."""
        out: list[TaskRecord] = []
        for wf in self.subtree():
            with wf._lock:
                out.extend(wf._records)
        return out

    def futures(self) -> list[Any]:
        return [rec.future for rec in self.tasks() if rec.future is not None]

    # ------------------------------------------------------------------ #
    # scope-wide control
    # ------------------------------------------------------------------ #
    def cancel(self, reason: str = "") -> int:
        """Cancel every unfinished task in the subtree (queued *and*
        running); sibling scopes are untouched.  Returns the number of
        tasks actually cancelled."""
        reason = reason or f"workflow {self.path!r} cancelled"
        for wf in self.subtree():
            wf._cancelled = True
            wf.cancel_reason = wf.cancel_reason or reason
        n = 0
        for rec in self.tasks():
            if rec.state in _TERMINAL:
                continue
            if self.dfk.cancel_task(rec.task_id, reason=reason):
                n += 1
        if self.dfk.monitor is not None:
            self.dfk.monitor.record_system_event(
                "workflow_cancelled", workflow=self.path, reason=reason,
                cancelled=n)
        return n

    def wait(self, timeout: float | None = None) -> bool:
        """Block until every task in the subtree resolved.  Returns False
        on timeout.  On a virtual-clock engine this *drives* the event
        loop instead of blocking (``timeout`` is virtual seconds)."""
        if self.dfk.clock.virtual:
            return self.dfk._drive_until(
                lambda: all(f.done() for f in self.futures()), timeout)
        pending = self.futures()
        done, not_done = _futures_wait(pending, timeout=timeout)
        return not not_done

    def stats(self) -> dict[str, Any]:
        """Aggregate state of the subtree.  Every :class:`TaskState` gets a
        bucket, so the per-state counts always sum to ``tasks``."""
        recs = self.tasks()
        by_state: dict[str, int] = {}
        retries = 0
        for rec in recs:
            by_state[rec.state.value] = by_state.get(rec.state.value, 0) + 1
            retries += rec.retry_count
        return {
            "workflow": self.path,
            "tasks": len(recs),
            "retries": retries,
            "scopes": sum(1 for _ in self.subtree()),
            "cancelled": self.cancelled,
            **{s.value: by_state.get(s.value, 0) for s in TaskState},
        }

    # ------------------------------------------------------------------ #
    # failure propagation
    # ------------------------------------------------------------------ #
    def on_member_failed(self, rec: TaskRecord) -> None:
        """A member task terminally failed: apply this scope's propagation
        policy.  Called by the engine; the innermost owning scope decides."""
        if self._cancelled or self.propagate == "none":
            return
        reason = (f"propagated failure: task {rec.task_id} ({rec.name}) "
                  f"failed in scope {self.path!r}")
        if self.propagate == "siblings":
            self.cancel(reason=reason)
        elif self.propagate == "ancestors":
            top = self
            while top.parent is not None:
                top = top.parent
            top.cancel(reason=reason)
