"""Event-driven core: one time-ordered event queue for the whole engine.

Pre-refactor the DataFlowKernel mixed three concurrency mechanisms: a
``threading.Timer`` per delayed retry, a dedicated ``_watch_loop`` polling
thread for heartbeat/straggler checks, and inline dispatch on whichever
thread happened to complete a dependency.  This module replaces all three
with a single :class:`EventLoop`: a min-heap of timestamped events drained
by one daemon thread under one lock discipline.

* **dispatches** are ``call_soon`` events (serialized on the loop thread);
* **delayed retries** are ``call_later`` events (cancellable, no Timer
  thread per retry);
* **heartbeat and straggler checks** are ``period=``-rescheduling events
  instead of a sleep-poll thread.

Event callbacks must never block for long — they run on the single loop
thread.  Exceptions raised by a callback are swallowed (a watcher bug must
not kill the engine), mirroring the old watcher loop's contract.
"""
from __future__ import annotations

import heapq
import itertools
import threading
import time
from typing import Any, Callable


class ScheduledEvent:
    """Handle for one scheduled callback; ``cancel()`` is race-safe."""

    __slots__ = ("when", "fn", "args", "name", "period", "cancelled")

    def __init__(self, when: float, fn: Callable[..., Any], args: tuple,
                 name: str, period: float | None):
        self.when = when
        self.fn = fn
        self.args = args
        self.name = name
        self.period = period       # not None => reschedules itself
        self.cancelled = False

    def cancel(self) -> None:
        self.cancelled = True

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        kind = f"every {self.period}s" if self.period else f"at {self.when:.3f}"
        return f"<ScheduledEvent {self.name!r} {kind}>"


class EventLoop:
    """Single-threaded, time-ordered event queue.

    Thread-safe producers (``call_soon`` / ``call_later`` / periodic
    events may be scheduled from any thread, including from inside a
    running callback); single consumer thread executes events in
    timestamp order, FIFO among equal timestamps.
    """

    def __init__(self, name: str = "engine-events",
                 on_error: Callable[[str, BaseException], Any] | None = None):
        self._heap: list[tuple[float, int, ScheduledEvent]] = []
        self._cond = threading.Condition()
        self._seq = itertools.count()
        self._stopped = False
        self._thread = threading.Thread(target=self._run, daemon=True, name=name)
        # observability: how many events have executed, by name
        self.dispatched: dict[str, int] = {}
        # optional hook observing swallowed callback exceptions (the DFK
        # records them as system events so watcher bugs stay visible)
        self.on_error = on_error

    # -- lifecycle --------------------------------------------------------
    def start(self) -> "EventLoop":
        self._thread.start()
        return self

    def stop(self) -> None:
        """Stop the loop; pending events are dropped (daemon semantics,
        matching the old daemon Timer threads at shutdown)."""
        with self._cond:
            self._stopped = True
            self._cond.notify_all()

    def join(self, timeout: float | None = None) -> None:
        self._thread.join(timeout)

    # -- producers --------------------------------------------------------
    def call_at(self, when: float, fn: Callable[..., Any], *args: Any,
                name: str = "", period: float | None = None) -> ScheduledEvent:
        """Schedule at an absolute ``time.monotonic()`` timestamp.

        The loop runs on the monotonic clock so a wall-clock step (NTP)
        can neither stall heartbeat/straggler checks nor fire retries
        early — parity with the ``threading.Timer``/sleep-loop mechanisms
        this replaces.
        """
        ev = ScheduledEvent(when, fn, args, name or fn.__name__, period)
        with self._cond:
            if self._stopped:
                ev.cancelled = True
                return ev
            heapq.heappush(self._heap, (ev.when, next(self._seq), ev))
            self._cond.notify_all()
        return ev

    def call_later(self, delay: float, fn: Callable[..., Any], *args: Any,
                   name: str = "") -> ScheduledEvent:
        return self.call_at(time.monotonic() + max(delay, 0.0), fn, *args, name=name)

    def call_soon(self, fn: Callable[..., Any], *args: Any,
                  name: str = "") -> ScheduledEvent:
        # stamped "now", not 0.0: a burst of soon-events must interleave
        # FIFO with already-due timers (heartbeat checks, due retries)
        # instead of starving them until the burst drains
        return self.call_at(time.monotonic(), fn, *args, name=name)

    def schedule_periodic(self, period: float, fn: Callable[..., Any],
                          *args: Any, name: str = "") -> ScheduledEvent:
        """Run ``fn`` every ``period`` seconds until cancelled/stopped."""
        return self.call_at(time.monotonic() + period, fn, *args,
                            name=name or fn.__name__, period=period)

    def pending(self) -> int:
        with self._cond:
            return sum(1 for _, _, ev in self._heap if not ev.cancelled)

    # -- consumer ---------------------------------------------------------
    def _run(self) -> None:
        while True:
            with self._cond:
                while not self._stopped:
                    if not self._heap:
                        self._cond.wait()
                        continue
                    delay = self._heap[0][0] - time.monotonic()
                    if delay <= 0:
                        break
                    self._cond.wait(timeout=delay)
                if self._stopped:
                    return
                _, _, ev = heapq.heappop(self._heap)
            if ev.cancelled:
                continue
            try:
                ev.fn(*ev.args)
            except Exception as e:  # noqa: BLE001 - an event must not kill the loop
                if self.on_error is not None:
                    try:
                        self.on_error(ev.name, e)
                    except Exception:  # noqa: BLE001 - hook bugs stay contained
                        pass
            self.dispatched[ev.name] = self.dispatched.get(ev.name, 0) + 1
            if ev.period is not None and not ev.cancelled:
                with self._cond:
                    if not self._stopped:
                        ev.when = time.monotonic() + ev.period
                        heapq.heappush(self._heap, (ev.when, next(self._seq), ev))
                        self._cond.notify_all()
