"""Event-driven core: one time-ordered event queue for the whole engine.

Pre-refactor the DataFlowKernel mixed three concurrency mechanisms: a
``threading.Timer`` per delayed retry, a dedicated ``_watch_loop`` polling
thread for heartbeat/straggler checks, and inline dispatch on whichever
thread happened to complete a dependency.  This module replaces all three
with a single :class:`EventLoop`: a min-heap of timestamped events drained
by one daemon thread under one lock discipline.

* **dispatches** are ``call_soon`` events (serialized on the loop thread);
* **delayed retries** are ``call_later`` events (cancellable, no Timer
  thread per retry);
* **heartbeat and straggler checks** are ``period=``-rescheduling events
  instead of a sleep-poll thread.

Event callbacks must never block for long — they run on the single loop
thread.  Exceptions raised by a callback are swallowed (a watcher bug must
not kill the engine), mirroring the old watcher loop's contract.

Time is an injected :class:`Clock`.  The default :class:`RealClock` is the
historical behaviour (monotonic scheduling timebase, wall-clock stamps, a
consumer thread that sleeps between events).  A *virtual* clock — one whose
``virtual`` attribute is true, e.g. :class:`repro.sim.VirtualClock` — flips
the loop into deterministic inline mode: ``start()`` spawns no thread, and
:meth:`EventLoop.run_until` executes events on the calling thread, jumping
the clock instantly to each event's timestamp.  A "60-second" heartbeat
-loss scenario therefore executes in microseconds, and — because a single
thread executes every event in (timestamp, FIFO) order — identically on
every run.
"""
from __future__ import annotations

import heapq
import itertools
import threading
import time
from typing import Any, Callable


class Clock:
    """Time source protocol for the engine.

    ``now()`` is the *scheduling* timebase (monotonic seconds) the event
    loop orders events by; ``time()`` is the wall-clock stamp used for
    bookkeeping (heartbeats, TTF, monitor events); ``wait(cond, timeout)``
    blocks the consumer until notified or until ``timeout`` of this
    clock's seconds elapsed; ``sleep(seconds)`` pauses the calling thread
    for that many clock seconds (virtual clocks just jump forward).
    ``virtual`` marks clocks whose time advances by decree rather than by
    the passage of real time.
    """

    virtual: bool = False

    def now(self) -> float:  # pragma: no cover - protocol
        raise NotImplementedError

    def time(self) -> float:  # pragma: no cover - protocol
        raise NotImplementedError

    def wait(self, cond: threading.Condition, timeout: float) -> None:
        """Block on ``cond`` (held) for up to ``timeout`` clock seconds."""
        raise NotImplementedError  # pragma: no cover - protocol

    def sleep(self, seconds: float) -> None:
        """Pause the calling thread for ``seconds`` of this clock's time."""
        raise NotImplementedError  # pragma: no cover - protocol


class RealClock(Clock):
    """Wall time: the engine's historical behaviour."""

    virtual = False

    def now(self) -> float:
        return time.monotonic()

    def time(self) -> float:
        return time.time()

    def wait(self, cond: threading.Condition, timeout: float) -> None:
        cond.wait(timeout=timeout)

    def sleep(self, seconds: float) -> None:
        time.sleep(seconds)


#: Shared default clock — stateless, so one instance serves every engine.
REAL_CLOCK = RealClock()


class ScheduledEvent:
    """Handle for one scheduled callback; ``cancel()`` is race-safe."""

    __slots__ = ("when", "fn", "args", "name", "period", "cancelled")

    def __init__(self, when: float, fn: Callable[..., Any], args: tuple,
                 name: str, period: float | None):
        self.when = when
        self.fn = fn
        self.args = args
        self.name = name
        self.period = period       # not None => reschedules itself
        self.cancelled = False

    def cancel(self) -> None:
        self.cancelled = True

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        kind = f"every {self.period}s" if self.period else f"at {self.when:.3f}"
        return f"<ScheduledEvent {self.name!r} {kind}>"


class EventLoop:
    """Single-threaded, time-ordered event queue.

    Thread-safe producers (``call_soon`` / ``call_later`` / periodic
    events may be scheduled from any thread, including from inside a
    running callback); single consumer thread executes events in
    timestamp order, FIFO among equal timestamps.

    With a virtual ``clock`` the consumer thread is replaced by
    :meth:`run_until`: the caller's thread drains the heap inline,
    advancing the clock to each event's timestamp — no waiting, no
    threads, fully deterministic.
    """

    def __init__(self, name: str = "engine-events",
                 on_error: Callable[[str, BaseException], Any] | None = None,
                 clock: Clock | None = None):
        self.clock = clock or REAL_CLOCK
        self._heap: list[tuple[float, int, ScheduledEvent]] = []
        self._cond = threading.Condition()
        self._seq = itertools.count()
        self._stopped = False
        self._thread: threading.Thread | None = None
        if not self.clock.virtual:
            self._thread = threading.Thread(target=self._run, daemon=True,
                                            name=name)
        # observability: how many events have executed, by name
        self.dispatched: dict[str, int] = {}
        # optional hook observing swallowed callback exceptions (the DFK
        # records them as system events so watcher bugs stay visible)
        self.on_error = on_error

    # -- lifecycle --------------------------------------------------------
    def start(self) -> "EventLoop":
        if self._thread is not None:
            self._thread.start()
        return self

    def stop(self) -> None:
        """Stop the loop; pending events are dropped (daemon semantics,
        matching the old daemon Timer threads at shutdown)."""
        with self._cond:
            self._stopped = True
            self._cond.notify_all()

    def join(self, timeout: float | None = None) -> None:
        if self._thread is not None:
            self._thread.join(timeout)

    # -- producers --------------------------------------------------------
    def call_at(self, when: float, fn: Callable[..., Any], *args: Any,
                name: str = "", period: float | None = None) -> ScheduledEvent:
        """Schedule at an absolute ``clock.now()`` timestamp.

        The loop runs on the clock's monotonic timebase so a wall-clock
        step (NTP) can neither stall heartbeat/straggler checks nor fire
        retries early — parity with the ``threading.Timer``/sleep-loop
        mechanisms this replaces.
        """
        ev = ScheduledEvent(when, fn, args, name or fn.__name__, period)
        with self._cond:
            if self._stopped:
                ev.cancelled = True
                return ev
            heap = self._heap
            # wakeup coalescing: the consumer only needs a nudge when the
            # new event preempts the head it is already sleeping toward
            # (or the heap was empty).  Equal-timestamp bursts — the
            # call_soon fan-out storm — enqueue silently: the consumer
            # wakes for the head and drains everything due.  Inline mode
            # (virtual clock) has no consumer thread to wake at all.
            preempts = not heap or ev.when < heap[0][0]
            heapq.heappush(heap, (ev.when, next(self._seq), ev))
            if preempts and self._thread is not None:
                self._cond.notify_all()
        return ev

    def call_later(self, delay: float, fn: Callable[..., Any], *args: Any,
                   name: str = "") -> ScheduledEvent:
        return self.call_at(self.clock.now() + max(delay, 0.0), fn, *args,
                            name=name)

    def call_soon(self, fn: Callable[..., Any], *args: Any,
                  name: str = "") -> ScheduledEvent:
        # stamped "now", not 0.0: a burst of soon-events must interleave
        # FIFO with already-due timers (heartbeat checks, due retries)
        # instead of starving them until the burst drains
        return self.call_at(self.clock.now(), fn, *args, name=name)

    def schedule_periodic(self, period: float, fn: Callable[..., Any],
                          *args: Any, name: str = "") -> ScheduledEvent:
        """Run ``fn`` every ``period`` seconds until cancelled/stopped."""
        return self.call_at(self.clock.now() + period, fn, *args,
                            name=name or fn.__name__, period=period)

    def pending(self) -> int:
        with self._cond:
            return sum(1 for _, _, ev in self._heap if not ev.cancelled)

    # -- inline consumer (virtual clocks) ---------------------------------
    def run_until(self, predicate: Callable[[], bool] | None = None, *,
                  deadline: float | None = None,
                  max_events: int = 1_000_000) -> int:
        """Execute pending events inline, advancing a *virtual* clock.

        Events run on the calling thread in (timestamp, FIFO) order, the
        clock jumping to each event's timestamp — wall-clock cost is the
        callbacks themselves.  Stops when ``predicate()`` turns true
        (checked between events), when the next event lies beyond
        ``deadline`` (absolute ``clock.now()`` timestamp; the clock is
        advanced *to* the deadline so relative waits compose), when the
        heap drains, when the loop is stopped, or after ``max_events``
        (runaway-periodic backstop).  Returns the number of events
        executed.
        """
        if not self.clock.virtual:
            raise RuntimeError("run_until() requires a virtual clock; "
                               "real clocks drain on the loop thread")
        executed = 0
        # land the clock on the deadline whenever the run exhausted
        # everything scheduled before it (next-event-beyond-deadline,
        # drained heap, stopped loop) — but not when the predicate or the
        # max_events backstop cut the run short with due events remaining
        land_on_deadline = deadline is not None
        while executed < max_events:
            if predicate is not None and predicate():
                land_on_deadline = False
                break
            with self._cond:
                if self._stopped or not self._heap:
                    break
                when = self._heap[0][0]
                if deadline is not None and when > deadline:
                    break
                _, _, ev = heapq.heappop(self._heap)
            if ev.cancelled:
                continue
            self.clock.advance_to(ev.when)  # type: ignore[attr-defined]
            self._execute(ev)
            executed += 1
        else:
            land_on_deadline = False
        if land_on_deadline:
            self.clock.advance_to(deadline)  # type: ignore[attr-defined]
        return executed

    # -- consumer ---------------------------------------------------------
    def _execute(self, ev: ScheduledEvent) -> None:
        try:
            ev.fn(*ev.args)
        except Exception as e:  # noqa: BLE001 - an event must not kill the loop
            if self.on_error is not None:
                try:
                    self.on_error(ev.name, e)
                except Exception:  # noqa: BLE001 - hook bugs stay contained
                    pass
        self.dispatched[ev.name] = self.dispatched.get(ev.name, 0) + 1
        if ev.period is not None and not ev.cancelled:
            with self._cond:
                if not self._stopped:
                    ev.when = self.clock.now() + ev.period
                    heapq.heappush(self._heap, (ev.when, next(self._seq), ev))
                    # no notify: _execute only ever runs on the consumer
                    # thread (or inline under a virtual clock) — both
                    # re-examine the heap right after this returns

    def _run(self) -> None:
        while True:
            with self._cond:
                while not self._stopped:
                    if not self._heap:
                        self._cond.wait()
                        continue
                    delay = self._heap[0][0] - self.clock.now()
                    if delay <= 0:
                        break
                    self.clock.wait(self._cond, delay)
                if self._stopped:
                    return
                _, _, ev = heapq.heappop(self._heap)
            if ev.cancelled:
                continue
            self._execute(ev)
