"""Retry-handler contract between the DataFlowKernel and resilience modules.

Parsl exposes a ``retry_handler`` hook on the DFK; WRATH is implemented as
such a handler (paper §VI-B).  The baseline handler reproduces Parsl's
default behaviour: always retry on the same executor, regardless of failure
type or resource availability (paper §VII-A "Baseline").
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Any, Protocol

from repro.core.failures import FailureReport
from repro.engine.events import REAL_CLOCK


class Action(enum.Enum):
    RETRY = "retry"                      # re-execute (possibly elsewhere)
    FAIL = "fail"                        # terminal: fail-fast, no more retries
    RESTART_AND_RETRY = "restart_retry"  # restart failed component, then retry
    # proactive-plane actions (paper §IV↔§V feedback loop): emitted by the
    # ProactiveSentinel and honoured by the DFK; handlers may return them too
    PREEMPT = "preempt"                  # migrate off the current node now
    DRAIN = "drain"                      # drain the node, then retry elsewhere


@dataclass
class RetryDecision:
    action: Action
    # placement for the retry (None = scheduler default)
    target_pool: str | None = None
    target_node: str | None = None
    # rung-1 resource overrides (e.g. raise memory_gb after OOM analysis)
    resource_overrides: dict[str, Any] = field(default_factory=dict)
    # component to restart for RESTART_AND_RETRY ("worker:<node>", "manager:<node>")
    restart_component: str | None = None
    reason: str = ""
    # which retry-ladder rung produced this decision (for metrics; 0=none)
    rung: int = 0
    # dispatch delay (exponential backoff for transient contention)
    delay_s: float = 0.0


class RetryHandler(Protocol):
    def __call__(self, record: Any, report: FailureReport, context: "SchedulingContext") -> RetryDecision: ...


@dataclass
class SchedulingContext:
    """What a retry handler may inspect: the cluster view + history access.

    ``scheduler`` is the engine's active placement policy
    (:class:`repro.engine.scheduler.Scheduler`); handlers and the retry
    planner use it to choose among equally-valid rung candidates, so e.g. a
    least-loaded engine also load-balances its retries.  ``None`` preserves
    the legacy first-feasible-candidate behaviour.
    """

    cluster: Any                      # repro.engine.cluster.Cluster
    monitor: Any                      # repro.core.monitoring.MonitoringDatabase | None
    denylist: set[str] = field(default_factory=set)   # node names
    default_pool: str | None = None
    scheduler: Any = None             # repro.engine.scheduler.Scheduler | None
    # nodes denylisted by the proactive sentinel's drain (subset of
    # denylist); the policy engine's heartbeat-resume rule must not
    # un-denylist these — the sentinel owns their lifecycle (undrain)
    drained: set[str] = field(default_factory=set)
    # the engine's time source (repro.engine.events.Clock | None).
    # Handlers comparing "now" against monitor timestamps (heartbeat
    # recency, backoff windows) must read it from here so they stay
    # correct on a virtual clock.
    clock: Any = None

    def now(self) -> float:
        """Wall-clock "now" on the engine's clock (real-time fallback)."""
        clock = self.clock if self.clock is not None else REAL_CLOCK
        return clock.time()


def baseline_retry_handler(record, report: FailureReport, ctx: SchedulingContext) -> RetryDecision:
    """Parsl default: uniform retry on the same executor until retries run
    out.  Dependency failures are not retried (Parsl dep_fail semantics)."""
    from repro.core.failures import DependencyError

    if isinstance(report.exception, DependencyError):
        return RetryDecision(Action.FAIL, reason="dependency failed (dep_fail)")
    if record.retry_count >= record.max_retries:
        return RetryDecision(Action.FAIL, reason="retries exhausted")
    return RetryDecision(
        Action.RETRY,
        target_pool=report.pool or ctx.default_pool,
        reason="baseline: retry on same executor",
    )
