"""TBPP substrate: tasks, DAG, simulated cluster, executors, DataFlowKernel.

This is the Parsl-analog layer of the reproduction (paper §VI-A): a real,
runnable task-based parallel programming engine with futures and DAG
dependency resolution, executing on a simulated heterogeneous cluster.
Resilience plugs in as a composable :class:`PolicyStack`
(:mod:`repro.engine.policies`); the task hierarchy is first-class via
:class:`Workflow` scopes (:mod:`repro.engine.workflow`).  The curated
user-facing surface is re-exported by :mod:`repro.api`.
"""
from repro.engine.task import task, TaskDef, TaskRecord, AppFuture, TaskState, ResourceSpec
from repro.engine.cluster import Cluster, ResourcePool, Node, Worker
from repro.engine.events import EventLoop, ScheduledEvent
from repro.engine.executor import Executor
from repro.engine.scheduler import (
    SCHEDULERS,
    FeasibilityScheduler,
    HistoryAwareScheduler,
    LeastLoadedScheduler,
    RoundRobinScheduler,
    Scheduler,
    make_scheduler,
)
from repro.engine.policies import (
    PolicyStack,
    ProactivePolicy,
    ReplayPolicy,
    ReplicatePolicy,
    ReplicationError,
    ResiliencePolicy,
    RetryHandlerPolicy,
    StragglerPolicy,
    WrathPolicy,
    normalize_policies,
    replay,
    replicate,
)
from repro.engine.workflow import Workflow
from repro.engine.dfk import DataFlowKernel

__all__ = [
    "task",
    "TaskDef",
    "TaskRecord",
    "AppFuture",
    "TaskState",
    "ResourceSpec",
    "Cluster",
    "ResourcePool",
    "Node",
    "Worker",
    "Executor",
    "DataFlowKernel",
    "EventLoop",
    "ScheduledEvent",
    "Scheduler",
    "RoundRobinScheduler",
    "FeasibilityScheduler",
    "LeastLoadedScheduler",
    "HistoryAwareScheduler",
    "SCHEDULERS",
    "make_scheduler",
    # task-hierarchy API
    "Workflow",
    "ResiliencePolicy",
    "PolicyStack",
    "RetryHandlerPolicy",
    "WrathPolicy",
    "ProactivePolicy",
    "StragglerPolicy",
    "ReplayPolicy",
    "ReplicatePolicy",
    "ReplicationError",
    "normalize_policies",
    "replay",
    "replicate",
]
