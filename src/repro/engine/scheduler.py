"""Pluggable placement policies for the task, training and serving planes.

WRATH's hierarchical retry (paper §V-B) treats *where* a task runs as a
first-class, queryable decision.  This module extracts that decision out of
the executor into a :class:`Scheduler` strategy so every plane — the
DataFlowKernel dispatch path, the retry-ladder rungs, the training
supervisor's shard assignment and the serving driver's replica selection —
goes through one interface:

* :class:`RoundRobinScheduler` — baseline parity: cycles eligible nodes in
  pool order exactly as the pre-refactor ``Executor.select_node`` did;
* :class:`FeasibilityScheduler` — static resource-spec filtering (memory
  capacity, package environment, ulimits) before round-robin, so a task
  that can never run on a node is never placed there;
* :class:`LeastLoadedScheduler` — queue-depth-aware placement using the
  per-node load the executors expose (queued + in-flight tasks);
* :class:`HistoryAwareScheduler` — consults the
  :class:`~repro.core.monitoring.MonitoringDatabase` placement history
  (success rate and mean duration per node), the scheduling-time analog of
  retry rung 3: tasks gravitate to nodes where their template historically
  succeeded fast, with one exploration pass over unobserved nodes.

Select a scheduler by instance (``DataFlowKernel(scheduler=...)``) or by
name via :func:`make_scheduler` (CLI flags in ``launch/train.py`` and the
``fig6`` benchmark use the names in :data:`SCHEDULERS`).
"""
from __future__ import annotations

import itertools
import threading
from typing import TYPE_CHECKING, Any

if TYPE_CHECKING:  # pragma: no cover - import cycle guard
    from repro.engine.cluster import Node, ResourcePool
    from repro.engine.task import TaskRecord


def node_load(node: "Node") -> float:
    """Current load of a node: queued tasks + busy workers.

    This is the per-node metric executors expose for load-aware placement;
    a slow node holds its workers busy longer and its queue backs up, so
    load alone steers traffic away from stragglers without needing to know
    node speeds.

    Both terms are O(1) reads of incrementally-maintained counters — the
    run-queue depth and the ``busy_workers`` count the pickup/release
    paths keep current — so :class:`LeastLoadedScheduler`, victim
    selection and the monitoring snapshots never rescan the worker list.
    """
    return node.task_queue.qsize() + node.busy_workers


class Scheduler:
    """Placement strategy: pick one node for a task among eligible nodes.

    ``select`` receives the *already-filtered* eligible list (healthy,
    non-denylisted, pin honoured by the caller) in pool order and returns
    the chosen node, or ``None`` to signal "no acceptable node" (the caller
    routes that through the failure path as resource starvation).
    """

    name = "base"

    def bind(self, *, cluster: Any = None, monitor: Any = None) -> "Scheduler":
        """Late-bind engine context (called by the DFK at start)."""
        return self

    def select(self, record: "TaskRecord", nodes: list["Node"], *,
               pool: "ResourcePool | None" = None) -> "Node | None":
        raise NotImplementedError

    def select_victim(self, thief: "Node", nodes: list["Node"], *,
                      pool: "ResourcePool | None" = None) -> "Node | None":
        """Pick the node an idle ``thief`` should steal queued work from.

        The work-stealing half of the placement interface: ``nodes`` is
        the already-filtered candidate list (healthy, non-denylisted,
        thief excluded) in pool order.  The default shared by every
        strategy picks the deepest run queue — the same load index
        ``select`` consumes — with ties broken by pool order (first
        wins), so victim choice is deterministic under the sim plane's
        virtual clock.  ``None`` means nothing is worth stealing.
        """
        best: "Node | None" = None
        best_depth = 0
        for n in nodes:
            depth = n.task_queue.qsize()
            if depth > best_depth:
                best, best_depth = n, depth
        return best

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"<{type(self).__name__} {self.name!r}>"


class RoundRobinScheduler(Scheduler):
    """Baseline parity: cycle eligible nodes in pool order.

    One independent counter per pool, starting at the first eligible node —
    the placement sequence of the pre-refactor ``Executor.select_node``
    (which kept one ``itertools.count`` per executor, i.e. per pool).
    Failure-free dispatch is node-for-node identical to the old engine;
    once WRATH rungs or speculation also select through this scheduler,
    their picks advance the same counter (by design: one rotation per
    pool), where the old code took the first feasible candidate instead.
    """

    name = "round_robin"

    def __init__(self) -> None:
        self._counters: dict[str, "itertools.count[int]"] = {}
        self._lock = threading.Lock()

    def select(self, record: "TaskRecord", nodes: list["Node"], *,
               pool: "ResourcePool | None" = None) -> "Node | None":
        if not nodes:
            return None
        key = pool.name if pool is not None else "?"
        with self._lock:
            counter = self._counters.get(key)
            if counter is None:
                # not setdefault: that would build (and discard) a fresh
                # itertools.count per placement once the key exists
                counter = self._counters[key] = itertools.count()
            return nodes[next(counter) % len(nodes)]


class FeasibilityScheduler(RoundRobinScheduler):
    """Static feasibility filter (memory, packages, ulimits) + round-robin.

    A node that can never satisfy the task's (possibly rung-1-corrected)
    resource spec is excluded up front instead of failing the task at run
    time; returns ``None`` when no node in the pool is feasible, which the
    DFK routes through the retry handler (and a WRATH handler escalates to
    rung 4, a different pool).
    """

    name = "feasibility"

    def select(self, record: "TaskRecord", nodes: list["Node"], *,
               pool: "ResourcePool | None" = None) -> "Node | None":
        spec = record.effective_resources()
        feasible = [n for n in nodes if n.satisfies(spec)[0]]
        return super().select(record, feasible, pool=pool)


class LeastLoadedScheduler(Scheduler):
    """Queue-depth-aware placement: pick the least-loaded eligible node.

    Load is :func:`node_load` (queued + in-flight); ties break by pool
    order, so an idle cluster degrades to first-fit and a busy one spreads.
    """

    name = "least_loaded"

    def select(self, record: "TaskRecord", nodes: list["Node"], *,
               pool: "ResourcePool | None" = None) -> "Node | None":
        if not nodes:
            return None
        return min(nodes, key=node_load)


class HistoryAwareScheduler(Scheduler):
    """Placement informed by the monitoring database's placement history.

    The scheduling-time analog of retry rung 3 ("retry where the task has
    historically succeeded"): for each task template the scheduler queries
    per-node success counts and mean durations.  Unobserved nodes are
    explored first (round-robin) so history accumulates; once every
    eligible node has history, nodes are restricted to the *good* set —
    success rate within ``rate_slack`` of the best and mean duration within
    ``duration_slack``× of the fastest — and the least-loaded good node
    wins, spreading traffic across the fast, reliable nodes.

    Exploration is load-gated: an unobserved node is only probed while it
    is idle, so a slow unknown node accumulates at most one probe task at
    a time instead of absorbing the whole submission burst while the fast
    nodes wait to be "discovered".

    Falls back to least-loaded when no monitor is bound.
    """

    name = "history"

    def __init__(self, monitor: Any = None, *, rate_slack: float = 0.25,
                 duration_slack: float = 1.5) -> None:
        self.monitor = monitor
        self._monitor_pinned = monitor is not None
        self.rate_slack = rate_slack
        self.duration_slack = duration_slack
        self._explore = RoundRobinScheduler()

    def bind(self, *, cluster: Any = None, monitor: Any = None) -> "Scheduler":
        # a constructor-supplied monitor is pinned; otherwise the scheduler
        # follows whichever engine most recently bound it, so one instance
        # reused across engines reads the *live* history database
        if monitor is not None and not self._monitor_pinned:
            self.monitor = monitor
        return self

    def select(self, record: "TaskRecord", nodes: list["Node"], *,
               pool: "ResourcePool | None" = None) -> "Node | None":
        if not nodes:
            return None
        if self.monitor is None:
            return min(nodes, key=node_load)
        hist = self.monitor.node_history(record.name)
        unseen = [n for n in nodes
                  if n.name not in hist or hist[n.name].total == 0]
        if unseen:
            idle_unseen = [n for n in unseen if node_load(n) < 1]
            if idle_unseen:
                return self._explore.select(record, idle_unseen, pool=pool)
            if len(unseen) == len(nodes):
                return min(nodes, key=node_load)
        seen = [n for n in nodes if n not in unseen]
        best_rate = max(hist[n.name].success_rate for n in seen)
        durations = [hist[n.name].avg_duration for n in seen
                     if hist[n.name].avg_duration > 0]
        best_dur = min(durations) if durations else 0.0
        good = [n for n in seen
                if hist[n.name].success_rate >= best_rate - self.rate_slack
                and (best_dur == 0.0 or hist[n.name].avg_duration
                     <= self.duration_slack * best_dur)]
        return min(good or seen, key=node_load)


SCHEDULERS: dict[str, type[Scheduler]] = {
    RoundRobinScheduler.name: RoundRobinScheduler,
    FeasibilityScheduler.name: FeasibilityScheduler,
    LeastLoadedScheduler.name: LeastLoadedScheduler,
    HistoryAwareScheduler.name: HistoryAwareScheduler,
}


def make_scheduler(name: str, *, monitor: Any = None) -> Scheduler:
    """Build a scheduler by name (see :data:`SCHEDULERS` for choices)."""
    try:
        cls = SCHEDULERS[name.replace("-", "_")]
    except KeyError:
        raise ValueError(
            f"unknown scheduler {name!r}; choose from {sorted(SCHEDULERS)}"
        ) from None
    sched = cls()
    return sched.bind(monitor=monitor)
