"""Executors: schedule tasks from the DFK onto node managers (paper §VI-A).

One :class:`Executor` wraps one :class:`~repro.engine.cluster.ResourcePool`
(the Parsl executor ↔ resource-pool correspondence the paper's hierarchical
retry rung 4 moves tasks across).  The executor maintains the pool's node
managers, relays worker results back to the DFK, and exposes per-node load
metrics — but *node selection is delegated to an injected*
:class:`~repro.engine.scheduler.Scheduler` (round-robin by default, for
baseline parity).  Placement pins from the retry handler
(``record.target_node``) are honoured before the scheduler is consulted.
"""
from __future__ import annotations

import threading
from typing import Any, Callable

from repro.core.failures import PilotJobInitError
from repro.engine.cluster import Node, NodeManager, ResourcePool
from repro.engine.events import REAL_CLOCK, Clock
from repro.engine.scheduler import RoundRobinScheduler, Scheduler, node_load
from repro.engine.task import TaskRecord


class Executor:
    def __init__(
        self,
        pool: ResourcePool,
        on_result: Callable[[TaskRecord, Any, BaseException | None, Any], None],
        *,
        scheduler: Scheduler | None = None,
        heartbeat: Callable[[str, float], None] | None = None,
        denylisted: Callable[[str], bool] = lambda node: False,
        heartbeat_period: float = 0.05,
        clock: Clock | None = None,
        steal: bool = False,
        on_steal: Callable[[TaskRecord, str, str], None] | None = None,
    ):
        self.pool = pool
        self.on_result = on_result
        self.scheduler = scheduler or RoundRobinScheduler()
        self.denylisted = denylisted
        self.managers: dict[str, NodeManager] = {}
        self._lock = threading.Lock()
        self._heartbeat = heartbeat
        self._heartbeat_period = heartbeat_period
        self.clock = clock or REAL_CLOCK
        # decentralized work stealing: idle workers pull queued records off
        # loaded siblings via steal_task(); on_steal(rec, victim, thief) is
        # the DFK bookkeeping callback fired before the thief runs it
        self.steal = steal
        self.on_steal = on_steal
        self._started = False

    # -- pilot-job lifecycle ---------------------------------------------
    def _make_manager(self, node: Node) -> NodeManager:
        return NodeManager(node, self.on_result, self._heartbeat,
                           heartbeat_period=self._heartbeat_period,
                           clock=self.clock,
                           steal_source=self.steal_task if self.steal
                           else None)

    def start(self) -> None:
        failures = []
        for node in self.pool.nodes:
            mgr = self._make_manager(node)
            node.manager = mgr
            try:
                mgr.start()
                self.managers[node.name] = mgr
            except PilotJobInitError as e:
                failures.append(e)
        self._started = True
        if failures and not self.managers:
            raise PilotJobInitError(
                f"all pilot jobs failed in pool {self.pool.name}: {failures[0]}")

    def stop(self) -> None:
        for mgr in self.managers.values():
            mgr.stop()
        self._started = False

    # -- elastic membership ------------------------------------------------
    def add_node(self, node: Node) -> None:
        """A node joins the running pool: pilot job starts immediately and
        the scheduler sees it on the next placement."""
        self.pool.add_node(node)
        mgr = self._make_manager(node)
        node.manager = mgr
        if self._started:
            mgr.start()
            self.managers[node.name] = mgr

    def remove_node(self, node_name: str) -> Node | None:
        """A node leaves the running pool: pilot job stops, placement
        stops immediately.  The caller sweeps any assigned work first."""
        mgr = self.managers.pop(node_name, None)
        if mgr is not None:
            mgr.stop()
        return self.pool.remove_node(node_name)

    # -- scheduling --------------------------------------------------------
    def eligible_nodes(self, record: TaskRecord) -> list[Node]:
        """Healthy, non-denylisted nodes in pool order.

        Static feasibility (spec vs. node) is NOT applied here — baseline
        Parsl does not check it; feasibility-aware placement is the job of
        :class:`~repro.engine.scheduler.FeasibilityScheduler` or of WRATH
        pinning ``target_node``/``target_pool``.
        """
        # one pass, one list: health and denylist checks fused (this runs
        # once per placement, so the extra healthy_nodes() round-trip and
        # intermediate list were pure overhead at 100k-task scale)
        denylisted = self.denylisted
        return [n for n in self.pool.nodes
                if n.healthy and not denylisted(n.name)]

    def select_node(self, record: TaskRecord) -> Node | None:
        if record.target_node:
            n = next((n for n in self.pool.nodes if n.name == record.target_node), None)
            if n is not None and n.healthy and not self.denylisted(n.name):
                return n
        return self.scheduler.select(record, self.eligible_nodes(record),
                                     pool=self.pool)

    def submit(self, record: TaskRecord) -> Node | None:
        """Queue the task on a node; returns the chosen node (None = no node)."""
        node = self.select_node(record)
        if node is None:
            return None
        for w in node.workers:
            if w.alive:
                break
        else:
            # every worker on the target died (e.g. killed mid-task) and the
            # manager's periodic respawn hasn't fired yet: respawn now so
            # the submission doesn't stall for up to a heartbeat period
            mgr = self.managers.get(node.name)
            if mgr is not None:
                mgr.restart_dead_workers()
        node.task_queue.put(record)
        return node

    # -- work stealing -----------------------------------------------------
    def steal_task(self, thief: Node) -> TaskRecord | None:
        """Steal one queued record for an idle ``thief`` node.

        Victim selection goes through the scheduler interface
        (:meth:`~repro.engine.scheduler.Scheduler.select_victim`, fed by
        the same O(1) load index placement uses); the removal takes the
        *newest* stealable record off the victim's run-queue tail.  A
        record is stealable only when nothing pinned it (``target_node``
        pins cover retry-rung placement; speculative copies are excluded
        outright so a racing copy can't migrate away from the diversity
        it was launched for), no cancellation or resolution raced it, and
        the thief can statically satisfy its resource spec.  ``on_steal``
        fires before the record is handed over, so the DFK re-points its
        assignment table while the task is still invisible to the thief's
        execution path.
        """
        if not self.steal or not thief.healthy or self.denylisted(thief.name):
            return None
        victims = [n for n in self.pool.healthy_nodes()
                   if n is not thief and not self.denylisted(n.name)]
        victim = self.scheduler.select_victim(thief, victims, pool=self.pool)
        if victim is None:
            return None
        rec = victim.task_queue.steal_tail(
            lambda r: self._stealable(r, thief))
        if rec is None:
            return None
        if self.on_steal is not None:
            self.on_steal(rec, victim.name, thief.name)
        return rec

    def _stealable(self, rec: TaskRecord, thief: Node) -> bool:
        return (not rec.cancel_requested
                and not rec.is_speculative
                and rec.target_node is None
                and not (rec.future is not None and rec.future.done())
                and thief.satisfies(rec.effective_resources())[0])

    def cancel_queued(self, task_id: str, node_name: str) -> TaskRecord | None:
        """Real cancellation: pull a still-queued task off its node.

        Returns the removed record (truthy) if one was dequeued before any
        worker picked it up — callers inspect ``is_speculative`` to tell a
        racing copy from the original; ``None`` means nothing matching is
        queued (already running or finished) and the caller must use the
        migration/ignore path instead.
        """
        mgr = self.managers.get(node_name)
        if mgr is None:
            return None
        return mgr.cancel(task_id)

    # -- component restart (WRATH policy action) --------------------------
    def restart_workers(self, node_name: str) -> int:
        mgr = self.managers.get(node_name)
        if mgr is None:
            return 0
        return mgr.restart_dead_workers()

    # -- load metrics (scheduler inputs) -----------------------------------
    def loads(self) -> dict[str, float]:
        """Per-node load (queued + in-flight) — the metric the load-aware
        schedulers consume via :func:`~repro.engine.scheduler.node_load`."""
        return {n.name: node_load(n) for n in self.pool.nodes}

    def queued_tasks(self) -> int:
        return sum(n.task_queue.qsize() for n in self.pool.nodes)
