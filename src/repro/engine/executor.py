"""Executors: schedule tasks from the DFK onto node managers (paper §VI-A).

One :class:`Executor` wraps one :class:`~repro.engine.cluster.ResourcePool`
(the Parsl executor ↔ resource-pool correspondence the paper's hierarchical
retry rung 4 moves tasks across).  The executor maintains the pool's node
managers, performs node selection (round-robin over healthy, non-denylisted
nodes, honouring placement pins from the retry handler), and relays worker
results back to the DFK.
"""
from __future__ import annotations

import itertools
import threading
from typing import Any, Callable

from repro.core.failures import PilotJobInitError
from repro.engine.cluster import Node, NodeManager, ResourcePool
from repro.engine.task import TaskRecord


class Executor:
    def __init__(
        self,
        pool: ResourcePool,
        on_result: Callable[[TaskRecord, Any, BaseException | None, Any], None],
        *,
        heartbeat: Callable[[str, float], None] | None = None,
        denylisted: Callable[[str], bool] = lambda node: False,
        heartbeat_period: float = 0.05,
    ):
        self.pool = pool
        self.on_result = on_result
        self.denylisted = denylisted
        self.managers: dict[str, NodeManager] = {}
        self._rr = itertools.count()
        self._lock = threading.Lock()
        self._heartbeat = heartbeat
        self._heartbeat_period = heartbeat_period
        self._started = False

    # -- pilot-job lifecycle ---------------------------------------------
    def start(self) -> None:
        failures = []
        for node in self.pool.nodes:
            mgr = NodeManager(node, self.on_result, self._heartbeat,
                              heartbeat_period=self._heartbeat_period)
            node.manager = mgr
            try:
                mgr.start()
                self.managers[node.name] = mgr
            except PilotJobInitError as e:
                failures.append(e)
        self._started = True
        if failures and not self.managers:
            raise PilotJobInitError(
                f"all pilot jobs failed in pool {self.pool.name}: {failures[0]}")

    def stop(self) -> None:
        for mgr in self.managers.values():
            mgr.stop()
        self._started = False

    # -- scheduling --------------------------------------------------------
    def eligible_nodes(self, record: TaskRecord) -> list[Node]:
        spec = record.effective_resources()
        out = []
        for n in self.pool.healthy_nodes():
            if self.denylisted(n.name):
                continue
            # static feasibility: never schedule onto a node that can't
            # possibly satisfy the spec *if the scheduler knows better*.
            # NOTE: baseline Parsl does NOT check this — feasibility-aware
            # placement only happens when WRATH pins target_node/pool.
            out.append(n)
        return out

    def select_node(self, record: TaskRecord) -> Node | None:
        if record.target_node:
            n = next((n for n in self.pool.nodes if n.name == record.target_node), None)
            if n is not None and n.healthy and not self.denylisted(n.name):
                return n
        nodes = self.eligible_nodes(record)
        if not nodes:
            return None
        with self._lock:
            return nodes[next(self._rr) % len(nodes)]

    def submit(self, record: TaskRecord) -> Node | None:
        """Queue the task on a node; returns the chosen node (None = no node)."""
        node = self.select_node(record)
        if node is None:
            return None
        node.task_queue.put(record)
        return node

    # -- component restart (WRATH policy action) --------------------------
    def restart_workers(self, node_name: str) -> int:
        mgr = self.managers.get(node_name)
        if mgr is None:
            return 0
        return mgr.restart_dead_workers()

    def queued_tasks(self) -> int:
        return sum(n.task_queue.qsize() for n in self.pool.nodes)
