"""DataFlowKernel: the central manager of the TBPP framework (paper §VI-A).

Responsibilities mirror Parsl's DFK: dependency resolution (DAG), task
scheduling onto executors, task status tracking — and the *retry handler*
hook through which WRATH's resilience module is attached (paper §VI-B).

Since the event-driven refactor the DFK is built on two injected
subsystems:

* a **scheduler** (:mod:`repro.engine.scheduler`) that owns every
  placement decision.  ``DataFlowKernel(scheduler=...)`` accepts any of
  the four strategies (round-robin, feasibility, least-loaded,
  history-aware); the default :class:`RoundRobinScheduler` reproduces the
  pre-refactor dispatch placements (failure-free runs are node-for-node
  identical).  The same scheduler instance is shared with the executors
  (per-pool dispatch) and the retry planner (rung candidate selection), so
  load- and history-awareness apply uniformly;
* an **event loop** (:mod:`repro.engine.events`) through which every
  dispatch, delayed retry, heartbeat check and straggler check flows as a
  time-ordered event — no per-retry ``threading.Timer``, no polling
  watcher thread.

The proactive refactor adds a third: an optional **proactive sentinel**
(:mod:`repro.core.proactive`, enabled with ``proactive=True``) that closes
the paper's monitoring↔resilience feedback loop.  It reviews dispatches
and retry decisions inline (predictive fast-fail) and runs a periodic
health sweep (node drain / preemptive migration) — backed by a real task
**cancellation path**: :meth:`cancel_task` pulls still-queued records off
node queues, :meth:`preempt_task` migrates queued or running tasks away
from a node, and :meth:`drain_node` evacuates a node before hard loss.

The framework-side watchers are periodic events:

* a **heartbeat watcher** that declares nodes lost when their system
  monitoring agent goes silent (paper §IV), failing in-flight tasks with
  :class:`HardwareShutdownError` so they flow through the retry handler;
* a **straggler watcher** that (optionally) speculatively re-executes
  tasks running far beyond their expected duration on a different node.
  The expected duration is *profile-derived* — the p95 of the template's
  observed durations from the monitoring database — with the static
  user-supplied ``est_duration_s`` as fallback while history accumulates.

Batched submission with backpressure is available via :meth:`map`: the
number of outstanding (submitted, unfinished) tasks is capped so a large
sweep cannot flood the executors' queues.
"""
from __future__ import annotations

import threading
import time
from typing import TYPE_CHECKING, Any, Iterable

from repro.core.failures import (
    DependencyError,
    FailureReport,
    HardwareShutdownError,
    ResourceStarvationError,
    TaskCancelledError,
)
from repro.engine.cluster import Cluster
from repro.engine.events import EventLoop
from repro.engine.executor import Executor
from repro.engine.retry_api import (
    Action,
    RetryDecision,
    SchedulingContext,
    baseline_retry_handler,
)
from repro.engine.scheduler import RoundRobinScheduler, Scheduler
from repro.engine.task import AppFuture, TaskDef, TaskRecord, TaskState, new_task_record

if TYPE_CHECKING:  # pragma: no cover - import cycle guard
    from repro.core.proactive import ProactiveConfig, ProactiveSentinel


def _iter_futures(obj: Any):
    if isinstance(obj, AppFuture):
        yield obj
    elif isinstance(obj, (list, tuple, set)):
        for x in obj:
            yield from _iter_futures(x)
    elif isinstance(obj, dict):
        for x in obj.values():
            yield from _iter_futures(x)


def _resolve(obj: Any):
    """Replace finished AppFutures inside args with their results."""
    if isinstance(obj, AppFuture):
        return obj.result(timeout=0)
    if isinstance(obj, list):
        return [_resolve(x) for x in obj]
    if isinstance(obj, tuple):
        return tuple(_resolve(x) for x in obj)
    if isinstance(obj, dict):
        return {k: _resolve(v) for k, v in obj.items()}
    return obj


class DataFlowKernel:
    _current: "DataFlowKernel | None" = None

    def __init__(
        self,
        cluster: Cluster,
        *,
        retry_handler=None,
        monitor=None,
        scheduler: Scheduler | None = None,
        proactive: "bool | ProactiveConfig | ProactiveSentinel" = False,
        default_retries: int = 2,
        default_pool: str | None = None,
        heartbeat_period: float = 0.05,
        heartbeat_threshold: float = 5.0,   # missed periods before node is lost
        speculative_execution: bool = False,
        straggler_factor: float = 3.0,
        map_backpressure: int | None = None,
    ):
        self.cluster = cluster
        self.monitor = monitor
        self.retry_handler = retry_handler or baseline_retry_handler
        self.scheduler = scheduler or RoundRobinScheduler()
        # lazy import: repro.core.proactive imports repro.engine.retry_api,
        # which initializes this package — a module-level import would cycle
        from repro.core.proactive import make_sentinel
        self.sentinel = make_sentinel(proactive)
        self.default_retries = default_retries
        self.default_pool = default_pool or next(iter(cluster.pools))
        self.heartbeat_period = heartbeat_period
        self.heartbeat_threshold = heartbeat_threshold
        self.speculative_execution = speculative_execution
        self.straggler_factor = straggler_factor
        self.map_backpressure = map_backpressure

        self.tasks: dict[str, TaskRecord] = {}
        self.executors: dict[str, Executor] = {}
        self.denylist: set[str] = set()
        self.drained: set[str] = set()   # sentinel-drained subset of denylist
        self._assignment: dict[str, tuple[str, str]] = {}  # task -> (pool, node)
        self._children: dict[str, list[TaskRecord]] = {}
        self._speculated: set[str] = set()
        # task -> (backup copy record, node it was queued on); the loser of
        # the race is cancelled when the winner finishes
        self._spec_copies: dict[str, tuple[TaskRecord, str | None]] = {}
        self._done_first: dict[str, bool] = {}
        self._resume_logged: set[str] = set()  # nodes whose resume was recorded

        self._lock = threading.RLock()
        self._all_done = threading.Condition(self._lock)
        self._outstanding = 0
        self.events = EventLoop(name="dfk-events", on_error=self._on_event_error)

        self.stats: dict[str, float] = {
            "submitted": 0, "completed": 0, "failed": 0, "dep_failed": 0,
            "retries": 0, "retry_success": 0, "wrath_overhead_s": 0.0,
            "restarts": 0, "speculations": 0, "start_time": 0.0,
            # proactive plane
            "fast_fails": 0, "preemptions": 0, "drains": 0, "cancelled": 0,
        }

    # ------------------------------------------------------------------ #
    # lifecycle
    # ------------------------------------------------------------------ #
    def __enter__(self) -> "DataFlowKernel":
        self.start()
        DataFlowKernel._current = self
        return self

    def __exit__(self, *exc) -> None:
        DataFlowKernel._current = None
        self.shutdown()

    @classmethod
    def current(cls) -> "DataFlowKernel | None":
        return cls._current

    def start(self) -> None:
        self.stats["start_time"] = time.time()
        self.scheduler.bind(cluster=self.cluster, monitor=self.monitor)
        hb = self.monitor.heartbeat if self.monitor is not None else None
        for name, pool in self.cluster.pools.items():
            ex = Executor(
                pool, self._on_result, scheduler=self.scheduler, heartbeat=hb,
                denylisted=lambda node: node in self.denylist,
                heartbeat_period=self.heartbeat_period)
            ex.start()
            self.executors[name] = ex
        self.events.start()
        self.events.schedule_periodic(
            self.heartbeat_period, self._check_heartbeats, name="heartbeat-check")
        if self.speculative_execution:
            self.events.schedule_periodic(
                self.heartbeat_period, self._check_stragglers,
                name="straggler-check")
        if self.sentinel is not None:
            self.sentinel.attach(self)

    def shutdown(self) -> None:
        if self.sentinel is not None:
            self.sentinel.detach()
        self.events.stop()
        for ex in self.executors.values():
            ex.stop()

    def context(self) -> SchedulingContext:
        return SchedulingContext(
            cluster=self.cluster, monitor=self.monitor,
            denylist=self.denylist, default_pool=self.default_pool,
            scheduler=self.scheduler, drained=self.drained)

    def _on_event_error(self, event_name: str, err: BaseException) -> None:
        """Swallowed watcher/callback exceptions stay visible as events."""
        if self.monitor is not None:
            self.monitor.record_system_event(
                "event_error", event=event_name, error=type(err).__name__,
                message=str(err))

    # ------------------------------------------------------------------ #
    # submission & dependency resolution
    # ------------------------------------------------------------------ #
    def submit(self, td: TaskDef, args: tuple, kwargs: dict) -> AppFuture:
        rec = new_task_record(td, args, kwargs, default_retries=self.default_retries)
        deps = list({f.task_id: f for f in _iter_futures((args, kwargs))}.values())
        rec.depends_on = [f.record for f in deps]
        with self._lock:
            self.tasks[rec.task_id] = rec
            self.stats["submitted"] += 1
            self._outstanding += 1
            pending = [f for f in deps if not f.done()]
            for f in pending:
                self._children.setdefault(f.task_id, []).append(rec)
        if self.monitor is not None:
            self.monitor.record_task_event(rec.task_id, "submitted", name=rec.name,
                                           resources=rec.resources.asdict())
        if not pending:
            if self._claim_ready(rec):
                self.events.call_soon(self._maybe_dispatch, rec, name="dispatch")
        else:
            for f in pending:
                f.add_done_callback(lambda _f, r=rec: self._dep_done(r))
        return rec.future  # type: ignore[return-value]

    def map(self, td: TaskDef, arg_iter: Iterable[Any], *,
            max_outstanding: int | None = None) -> list[AppFuture]:
        """Batched submission with an outstanding-task backpressure cap.

        Each element of ``arg_iter`` becomes one task invocation (a tuple
        element is splatted as positional args, anything else is passed as
        the single argument).  At most ``max_outstanding`` (default: the
        DFK's ``map_backpressure``; ``None`` = unlimited) tasks from this
        map are outstanding — submitted but unfinished — at once; further
        submissions block until earlier tasks finish, bounding executor
        queue depth for large sweeps.
        """
        cap = max_outstanding if max_outstanding is not None else self.map_backpressure
        if cap is not None and cap < 1:
            raise ValueError(f"max_outstanding must be >= 1, got {cap}")
        gate = threading.BoundedSemaphore(cap) if cap else None
        futures: list[AppFuture] = []
        for args in arg_iter:
            if not isinstance(args, tuple):
                args = (args,)
            if gate is not None:
                gate.acquire()
                fut = self.submit(td, args, {})
                fut.add_done_callback(lambda _f, g=gate: g.release())
            else:
                fut = self.submit(td, args, {})
            futures.append(fut)
        return futures

    def _dep_done(self, rec: TaskRecord) -> None:
        if not self._claim_ready(rec):
            return
        self.events.call_soon(self._maybe_dispatch, rec, name="dispatch")

    def _claim_ready(self, rec: TaskRecord) -> bool:
        """Atomically move PENDING -> READY once all parents resolved.

        Multiple parent futures may complete concurrently and each fires a
        callback; exactly one caller wins the claim, preventing duplicate
        dispatch (and duplicate execution) of multi-parent tasks.
        """
        with self._lock:
            if rec.state is not TaskState.PENDING:
                return False
            if not all(p.future.done() for p in rec.depends_on):  # type: ignore[union-attr]
                return False
            rec.state = TaskState.READY
            return True

    def _maybe_dispatch(self, rec: TaskRecord) -> None:
        """Dispatch a READY-claimed task (or fail it on parent failure)."""
        failed_parent = next(
            (p for p in rec.depends_on
             if p.state in (TaskState.FAILED, TaskState.DEP_FAILED)), None)
        if failed_parent is not None:
            err = DependencyError(
                f"dependency {failed_parent.task_id} ({failed_parent.name}) failed",
                root_cause=failed_parent.exception)
            report = self._make_report(rec, err, node=None, pool=None, worker=None)
            self._route_failure(rec, report, err)
            return
        # dependencies satisfied: materialize parent results into the args
        rec.args = _resolve(rec.args)
        rec.kwargs = _resolve(rec.kwargs)
        self._dispatch(rec)

    def _dispatch(self, rec: TaskRecord) -> None:
        if self._done_first.get(rec.task_id) or rec.cancel_requested:
            return  # cancelled/resolved while queued for dispatch
        if rec.first_dispatch_time <= 0:
            rec.first_dispatch_time = time.time()
        if self.sentinel is not None:
            t0 = time.perf_counter()
            reason = self.sentinel.check_dispatch(rec)
            self.stats["wrath_overhead_s"] += time.perf_counter() - t0
            if reason is not None:
                self.fast_fail_task(rec.task_id, reason)
                return
        pool_name = rec.target_pool or self.default_pool
        ex = self.executors.get(pool_name)
        if ex is None:
            err = ResourceStarvationError(f"no executor for pool {pool_name!r}")
            self._route_failure(rec, self._make_report(rec, err), err)
            return
        node = ex.submit(rec)
        if node is None:
            err = ResourceStarvationError(
                f"no eligible node in pool {pool_name!r} "
                f"(denylist={sorted(self.denylist)})", pool=pool_name)
            self._route_failure(rec, self._make_report(rec, err, pool=pool_name), err)
            return
        with self._lock:
            rec.state = TaskState.SCHEDULED
            self._assignment[rec.task_id] = (pool_name, node.name)
        if self.monitor is not None:
            self.monitor.record_task_event(
                rec.task_id, "scheduled", pool=pool_name, node=node.name,
                attempt=rec.retry_count)

    # ------------------------------------------------------------------ #
    # cancellation / preemption / drain (the proactive action surface)
    # ------------------------------------------------------------------ #
    def fast_fail_task(self, task_id: str, reason: str) -> bool:
        """Predictive fast-fail: terminally fail a destined-to-fail task."""
        err = ResourceStarvationError(reason)
        if self.cancel_task(task_id, reason=reason, exc=err):
            self.stats["fast_fails"] += 1
            return True
        return False

    def cancel_task(self, task_id: str, *, reason: str = "",
                    exc: BaseException | None = None) -> bool:
        """Terminally cancel a task, pulling it off a node queue if queued.

        The future is resolved with ``exc`` (default
        :class:`TaskCancelledError`); a record already picked up by a
        worker keeps running to completion but its result is dropped (the
        worker's ``finally`` still releases node memory).  Returns False
        when the task is unknown or already resolved.
        """
        rec = self.tasks.get(task_id)
        if rec is None:
            return False
        with self._lock:
            if self._done_first.get(task_id) or rec.state in (
                    TaskState.COMPLETED, TaskState.FAILED, TaskState.DEP_FAILED):
                return False
            rec.cancel_requested = True
            rec.cancel_reason = reason
            pool_name, node_name = self._assignment.get(task_id, (None, None))
        if node_name:
            ex = self.executors.get(pool_name or self.default_pool)
            if ex is not None:
                ex.cancel_queued(task_id, node_name)  # real dequeue if still queued
        err = exc or TaskCancelledError(reason or f"task {task_id} cancelled",
                                        task_id=task_id)
        with self._lock:
            if self._done_first.get(task_id):
                return False  # completed in the window between the two locks
            self._done_first[task_id] = True
            rec.state = TaskState.FAILED
            rec.exception = err
            rec.terminal_time = time.time()
            self.stats["cancelled"] += 1
            self.stats["failed"] += 1
        if self.monitor is not None:
            self.monitor.record_task_event(task_id, "cancelled", reason=reason)
        self._cancel_race_loser(rec, task_id)
        self._finish(rec, error=err)
        return True

    def preempt_task(self, task_id: str, *, reason: str = "") -> bool:
        """Migrate a task away from its current node (proactive PREEMPT).

        A still-queued record is *really* cancelled (pulled off the node
        queue) and re-dispatched elsewhere; a running record gets a backup
        copy on another node — first finisher wins, exactly the
        speculative-execution race — because a thread-based worker cannot
        be interrupted mid-``fn``.
        """
        rec = self.tasks.get(task_id)
        if rec is None or self._done_first.get(task_id):
            return False
        with self._lock:
            pool_name, node_name = self._assignment.get(task_id, (None, None))
        if node_name is None:
            return False
        ex = self.executors.get(pool_name or self.default_pool)
        if ex is None:
            return False
        if ex.cancel_queued(task_id, node_name):
            # real cancellation: steer the re-dispatch away from the node
            candidates = [n for n in ex.eligible_nodes(rec)
                          if n.name != node_name]
            target = self.scheduler.select(rec, candidates, pool=ex.pool)
            rec.target_node = target.name if target is not None else None
            self.events.call_soon(self._dispatch, rec, name="preempt-dispatch")
        elif task_id not in self._speculated:
            # already running: migrate via a backup copy (winner-takes-future)
            self._speculated.add(task_id)
            if self._launch_copy(rec, avoid_node=node_name) is None:
                return False
        else:
            return False  # a backup already races this task; nothing to do
        self.stats["preemptions"] += 1
        if self.monitor is not None:
            self.monitor.record_task_event(
                task_id, "preempted", node=node_name, reason=reason)
        return True

    def drain_node(self, node_name: str, *, reason: str = "",
                   preempt: bool = True) -> bool:
        """Drain a node before hard loss: stop placing, migrate in-flight.

        The node joins the denylist *and* the drained set: the policy
        engine's heartbeat-resume rule leaves drained nodes alone — only
        :meth:`undrain_node` (the sentinel, once trends recover) releases
        them.
        """
        if node_name in self.drained:
            return False
        self.drained.add(node_name)
        self.denylist.add(node_name)
        self.stats["drains"] += 1
        if self.monitor is not None:
            self.monitor.record_system_event("node_drain", node=node_name,
                                             reason=reason)
        if preempt:
            victims = [tid for tid, rec in list(self.tasks.items())
                       if self._assignment.get(tid, (None, None))[1] == node_name
                       and rec.state in (TaskState.SCHEDULED, TaskState.RUNNING)
                       and not self._done_first.get(tid)]
            for tid in victims:
                self.preempt_task(tid, reason=f"node {node_name} draining")
        return True

    def undrain_node(self, node_name: str) -> None:
        self.drained.discard(node_name)
        self.denylist.discard(node_name)
        if self.monitor is not None:
            self.monitor.record_system_event("node_undrain", node=node_name)

    def _launch_copy(self, rec: TaskRecord, *,
                     avoid_node: str | None) -> TaskRecord | None:
        """Start a backup copy of ``rec`` on a different node.

        Shared by straggler speculation and preemptive migration: the copy
        shares the original's future and task id; whichever attempt
        finishes first wins (``_done_first``), and the loser is cancelled.
        """
        pool_name, _ = self._assignment.get(rec.task_id,
                                            (self.default_pool, None))
        ex = self.executors.get(pool_name or self.default_pool)
        if ex is None:
            return None
        copy = TaskRecord(
            task_id=rec.task_id, fn=rec.fn, name=rec.name, args=rec.args,
            kwargs=rec.kwargs, resources=rec.resources,
            max_retries=0, future=rec.future)
        copy.is_speculative = True
        candidates = [c for c in ex.eligible_nodes(copy)
                      if c.name != avoid_node]
        target = self.scheduler.select(copy, candidates, pool=ex.pool)
        if target is not None:
            copy.target_node = target.name
        placed = ex.submit(copy)
        with self._lock:
            self._spec_copies[rec.task_id] = (
                copy, placed.name if placed is not None else None)
        return copy

    def _cancel_race_loser(self, winner: TaskRecord, task_id: str) -> None:
        """When one attempt resolves the task, cancel the other attempt."""
        with self._lock:
            pair = self._spec_copies.pop(task_id, None)
            if pair is None:
                return
            copy, copy_node = pair
            pool_name, orig_node = self._assignment.get(task_id, (None, None))
            original = self.tasks.get(task_id)
        loser, loser_node = ((copy, copy_node) if winner is not copy
                             else (original, orig_node))
        if loser is None or loser is winner:
            return
        loser.cancel_requested = True
        loser.cancel_reason = "lost the speculative race"
        ex = self.executors.get(pool_name or self.default_pool)
        if ex is not None and loser_node:
            ex.cancel_queued(task_id, loser_node)  # never runs if still queued

    # ------------------------------------------------------------------ #
    # results & failure routing
    # ------------------------------------------------------------------ #
    def _on_result(self, rec: TaskRecord, result: Any,
                   err: BaseException | None, worker: Any) -> None:
        pool, node = self._assignment.get(rec.task_id, (None, None))
        # attribute the attempt to the node that actually ran it: for a
        # speculative copy the assignment table still points at the
        # straggler, which would credit the backup's fast finish to the
        # slow node and poison the placement history
        wnode = getattr(worker, "node", None)
        if wnode is not None:
            node = wnode.name
            pool = wnode.pool.name if wnode.pool is not None else pool
        duration = rec.end_time - rec.start_time
        rec.record_attempt(node=node or "?", pool=pool or "?",
                           worker=getattr(worker, "worker_id", "?"),
                           ok=err is None, error=type(err).__name__ if err else None,
                           duration=duration)
        if self.monitor is not None:
            self.monitor.record_task_event(
                rec.task_id, "finished" if err is None else "error",
                node=node, pool=pool, duration=duration,
                error=type(err).__name__ if err else None)
            if node:
                self.monitor.record_task_placement(
                    rec.name, node, pool, ok=err is None, duration=duration,
                    memory_gb=rec.effective_resources().memory_gb)
        with self._lock:
            if self._done_first.get(rec.task_id):
                return  # another attempt (or a cancellation) resolved this task
            if err is None:
                self._done_first[rec.task_id] = True
                rec.state = TaskState.COMPLETED
                if rec.retry_count > 0:
                    self.stats["retry_success"] += 1
                self.stats["completed"] += 1
        if err is None:
            self._cancel_race_loser(rec, rec.task_id)
            self._finish(rec, result=result)
        else:
            if rec.is_speculative:
                return  # backup copy failed; the original is still in flight
            report = self._make_report(rec, err, node=node, pool=pool,
                                       worker=getattr(worker, "worker_id", None))
            self._route_failure(rec, report, err)

    def _make_report(self, rec: TaskRecord, err: BaseException, *,
                     node: str | None = None, pool: str | None = None,
                     worker: str | None = None) -> FailureReport:
        profile: dict[str, float] = {}
        if node:
            n = self.cluster.find_node(node)
            if n is not None:
                profile = {
                    "node_memory_gb": n.memory_gb,
                    "node_mem_in_use_gb": n.mem_in_use_gb,
                    "node_speed": n.speed,
                    "node_healthy": float(n.healthy),
                    "node_ulimit_files": float(n.ulimit_files),
                }
        report = FailureReport.from_exception(
            err, task_id=rec.task_id, node=node, pool=pool, worker=worker,
            resource_profile=profile, requirements=rec.effective_resources().asdict(),
            retry_count=rec.retry_count, timestamp=time.time())
        if self.monitor is not None:
            self.monitor.report_failure(report)
        return report

    def _route_failure(self, rec: TaskRecord, report: FailureReport,
                       err: BaseException) -> None:
        t0 = time.perf_counter()
        try:
            decision = self.retry_handler(rec, report, self.context())
        except Exception as handler_err:  # noqa: BLE001 - handler bug = fail task
            decision = RetryDecision(Action.FAIL,
                                     reason=f"retry handler error: {handler_err!r}")
        # proactive second opinion: veto retries destined to fail
        if self.sentinel is not None and decision.action is not Action.FAIL:
            try:
                decision = self.sentinel.review_retry(rec, report, decision)
            except Exception as sentinel_err:  # noqa: BLE001 - sentinel bug = keep decision
                self._on_event_error("proactive-review", sentinel_err)
        self.stats["wrath_overhead_s"] += time.perf_counter() - t0

        # engine invariant: a child whose parent terminally failed can never
        # be re-executed (its arguments are unresolvable) — coerce to FAIL
        # even if a (buggy) handler says otherwise.
        if isinstance(err, DependencyError) and decision.action is not Action.FAIL:
            decision = RetryDecision(
                Action.FAIL, reason=f"dependency failure is terminal "
                                    f"(handler said {decision.action.value})")

        if self.monitor is not None:
            self.monitor.record_task_event(
                rec.task_id, "retry_decision", action=decision.action.value,
                reason=decision.reason, rung=decision.rung,
                target_pool=decision.target_pool, target_node=decision.target_node)

        if decision.action is Action.DRAIN and report.node:
            # drain the failing node, then retry the task elsewhere
            self.drain_node(report.node, reason=decision.reason)

        if decision.action is Action.RESTART_AND_RETRY and decision.restart_component:
            kind, _, where = decision.restart_component.partition(":")
            if kind == "worker" and where:
                pool, _node = self._assignment.get(rec.task_id, (None, None))
                ex = self.executors.get(pool or self.default_pool)
                if ex is not None:
                    self.stats["restarts"] += ex.restart_workers(where)

        if decision.action in (Action.RETRY, Action.RESTART_AND_RETRY,
                               Action.PREEMPT, Action.DRAIN):
            target_node = decision.target_node
            if (decision.action is Action.PREEMPT and target_node is None
                    and report.node):
                # PREEMPT's contract is "migrate off the current node": with
                # no explicit pin, steer the re-dispatch away from it
                ex = self.executors.get(decision.target_pool
                                        or report.pool or self.default_pool)
                if ex is not None:
                    candidates = [n for n in ex.eligible_nodes(rec)
                                  if n.name != report.node]
                    picked = self.scheduler.select(rec, candidates, pool=ex.pool)
                    if picked is not None:
                        target_node = picked.name
            with self._lock:
                rec.retry_count += 1
                self.stats["retries"] += 1
                rec.state = TaskState.RETRYING
                rec.target_pool = decision.target_pool
                rec.target_node = target_node
                if decision.resource_overrides:
                    rec.resource_overrides.update(decision.resource_overrides)
            # delayed retries are ordinary events on the engine loop — no
            # per-retry Timer thread
            if decision.delay_s > 0:
                self.events.call_later(decision.delay_s, self._dispatch, rec,
                                       name="delayed-retry")
            else:
                self.events.call_soon(self._dispatch, rec, name="retry-dispatch")
            return

        # terminal failure
        is_dep = isinstance(err, DependencyError)
        with self._lock:
            self._done_first[rec.task_id] = True
            rec.state = TaskState.DEP_FAILED if is_dep else TaskState.FAILED
            rec.exception = err
            rec.terminal_time = time.time()
            self.stats["dep_failed" if is_dep else "failed"] += 1
        self._finish(rec, error=err)

    def _finish(self, rec: TaskRecord, *, result: Any = None,
                error: BaseException | None = None) -> None:
        fut = rec.future
        assert fut is not None
        with self._all_done:
            if getattr(rec, "_finished", False) or fut.done():
                return  # idempotent: speculation/races must not double-set
            rec._finished = True  # type: ignore[attr-defined]
            self._outstanding -= 1
            if self._outstanding <= 0:
                self._all_done.notify_all()
        if error is None:
            fut.set_result(result)
        else:
            fut.set_exception(error)

    # ------------------------------------------------------------------ #
    # watchers: heartbeat loss + stragglers (periodic events)
    # ------------------------------------------------------------------ #
    def _check_heartbeats(self) -> None:
        if self.monitor is None:
            return
        now = time.time()
        stale_after = self.heartbeat_period * self.heartbeat_threshold
        for node_name, last in list(self.monitor.last_heartbeats().items()):
            node = self.cluster.find_node(node_name)
            if node is None:
                continue
            if now - last > stale_after:
                # silence re-arms the next resume transition even while the
                # node is denylisted — a second lost->resumed cycle must
                # produce a second heartbeat_resumed event
                self._resume_logged.discard(node_name)
                if node_name not in self.denylist:
                    # silent node: environment-layer failure detected via
                    # heartbeat loss (paper §III-B / §IV)
                    self.monitor.record_system_event(
                        "heartbeat_lost", node=node_name, stale_s=now - last)
                    self._fail_tasks_on_node(node_name)
            elif node_name in self.denylist:
                # node resumed communication: HTCondor-style un-denylist is
                # handled by the policy engine via monitor events.  Record
                # the resume once per transition, not on every check while
                # the node awaits un-denylisting.
                if node_name not in self._resume_logged:
                    self._resume_logged.add(node_name)
                    self.monitor.record_system_event(
                        "heartbeat_resumed", node=node_name)
            else:
                # healthy & trusted again: arm the next resume transition
                self._resume_logged.discard(node_name)

    def _fail_tasks_on_node(self, node_name: str) -> None:
        victims = [rec for tid, rec in self.tasks.items()
                   if self._assignment.get(tid, (None, None))[1] == node_name
                   and rec.state in (TaskState.SCHEDULED, TaskState.RUNNING)
                   and not self._done_first.get(tid)]
        for rec in victims:
            err = HardwareShutdownError(
                f"node {node_name} lost (heartbeat silent)", node=node_name)
            report = self._make_report(rec, err, node=node_name,
                                       pool=self._assignment[rec.task_id][0])
            self._route_failure(rec, report, err)

    def _straggler_estimate(self, rec: TaskRecord) -> float:
        """Expected duration for straggler detection.

        Profile-derived (template p95 from the monitoring database) when
        enough history exists; the static user-declared ``est_duration_s``
        is the cold-start fallback.  0.0 = no estimate, no detection.
        """
        if self.monitor is not None:
            est = self.monitor.expected_duration(rec.name)
            if est > 0:
                return est
        return rec.resources.est_duration_s

    def _check_stragglers(self) -> None:
        now = time.time()
        for tid, rec in list(self.tasks.items()):
            if self._done_first.get(tid) or tid in self._speculated:
                continue
            # only tasks a worker actually picked up accrue runtime — the
            # RUNNING transition is set by the worker on pickup
            if rec.state is not TaskState.RUNNING or rec.start_time <= 0:
                continue
            est = self._straggler_estimate(rec)
            if est <= 0:
                continue
            if now - rec.start_time > self.straggler_factor * est:
                self._speculated.add(tid)
                self.stats["speculations"] += 1
                _, node = self._assignment.get(tid, (self.default_pool, None))
                copy = self._launch_copy(rec, avoid_node=node)
                if copy is not None and self.monitor is not None:
                    self.monitor.record_task_event(
                        tid, "speculative_copy", original_node=node)

    # ------------------------------------------------------------------ #
    # sync helpers
    # ------------------------------------------------------------------ #
    def wait_all(self, timeout: float | None = None) -> bool:
        with self._all_done:
            if self._outstanding <= 0:
                return True
            return self._all_done.wait(timeout)

    def makespan(self) -> float:
        return time.time() - self.stats["start_time"]

    def success_rates(self) -> dict[str, float]:
        total = self.stats["submitted"]
        retried = self.stats["retries"]
        return {
            "task_success_rate": self.stats["completed"] / total if total else 0.0,
            "retry_success_rate": (self.stats["retry_success"] / retried) if retried else 0.0,
            "tasks": total,
            "retries": retried,
        }

    def failed_task_ttfs(self, *, include_dep_failed: bool = False) -> list[float]:
        """Per-task time-to-failure (first dispatch -> terminal) of failed
        tasks; dependency-wait before the first placement is excluded.

        The proactive plane's headline metric: destined-to-fail tasks
        should terminate sooner (fig 4's normalized TTF < 1).  Dep-failed
        children are excluded by default: their terminal time is gated by
        when their *healthy* sibling parents finish, which says nothing
        about how fast the doomed parent itself was terminated.
        """
        states = ((TaskState.FAILED, TaskState.DEP_FAILED)
                  if include_dep_failed else (TaskState.FAILED,))
        return [rec.terminal_time - (rec.first_dispatch_time or rec.submit_time)
                for rec in self.tasks.values()
                if rec.terminal_time > 0 and rec.submit_time > 0
                and rec.state in states]
