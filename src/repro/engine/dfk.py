"""DataFlowKernel: the central manager of the TBPP framework (paper §VI-A).

Responsibilities mirror Parsl's DFK: dependency resolution (DAG), task
scheduling onto executors, task status tracking — and the *retry handler*
hook through which WRATH's resilience module is attached (paper §VI-B).

Since the event-driven refactor the DFK is built on two injected
subsystems:

* a **scheduler** (:mod:`repro.engine.scheduler`) that owns every
  placement decision.  ``DataFlowKernel(scheduler=...)`` accepts any of
  the four strategies (round-robin, feasibility, least-loaded,
  history-aware); the default :class:`RoundRobinScheduler` reproduces the
  pre-refactor dispatch placements (failure-free runs are node-for-node
  identical).  The same scheduler instance is shared with the executors
  (per-pool dispatch) and the retry planner (rung candidate selection), so
  load- and history-awareness apply uniformly;
* an **event loop** (:mod:`repro.engine.events`) through which every
  dispatch, delayed retry, heartbeat check and straggler check flows as a
  time-ordered event — no per-retry ``threading.Timer``, no polling
  watcher thread.

The proactive refactor adds a third: an optional **proactive sentinel**
(:mod:`repro.core.proactive`, enabled with ``proactive=True``) that closes
the paper's monitoring↔resilience feedback loop.  It reviews dispatches
and retry decisions inline (predictive fast-fail) and runs a periodic
health sweep (node drain / preemptive migration) — backed by a real task
**cancellation path**: :meth:`cancel_task` pulls still-queued records off
node queues, :meth:`preempt_task` migrates queued or running tasks away
from a node, and :meth:`drain_node` evacuates a node before hard loss.

The framework-side watchers are periodic events:

* a **heartbeat watcher** that declares nodes lost when their system
  monitoring agent goes silent (paper §IV), failing in-flight tasks with
  :class:`HardwareShutdownError` so they flow through the retry handler;
* a **straggler watcher** that (optionally) speculatively re-executes
  tasks running far beyond their expected duration on a different node.
  The expected duration is *profile-derived* — the p95 of the template's
  observed durations from the monitoring database — with the static
  user-supplied ``est_duration_s`` as fallback while history accumulates.

Batched submission with backpressure is available via :meth:`map`: the
number of outstanding (submitted, unfinished) tasks is capped so a large
sweep cannot flood the executors' queues.

Since the task-hierarchy API redesign, resilience is configured through a
**composable policy stack** (:mod:`repro.engine.policies`): pass
``policy=`` a :class:`~repro.engine.policies.ResiliencePolicy` (or a list
of them) and every lifecycle transition — submit, dispatch, running,
failure, result, periodic tick — flows through the stack, with the first
decisive :class:`RetryDecision` winning and Parsl's baseline retry as the
terminal fallback.  Stacks resolve per task invocation: per-call policies
(``TaskDef.options(policy=...)``) run first, then the enclosing
:class:`~repro.engine.workflow.Workflow` chain, then the engine stack.
The historical kwargs — ``retry_handler=``, ``proactive=``,
``speculative_execution=`` — still work but are deprecated shims that
adapt into single-element policy stacks.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Any, Iterable

from repro.core.failures import (
    DependencyError,
    FailureReport,
    HardwareShutdownError,
    ResourceStarvationError,
    TaskCancelledError,
)
from repro.engine.cluster import Cluster
from repro.engine.events import REAL_CLOCK, Clock, EventLoop
from repro.engine.executor import Executor
from repro.engine.policies import (
    PolicyStack,
    ProactivePolicy,
    ResiliencePolicy,
    normalize_policies,
    shim_legacy_kwargs,
)
from repro.engine.retry_api import (
    Action,
    RetryDecision,
    SchedulingContext,
)
from repro.engine.scheduler import RoundRobinScheduler, Scheduler
from repro.engine.task import AppFuture, TaskDef, TaskRecord, TaskState, new_task_record
from repro.engine.workflow import Workflow


# map() internals: distinguish "no positional args" and "iterator ran dry"
# from legitimate user values (None, (), ...)
_NO_ARGS = object()
_EXHAUSTED = object()


def _iter_futures(obj: Any):
    if isinstance(obj, AppFuture):
        yield obj
    elif isinstance(obj, (list, tuple, set)):
        for x in obj:
            yield from _iter_futures(x)
    elif isinstance(obj, dict):
        for x in obj.values():
            yield from _iter_futures(x)


def _resolve(obj: Any):
    """Replace finished AppFutures inside args with their results."""
    if isinstance(obj, AppFuture):
        return obj.result(timeout=0)
    if isinstance(obj, list):
        return [_resolve(x) for x in obj]
    if isinstance(obj, tuple):
        return tuple(_resolve(x) for x in obj)
    if isinstance(obj, dict):
        return {k: _resolve(v) for k, v in obj.items()}
    return obj


class DataFlowKernel:
    _current: "DataFlowKernel | None" = None

    def __init__(
        self,
        cluster: Cluster,
        *,
        policy: Any = None,
        checkpoint: Any = None,          # TaskStore | CheckpointPolicy | path
        retry_handler=None,              # deprecated: use policy=
        monitor=None,
        scheduler: Scheduler | None = None,
        work_stealing: bool = False,
        proactive: Any = False,          # deprecated: use policy=[ProactivePolicy()]
        default_retries: int = 2,
        default_pool: str | None = None,
        heartbeat_period: float = 0.05,
        heartbeat_threshold: float = 5.0,   # missed periods before node is lost
        speculative_execution: bool = False,  # deprecated: StragglerPolicy
        straggler_factor: float = 3.0,
        map_backpressure: int | None = None,
        clock: Clock | None = None,
        executor_factory: Any = None,
        _warn_legacy: bool = True,
    ):
        self.cluster = cluster
        self.monitor = monitor
        # injected time source: every timer, heartbeat check, straggler
        # sweep, retry delay and TTF stamp flows through this clock.  A
        # virtual clock (repro.sim.VirtualClock) runs the whole engine in
        # deterministic inline mode — see EventLoop.run_until.
        self.clock = clock or REAL_CLOCK
        # executor construction hook: (dfk, pool) -> Executor.  The sim
        # plane swaps in SimExecutor so tasks execute inline on the event
        # loop instead of on worker threads.
        self._executor_factory = executor_factory
        self.scheduler = scheduler or RoundRobinScheduler()
        # decentralized work stealing: idle nodes pull the newest queued
        # record off the most-loaded sibling in their pool (victim picked
        # through Scheduler.select_victim).  Off by default: stealing
        # intentionally departs from the baseline round-robin placement
        # parity, and pinned/speculative records are never stolen.
        self.work_stealing = work_stealing
        # canonical resilience configuration: an ordered policy stack.  The
        # deprecated kwargs adapt into equivalent single-element stacks
        # appended after any explicitly-passed policies; checkpoint= joins
        # last so result validators ahead of it veto a commit.
        ckpt_parts: tuple = ()
        if checkpoint is not None:
            from repro.checkpoint.task_store import as_checkpoint_policy
            ckpt_parts = (as_checkpoint_policy(checkpoint),)
        self.policies = PolicyStack(
            normalize_policies(policy)
            + shim_legacy_kwargs(
                retry_handler=retry_handler, proactive=proactive,
                speculative_execution=speculative_execution,
                straggler_factor=straggler_factor, warn=_warn_legacy)
            + ckpt_parts,
            on_error=self._on_event_error)
        # engine-level task-output store (None when not checkpointing):
        # the lineage-aware memoization plane tests and tooling introspect
        self.task_store = next(
            (p.store for p in self.policies._checkpointers
             if getattr(p, "store", None) is not None), None)
        # legacy introspection points: the adapted handler/sentinel (tests
        # and tooling read dfk.sentinel.decisions)
        self.retry_handler = retry_handler
        self.sentinel = next(
            (p.sentinel for p in self.policies if isinstance(p, ProactivePolicy)),
            None)
        self.default_retries = default_retries
        self.default_pool = default_pool or next(iter(cluster.pools))
        self.heartbeat_period = heartbeat_period
        self.heartbeat_threshold = heartbeat_threshold
        self.speculative_execution = speculative_execution
        self.straggler_factor = straggler_factor
        self.map_backpressure = map_backpressure

        self.tasks: dict[str, TaskRecord] = {}
        self.executors: dict[str, Executor] = {}
        self.denylist: set[str] = set()
        self.drained: set[str] = set()   # sentinel-drained subset of denylist
        self._assignment: dict[str, tuple[str, str]] = {}  # task -> (pool, node)
        self._speculated: set[str] = set()
        # task -> [(racing copy record, node it was queued on), ...]; every
        # losing attempt is cancelled when the winner resolves the task
        self._spec_copies: dict[str, list[tuple[TaskRecord, str | None]]] = {}
        self._replicated: set[str] = set()  # tasks whose replicas launched
        # task -> number of racing copies still in flight; a terminal
        # failure of the original DEFERS while copies remain (a healthy
        # replica may still win — HPX replicate semantics), resolving with
        # the stashed error only once every attempt has failed
        self._live_copies: dict[str, int] = {}
        self._pending_terminal: dict[str, BaseException] = {}
        self._done_first: dict[str, bool] = {}
        self._resume_logged: set[str] = set()  # nodes whose resume was recorded
        self._workflows: list[Workflow] = []
        # per-call policies (TaskDef.options(policy=)) bound to this engine;
        # keyed by id so bind/unbind runs once per object.  Tickers among
        # them are tracked separately so the 50 ms policy tick stays
        # O(tickers), not O(all policies ever used)
        self._adhoc_bound: dict[int, ResiliencePolicy] = {}
        self._adhoc_tickers: list[ResiliencePolicy] = []
        # ticker policies contributed by workflow scopes, collected
        # incrementally at registration so the 50 ms tick never rescans
        # the (append-only) workflow list
        self._workflow_tickers: list[ResiliencePolicy] = []
        self._ticker_ids: set[int] = set()
        # resolved-stack cache keyed by the identity tuple of the extra
        # (task + workflow) parts: a policied workflow's map() submits
        # thousands of tasks but builds one PolicyStack.  Cached stacks
        # hold strong refs to their policies, keeping the ids stable.
        self._stack_cache: dict[tuple, PolicyStack] = {}
        self._started = False
        self._shutting_down = False

        # LOCKING DISCIPLINE: _lock guards the bookkeeping tables (tasks,
        # stats, assignment, race/copy state) and nothing else.  Policy
        # hooks, future resolution (set_result / set_exception and the
        # done-callbacks they fire) and monitor writes always run OUTSIDE
        # it — a callback that re-enters the engine (submit, cancel_task,
        # preempt_task) while the lock is held would deadlock non-reentrant
        # callers and inflates the critical section for every thread.
        self._lock = threading.RLock()
        self._all_done = threading.Condition(self._lock)
        self._outstanding = 0
        # batched dispatch: ready submissions land here and one "dispatch"
        # drain event places the whole burst — one event-loop entry and one
        # bookkeeping lock acquisition per batch instead of per task
        self._dispatch_queue: deque[TaskRecord] = deque()
        self._drain_scheduled = False
        self._dispatch_lock = threading.Lock()
        self.events = EventLoop(name="dfk-events", on_error=self._on_event_error,
                                clock=self.clock)

        self.stats: dict[str, float] = {
            "submitted": 0, "completed": 0, "failed": 0, "dep_failed": 0,
            "retries": 0, "retry_success": 0, "wrath_overhead_s": 0.0,
            "restarts": 0, "speculations": 0, "start_time": 0.0,
            # proactive plane
            "fast_fails": 0, "preemptions": 0, "drains": 0, "cancelled": 0,
            # replicate(n) racing copies
            "replicas": 0,
            # lineage-aware checkpoint plane: tasks resolved from the store
            "memo_hits": 0,
            # decentralized work stealing: queued records migrated to an
            # idle node (one count per hop)
            "steals": 0,
            # elastic cluster membership
            "joins": 0, "leaves": 0,
        }

    # ------------------------------------------------------------------ #
    # lifecycle
    # ------------------------------------------------------------------ #
    def __enter__(self) -> "DataFlowKernel":
        self.start()
        DataFlowKernel._current = self
        return self

    def __exit__(self, *exc) -> None:
        DataFlowKernel._current = None
        self.shutdown()

    @classmethod
    def current(cls) -> "DataFlowKernel | None":
        return cls._current

    def _make_executor(self, pool) -> Executor:
        hb = self.monitor.heartbeat if self.monitor is not None else None
        return Executor(
            pool, self._on_result, scheduler=self.scheduler, heartbeat=hb,
            # the live set's bound __contains__: same live view as a
            # lambda, minus a Python frame per check on the dispatch path
            # (the set is only ever mutated in place, never rebound)
            denylisted=self.denylist.__contains__,
            heartbeat_period=self.heartbeat_period, clock=self.clock,
            steal=self.work_stealing, on_steal=self._record_steal)

    def start(self) -> None:
        self.stats["start_time"] = self.clock.time()
        self.scheduler.bind(cluster=self.cluster, monitor=self.monitor)
        factory = self._executor_factory or DataFlowKernel._make_executor
        for name, pool in self.cluster.pools.items():
            ex = factory(self, pool)
            ex.start()
            self.executors[name] = ex
        self.events.start()
        self.events.schedule_periodic(
            self.heartbeat_period, self._check_heartbeats, name="heartbeat-check")
        self.events.schedule_periodic(
            self.heartbeat_period, self._policy_tick, name="policy-tick")
        self._started = True
        self.policies.bind(self)
        for wf in list(self._workflows):
            for p in wf.policies:
                p.bind(self)

    def shutdown(self) -> None:
        self._shutting_down = True
        self.policies.unbind()
        for wf in list(self._workflows):
            for p in wf.policies:
                p.unbind()
        for p in self._adhoc_bound.values():
            p.unbind()
        self.events.stop()
        # resolve every future the engine can never run again, so no
        # AppFuture.result() call hangs on a dead kernel.  RUNNING tasks
        # are left alone: their worker finishes the in-flight fn and
        # delivers the real result (a post-shutdown *failure* is made
        # terminal by _route_failure's shutting-down guard, so those
        # futures resolve too instead of waiting on a stopped event loop).
        # Under a virtual clock there are no worker threads — a RUNNING
        # task's completion is an event on the now-stopped loop, so it can
        # never deliver; those futures must be resolved here too.
        pending = [rec for rec in list(self.tasks.values())
                   if rec.future is not None and not rec.future.done()
                   and (rec.state is not TaskState.RUNNING
                        or self.clock.virtual)]
        for rec in pending:
            self.cancel_task(
                rec.task_id, reason="DataFlowKernel shut down",
                exc=RuntimeError(
                    f"DataFlowKernel shut down while task {rec.task_id} "
                    f"({rec.name}) was {rec.state.value}"))
        # terminal failures stashed while racing copies were in flight:
        # copies that never got to run can no longer save the task
        for task_id, err in list(self._pending_terminal.items()):
            self._pending_terminal.pop(task_id, None)
            rec = self.tasks.get(task_id)
            if rec is not None:
                self._fail_terminally(rec, err)
        for ex in self.executors.values():
            ex.stop()
        self._started = False

    def workflow(self, name: str, **kwargs: Any) -> Workflow:
        """Create a top-level :class:`Workflow` scope on this kernel."""
        return Workflow(name, dfk=self, **kwargs)

    def _register_workflow(self, wf: Workflow) -> None:
        self._workflows.append(wf)
        for p in wf.policies:
            if (type(p).on_tick is not ResiliencePolicy.on_tick
                    and id(p) not in self._ticker_ids):
                self._ticker_ids.add(id(p))
                self._workflow_tickers.append(p)
        if self._started:
            for p in wf.policies:
                p.bind(self)

    def _policy_tick(self) -> None:
        """Periodic ``on_tick`` fan-out over engine + workflow policies."""
        tickers = list(self.policies._tickers)
        seen = {id(p) for p in tickers}
        for p in (*self._workflow_tickers, *self._adhoc_tickers):
            if id(p) not in seen:
                seen.add(id(p))
                tickers.append(p)
        if not tickers:
            return
        t0 = time.perf_counter()
        ctx = self.context()
        for p in tickers:
            try:
                p.on_tick(ctx)
            except Exception as err:  # noqa: BLE001 - a policy bug must not kill the tick
                self._on_event_error("policy-tick", err)
        self.stats["wrath_overhead_s"] += time.perf_counter() - t0

    def context(self) -> SchedulingContext:
        return SchedulingContext(
            cluster=self.cluster, monitor=self.monitor,
            denylist=self.denylist, default_pool=self.default_pool,
            scheduler=self.scheduler, drained=self.drained,
            clock=self.clock)

    def _on_event_error(self, event_name: str, err: BaseException) -> None:
        """Swallowed watcher/callback exceptions stay visible as events."""
        if self.monitor is not None:
            self.monitor.record_system_event(
                "event_error", event=event_name, error=type(err).__name__,
                message=str(err))

    # ------------------------------------------------------------------ #
    # submission & dependency resolution
    # ------------------------------------------------------------------ #
    def _resolve_stack(self, td: TaskDef, wf: Workflow | None) -> PolicyStack:
        """Per-invocation policy stack: task > workflow chain > engine."""
        parts = normalize_policies(td.policy)
        if wf is not None:
            parts = parts + wf.chain_policies()
        if not parts:
            return self.policies          # common case: share the engine stack
        key = tuple(id(p) for p in parts)
        with self._lock:
            cached = self._stack_cache.get(key)
        if cached is not None:
            return cached
        # per-call policies must participate in the engine lifecycle like
        # engine/workflow ones: bind them (idempotent) and register any
        # tickers so the periodic policy tick reaches them too.  bind() is
        # policy code — it runs outside _lock; the registry mutations
        # themselves are guarded so concurrent submitters can't corrupt it
        for p in parts:
            with self._lock:
                fresh = id(p) not in self._adhoc_bound
                if fresh:
                    self._adhoc_bound[id(p)] = p
                    if type(p).on_tick is not ResiliencePolicy.on_tick:
                        self._adhoc_tickers.append(p)
            if fresh:
                p.bind(self)
        stack = PolicyStack(parts + self.policies.policies,
                            on_error=self._on_event_error)
        with self._lock:
            return self._stack_cache.setdefault(key, stack)

    def submit(self, td: TaskDef, args: tuple, kwargs: dict) -> AppFuture:
        if self._shutting_down:
            # PR-3 contract: shutdown resolves every pending future with
            # RuntimeError — a post-shutdown submit must not hang either.
            # The task is never registered (no _outstanding increment, no
            # event on the stopped loop); its future resolves immediately.
            rec = new_task_record(td, args, kwargs, default_retries=0,
                                  now=self.clock.time())
            rec.state = TaskState.FAILED
            rec.exception = RuntimeError(
                f"DataFlowKernel is shut down: cannot submit task "
                f"{td.name!r}")
            rec.future.set_exception(rec.exception)  # type: ignore[union-attr]
            return rec.future  # type: ignore[return-value]
        # hierarchy resolution: an explicit options(workflow=...) pin wins,
        # else the thread's innermost active scope (None = engine root)
        wf = td.workflow if td.workflow is not None else Workflow.current()
        default_retries = self.default_retries
        if td.max_retries is None and wf is not None:
            wf_retries = wf.effective_retries()
            if wf_retries is not None:
                default_retries = wf_retries
        rec = new_task_record(td, args, kwargs, default_retries=default_retries,
                              now=self.clock.time())
        rec.workflow = wf
        rec.pool_default = td.pool or (wf.effective_pool() if wf else None)
        if wf is not None and rec.target_node is None:
            rec.target_node = wf.effective_node()
        rec.stack = self._resolve_stack(td, wf)
        if rec.stack.wants_running:
            rec.on_running = self._notify_running
        # dependency scan: the generic walk handles futures nested inside
        # containers, but the overwhelmingly common sweep shape — scalar
        # positional args, no kwargs — needs only one isinstance per arg
        # to prove there is nothing to walk
        deps: Any = ()
        if kwargs or any(isinstance(a, (AppFuture, list, tuple, set, dict))
                         for a in args):
            deps = list({f.task_id: f
                         for f in _iter_futures((args, kwargs))}.values())
            if deps:
                rec.depends_on = [f.record for f in deps]
        with self._lock:
            self.tasks[rec.task_id] = rec
            self.stats["submitted"] += 1
            self._outstanding += 1
            pending = [f for f in deps if not f.done()] if deps else ()
            if not pending:
                # claim READY inline under the registration lock (no second
                # acquisition): dependency callbacks aren't registered yet,
                # so nothing else can race the PENDING->READY transition
                rec.state = TaskState.READY
        try:
            if wf is not None:
                wf._add(rec)
            if self.monitor is not None:
                scope = {"workflow": wf.path} if wf is not None else {}
                self.monitor.record_task_event(
                    rec.task_id, "submitted", name=rec.name,
                    resources=rec.resources.asdict(), **scope)
            if wf is not None and wf.cancelled:
                # submissions into a cancelled scope resolve immediately
                self.cancel_task(rec.task_id,
                                 reason=f"workflow {wf.path!r} is cancelled")
                return rec.future  # type: ignore[return-value]
            if rec.stack._submitters:
                t0 = time.perf_counter()
                rec.stack.on_submit(rec, self.context())
                self.stats["wrath_overhead_s"] += time.perf_counter() - t0
            if not pending:
                self._enqueue_dispatch(rec)
            else:
                for f in pending:
                    f.add_done_callback(lambda _f, r=rec: self._dep_done(r))
        except BaseException as sub_err:
            # a submission that dies after registering must not leave a
            # phantom outstanding task behind (wait_all would never return
            # and a map() sweep would lose capacity forever)
            with self._all_done:
                if not rec._finished:
                    self.tasks.pop(rec.task_id, None)
                    self.stats["submitted"] -= 1
                    self._outstanding -= 1
                    if self._outstanding <= 0:
                        self._all_done.notify_all()
            # the record may already sit in a workflow scope's member list:
            # resolve its future so Workflow.wait()/futures() can't hang on
            # a task the engine disowned
            if rec.future is not None and not rec.future.done():
                rec.state = TaskState.FAILED
                rec.exception = RuntimeError(
                    f"submission of task {rec.task_id} ({rec.name}) "
                    f"failed: {sub_err!r}")
                rec.future.set_exception(rec.exception)
            raise
        return rec.future  # type: ignore[return-value]

    def _notify_running(self, rec: TaskRecord) -> None:
        """Worker RUNNING-transition callback -> policy ``on_running``."""
        stack = rec.stack
        if stack is not None:
            stack.on_running(rec, self.context())

    def map(self, td: TaskDef, arg_iter: Iterable[Any] | None = None, *,
            kwargs_iter: Iterable[dict] | None = None, unpack: bool = True,
            max_outstanding: int | None = None) -> list[AppFuture]:
        """Batched submission with an outstanding-task backpressure cap.

        Each element of ``arg_iter`` becomes one task invocation.  With
        ``unpack=True`` (the historical default) a *tuple* element is
        splatted as positional args; with ``unpack=False`` every element
        — tuples included — is passed as the single positional argument.
        ``kwargs_iter`` supplies per-invocation keyword arguments: a
        parallel iterable of dicts (zipped 1:1 with ``arg_iter``; lengths
        must match), or the sole iterable when ``arg_iter`` is omitted.

        At most ``max_outstanding`` (default: the DFK's
        ``map_backpressure``; ``None`` = unlimited) tasks from this map
        are outstanding — submitted but unfinished — at once; further
        submissions block until earlier tasks finish, bounding executor
        queue depth for large sweeps.
        """
        if arg_iter is None and kwargs_iter is None:
            raise ValueError("map() needs arg_iter and/or kwargs_iter")
        cap = max_outstanding if max_outstanding is not None else self.map_backpressure
        if cap is not None and cap < 1:
            raise ValueError(f"max_outstanding must be >= 1, got {cap}")
        gate = threading.BoundedSemaphore(cap) if cap else None

        def invocations():
            if kwargs_iter is None:
                for args in arg_iter:  # type: ignore[union-attr]
                    yield args, {}
            elif arg_iter is None:
                for kwargs in kwargs_iter:
                    yield _NO_ARGS, kwargs
            else:
                args_it, kw_it = iter(arg_iter), iter(kwargs_iter)
                while True:
                    a = next(args_it, _EXHAUSTED)
                    k = next(kw_it, _EXHAUSTED)
                    if a is _EXHAUSTED and k is _EXHAUSTED:
                        return
                    if a is _EXHAUSTED or k is _EXHAUSTED:
                        raise ValueError(
                            "map(): arg_iter and kwargs_iter lengths differ")
                    yield a, k

        futures: list[AppFuture] = []
        for args, kwargs in invocations():
            if args is _NO_ARGS:
                args = ()
            elif unpack and isinstance(args, tuple):
                pass                      # tuple-splat (historical default)
            else:
                args = (args,)
            if not isinstance(kwargs, dict):
                raise TypeError(
                    f"kwargs_iter elements must be dicts, got {type(kwargs).__name__}")
            if gate is not None:
                if self.clock.virtual:
                    # inline mode: a blocking acquire would deadlock (this
                    # thread is the one that resolves tasks) — drive the
                    # loop until a slot frees up instead.  The memoized
                    # predicate acquires at most once, so a run that ends
                    # without a slot (stopped loop, exhausted horizon) is
                    # detected instead of leaking a phantom release later.
                    held = {"ok": False}

                    def _try_acquire() -> bool:
                        if not held["ok"]:
                            held["ok"] = gate.acquire(blocking=False)
                        return held["ok"]

                    if not self._drive_until(_try_acquire):
                        raise RuntimeError(
                            "map(): backpressure slot never freed (engine "
                            "stopped or virtual horizon exhausted)")
                else:
                    gate.acquire()
                try:
                    fut = self.submit(td, args, dict(kwargs))
                except BaseException:
                    # a failed submission must give its slot back — leaking
                    # it would strand the rest of the sweep at cap-1 (and a
                    # later failure would eventually deadlock the map)
                    gate.release()
                    raise
                fut.add_done_callback(lambda _f, g=gate: g.release())
            else:
                fut = self.submit(td, args, dict(kwargs))
            futures.append(fut)
        return futures

    def _dep_done(self, rec: TaskRecord) -> None:
        if not self._claim_ready(rec):
            return
        self._enqueue_dispatch(rec)

    def _claim_ready(self, rec: TaskRecord) -> bool:
        """Atomically move PENDING -> READY once all parents resolved.

        Multiple parent futures may complete concurrently and each fires a
        callback; exactly one caller wins the claim, preventing duplicate
        dispatch (and duplicate execution) of multi-parent tasks.
        """
        with self._lock:
            if rec.state is not TaskState.PENDING:
                return False
            if not all(p.future.done() for p in rec.depends_on):  # type: ignore[union-attr]
                return False
            rec.state = TaskState.READY
            return True

    def _enqueue_dispatch(self, rec: TaskRecord) -> None:
        """Queue a READY record for the next batched dispatch drain.

        At most one drain event is in flight regardless of burst size, so
        a 100k-task submission storm costs one event-loop entry per batch
        instead of one per task.
        """
        with self._dispatch_lock:
            self._dispatch_queue.append(rec)
            if self._drain_scheduled:
                return
            self._drain_scheduled = True
        self.events.call_soon(self._drain_dispatches, name="dispatch")

    def _drain_dispatches(self) -> None:
        """The dispatch event: place every queued submission in one pass.

        Successful placements collect into a batch whose SCHEDULED
        transition and assignment-table writes happen under one lock
        acquisition (:meth:`_bookkeep_placements`); records that route to
        a failure/memo path bookkeep themselves.  Loops until the queue is
        empty, so records becoming READY mid-drain (memo hits resolving a
        child's last dependency, policy-hook submissions) dispatch in this
        same event rather than scheduling another.
        """
        while True:
            with self._dispatch_lock:
                if not self._dispatch_queue:
                    self._drain_scheduled = False
                    return
                batch = list(self._dispatch_queue)
                self._dispatch_queue.clear()
            placed = []
            for rec in batch:
                out = self._maybe_dispatch(rec)
                if out is not None:
                    placed.append((rec, *out))
            if placed:
                self._bookkeep_placements(placed)

    def _maybe_dispatch(self, rec: TaskRecord) -> tuple[str, Any, int] | None:
        """Dispatch a READY-claimed task (or fail it on parent failure).

        Returns the placement tuple for the drain loop's batched
        bookkeeping, or ``None`` when the task resolved some other way
        (parent failure, memo hit, fast-fail, resource starvation).
        """
        if rec.depends_on:
            failed_parent = next(
                (p for p in rec.depends_on
                 if p.state in (TaskState.FAILED, TaskState.DEP_FAILED)), None)
            if failed_parent is not None:
                err = DependencyError(
                    f"dependency {failed_parent.task_id} ({failed_parent.name}) failed",
                    root_cause=failed_parent.exception)
                report = self._make_report(rec, err, node=None, pool=None, worker=None)
                self._route_failure(rec, report, err)
                return None
            # dependencies satisfied: materialize parent results into the
            # args.  Dependency-free records skip the walk — their args
            # cannot contain futures, or they would have had dependencies.
            rec.args = _resolve(rec.args)
            rec.kwargs = _resolve(rec.kwargs)
        # lineage-aware memoization: with a CheckpointPolicy in the stack
        # and the args now embedding every parent's result, a committed
        # result for this invocation hash resolves the future right here —
        # the restart path that skips the completed frontier
        stack = rec.stack if rec.stack is not None else self.policies
        if (stack._checkpointers and rec.retry_count == 0
                and not rec.cancel_requested
                and self._try_memoized(rec, stack)):
            return None
        return self._place(rec)

    def _try_memoized(self, rec: TaskRecord, stack: PolicyStack) -> bool:
        """Probe the checkpoint stores for this record's lineage key.

        A hit still runs the stack's result validators (the same gate a
        fresh execution passes through); a cached result that fails
        validation triggers **dependency-aware rollback** — the entry and
        all its descendants are invalidated — and the task re-executes.

        The store probe runs synchronously on the event-loop thread,
        like every other dispatch-time policy hook.  For an on-disk
        store this is local-file I/O (values cache in memory after the
        first load); replaying a frontier of very large cached results
        on a *real-clock* engine can delay heartbeat/straggler timers —
        widen ``heartbeat_threshold`` there, or keep bulky results out
        of the task store.  Moving hydration off-loop is future work.
        """
        t0 = time.perf_counter()
        hit, value = stack.memo_lookup(rec, self.context())
        self.stats["wrath_overhead_s"] += time.perf_counter() - t0
        if not hit:
            return False
        vexc = (stack.on_result(rec, value, self.context())
                if stack._validators else None)
        if vexc is not None:
            removed = stack.memo_invalidate(rec, reason=str(vexc))
            if self.monitor is not None:
                self.monitor.record_task_event(
                    rec.task_id, "memo_rollback", name=rec.name,
                    error=type(vexc).__name__, invalidated=len(removed))
            return False
        # a hit reached via a *different* parent lineage (converging
        # DAGs: two parents, same output value, one child key) must still
        # register the new parent edges — commit is a value no-op here
        # but unions parents, keeping rollback dependency-complete
        stack.memo_commit(rec, value, self.context())
        self._complete_memoized(rec, value)
        return True

    def _complete_memoized(self, rec: TaskRecord, value: Any) -> bool:
        """Resolve a task from the checkpoint store without dispatching."""
        with self._lock:
            if self._done_first.get(rec.task_id):
                return False
            self._done_first[rec.task_id] = True
            rec.state = TaskState.COMPLETED
            rec.end_time = self.clock.time()
            self.stats["completed"] += 1
            self.stats["memo_hits"] += 1
        if self.monitor is not None:
            self.monitor.record_task_event(
                rec.task_id, "memoized", name=rec.name,
                key=(rec.lineage_key or "")[:12])
        self._cancel_race_loser(rec, rec.task_id)
        self._finish(rec, result=value)
        return True

    def _place(self, rec: TaskRecord) -> tuple[str, Any, int] | None:
        """Hand one record to its pool executor.

        Returns ``(pool_name, node, steal_hops_before_queueing)`` for the
        bookkeeping write, or ``None`` when the record took a
        failure/fast-fail path instead (those bookkeep themselves).
        """
        if self._done_first.get(rec.task_id) or rec.cancel_requested:
            return None  # cancelled/resolved while queued for dispatch
        if rec.first_dispatch_time <= 0:
            rec.first_dispatch_time = self.clock.time()
        stack = rec.stack if rec.stack is not None else self.policies
        if stack._dispatchers:
            t0 = time.perf_counter()
            reason = stack.on_dispatch(rec, self.context())
            self.stats["wrath_overhead_s"] += time.perf_counter() - t0
            if reason is not None:
                self.fast_fail_task(rec.task_id, reason)
                return None
        pool_name = rec.target_pool or rec.pool_default or self.default_pool
        ex = self.executors.get(pool_name)
        if ex is None:
            err = ResourceStarvationError(f"no executor for pool {pool_name!r}")
            self._route_failure(rec, self._make_report(rec, err), err)
            return None
        # snapshot the steal-hop count before the record becomes visible
        # to workers: if a thief migrates it before our bookkeeping write
        # lands, that write must not clobber the thief's assignment
        hops = len(rec.steal_path)
        node = ex.submit(rec)
        if node is None:
            err = ResourceStarvationError(
                f"no eligible node in pool {pool_name!r} "
                f"(denylist={sorted(self.denylist)})", pool=pool_name)
            self._route_failure(rec, self._make_report(rec, err, pool=pool_name), err)
            return None
        return pool_name, node, hops

    def _bookkeep_placements(
            self, batch: list[tuple[TaskRecord, str, Any, int]]) -> None:
        """State + assignment writes for a batch of placements under ONE
        lock acquisition, then the out-of-lock side effects (monitor
        events, replica launches).

        Guards: only READY/RETRYING records are promoted to SCHEDULED — a
        worker that already marked the task RUNNING, or a cancellation
        that already made it terminal, is never clobbered — and a record
        stolen between queueing and this write keeps the thief's
        assignment (the hop count moved past the snapshot).
        """
        with self._lock:
            for rec, pool_name, node, hops in batch:
                if rec.state in (TaskState.READY, TaskState.RETRYING):
                    rec.state = TaskState.SCHEDULED
                if len(rec.steal_path) == hops:
                    self._assignment[rec.task_id] = (pool_name, node.name)
        monitor = self.monitor
        for rec, pool_name, node, _hops in batch:
            if monitor is not None:
                monitor.record_task_event(
                    rec.task_id, "scheduled", pool=pool_name, node=node.name,
                    attempt=rec.retry_count)
            if rec.replicas > 0 and rec.retry_count == 0:
                self._launch_replicas(rec, first_node=node.name)

    def _dispatch(self, rec: TaskRecord) -> None:
        """Place one record immediately (retry / preempt / delayed-retry
        paths; first-time submissions go through the batched drain)."""
        out = self._place(rec)
        if out is not None:
            self._bookkeep_placements([(rec, *out)])

    def _record_steal(self, rec: TaskRecord, victim: str, thief: str) -> None:
        """Executor ``on_steal`` callback: re-point bookkeeping at the
        thief before it runs the record.

        The assignment table is what heartbeat-loss sweeps, cancellation,
        preemption and drain key on, so it must follow the task; the
        appended steal-path hop keeps the full migration history on the
        record so a later failure categorizes and propagates (workflow
        scope, retry rung, checkpoint lineage) against the node that
        actually held the task.
        """
        with self._lock:
            pool_name, _ = self._assignment.get(
                rec.task_id,
                (rec.target_pool or rec.pool_default or self.default_pool,
                 None))
            if not rec.steal_path:
                rec.steal_path = []  # copy-on-write off the shared default
            rec.steal_path.append(
                {"from": victim, "to": thief, "time": self.clock.time()})
            self._assignment[rec.task_id] = (pool_name, thief)
            self.stats["steals"] += 1
        if self.monitor is not None:
            self.monitor.record_task_event(
                rec.task_id, "stolen", node=thief, source=victim,
                hops=len(rec.steal_path))

    # ------------------------------------------------------------------ #
    # cancellation / preemption / drain (the proactive action surface)
    # ------------------------------------------------------------------ #
    def fast_fail_task(self, task_id: str, reason: str) -> bool:
        """Predictive fast-fail: terminally fail a destined-to-fail task."""
        err = ResourceStarvationError(reason)
        if self.cancel_task(task_id, reason=reason, exc=err):
            self.stats["fast_fails"] += 1
            return True
        return False

    def cancel_task(self, task_id: str, *, reason: str = "",
                    exc: BaseException | None = None) -> bool:
        """Terminally cancel a task, pulling it off a node queue if queued.

        The future is resolved with ``exc`` (default
        :class:`TaskCancelledError`); a record already picked up by a
        worker keeps running to completion but its result is dropped (the
        worker's ``finally`` still releases node memory).  Returns False
        when the task is unknown or already resolved.
        """
        rec = self.tasks.get(task_id)
        if rec is None:
            return False
        with self._lock:
            if self._done_first.get(task_id) or rec.state in (
                    TaskState.COMPLETED, TaskState.FAILED, TaskState.DEP_FAILED):
                return False
            rec.cancel_requested = True
            rec.cancel_reason = reason
            pool_name, node_name = self._assignment.get(task_id, (None, None))
        if node_name:
            ex = self.executors.get(pool_name or self.default_pool)
            if ex is not None:
                ex.cancel_queued(task_id, node_name)  # real dequeue if still queued
        err = exc or TaskCancelledError(reason or f"task {task_id} cancelled",
                                        task_id=task_id)
        with self._lock:
            if self._done_first.get(task_id):
                return False  # completed in the window between the two locks
            self._done_first[task_id] = True
            rec.state = TaskState.FAILED
            rec.exception = err
            rec.terminal_time = self.clock.time()
            self.stats["cancelled"] += 1
            self.stats["failed"] += 1
        if self.monitor is not None:
            self.monitor.record_task_event(task_id, "cancelled", reason=reason)
        self._cancel_race_loser(rec, task_id)
        self._finish(rec, error=err)
        if not isinstance(err, TaskCancelledError):
            # a fast-fail (real error, not a plain cancel) is a genuine
            # terminal failure — let the owning scope propagate it; plain
            # cancellations must not re-trigger propagation storms
            self._propagate_workflow_failure(rec)
        return True

    def preempt_task(self, task_id: str, *, reason: str = "") -> bool:
        """Migrate a task away from its current node (proactive PREEMPT).

        A still-queued record is *really* cancelled (pulled off the node
        queue) and re-dispatched elsewhere; a running record gets a backup
        copy on another node — first finisher wins, exactly the
        speculative-execution race — because a thread-based worker cannot
        be interrupted mid-``fn``.
        """
        rec = self.tasks.get(task_id)
        if rec is None or self._done_first.get(task_id):
            return False
        with self._lock:
            pool_name, node_name = self._assignment.get(task_id, (None, None))
        if node_name is None:
            return False
        ex = self.executors.get(pool_name or self.default_pool)
        if ex is None:
            return False
        removed = ex.cancel_queued(task_id, node_name)
        if removed is not None and removed.is_speculative:
            # copies share the original's task id: we dequeued a racing
            # COPY, not the original (which is still running).  Retire the
            # copy's live-attempt slot — re-dispatching the running
            # original here would double-execute it.
            removed.cancel_requested = True
            self._copy_attempt_failed(removed)
            removed = None
        if removed is not None:
            # real cancellation: steer the re-dispatch away from the node
            candidates = [n for n in ex.eligible_nodes(rec)
                          if n.name != node_name]
            target = self.scheduler.select(rec, candidates, pool=ex.pool)
            rec.target_node = target.name if target is not None else None
            self.events.call_soon(self._dispatch, rec, name="preempt-dispatch")
        elif task_id not in self._speculated:
            # already running: migrate via a backup copy (winner-takes-future)
            self._speculated.add(task_id)
            if self._launch_copy(rec, avoid_node=node_name) is None:
                return False
        else:
            return False  # a backup already races this task; nothing to do
        self.stats["preemptions"] += 1
        if self.monitor is not None:
            self.monitor.record_task_event(
                task_id, "preempted", node=node_name, reason=reason)
        return True

    def drain_node(self, node_name: str, *, reason: str = "",
                   preempt: bool = True) -> bool:
        """Drain a node before hard loss: stop placing, migrate in-flight.

        The node joins the denylist *and* the drained set: the policy
        engine's heartbeat-resume rule leaves drained nodes alone — only
        :meth:`undrain_node` (the sentinel, once trends recover) releases
        them.
        """
        if node_name in self.drained:
            return False
        self.drained.add(node_name)
        self.denylist.add(node_name)
        self.stats["drains"] += 1
        if self.monitor is not None:
            self.monitor.record_system_event("node_drain", node=node_name,
                                             reason=reason)
        if preempt:
            victims = [tid for tid, rec in list(self.tasks.items())
                       if self._assignment.get(tid, (None, None))[1] == node_name
                       and rec.state in (TaskState.SCHEDULED, TaskState.RUNNING)
                       and not self._done_first.get(tid)]
            for tid in victims:
                self.preempt_task(tid, reason=f"node {node_name} draining")
        return True

    def undrain_node(self, node_name: str) -> None:
        self.drained.discard(node_name)
        self.denylist.discard(node_name)
        if self.monitor is not None:
            self.monitor.record_system_event("node_undrain", node=node_name)

    # ------------------------------------------------------------------ #
    # elastic cluster membership
    # ------------------------------------------------------------------ #
    def join_node(self, node: Any, *, pool: str | None = None) -> bool:
        """A new node joins a *running* pool: its pilot job starts, it
        heartbeats immediately, and the scheduler sees it on the next
        placement — no engine restart.  Returns False if the pool is
        unknown or a node by that name already exists."""
        pool_name = pool or self.default_pool
        ex = self.executors.get(pool_name)
        if ex is None or self.cluster.find_node(node.name) is not None:
            return False
        ex.add_node(node)
        with self._lock:
            self.stats["joins"] += 1
        if self.monitor is not None:
            self.monitor.record_system_event("node_join", node=node.name,
                                             pool=pool_name)
        return True

    def leave_node(self, node_name: str, *,
                   reason: str = "decommissioned") -> bool:
        """A node leaves the running cluster (scale-in, spot reclaim with
        notice, maintenance).  Placement stops immediately; everything
        queued or running there is swept through the normal failure
        routing so the retry hierarchy re-places it elsewhere.  Unlike
        :meth:`drain_node` the node is *gone* afterwards — the heartbeat
        watcher stops tracking it and a later join under the same name is
        a brand-new member."""
        ex = None
        for pool_name, cand in self.executors.items():
            if any(n.name == node_name for n in cand.pool.nodes):
                ex = cand
                break
        if ex is None:
            return False
        if self.monitor is not None:
            self.monitor.record_system_event("node_leave", node=node_name,
                                             reason=reason)
        # detach first: the failure sweep below re-places victims, and the
        # scheduler must already be blind to the leaving node
        ex.remove_node(node_name)
        with self._lock:
            self.stats["leaves"] += 1
            victims = [rec for tid, rec in self.tasks.items()
                       if self._assignment.get(tid, (None, None))[1] == node_name
                       and rec.state in (TaskState.SCHEDULED, TaskState.RUNNING)
                       and not self._done_first.get(tid)]
        for rec in victims:
            err = HardwareShutdownError(
                f"node {node_name} left the cluster ({reason})",
                node=node_name)
            report = self._make_report(rec, err, node=node_name,
                                       pool=self._assignment[rec.task_id][0])
            self._route_failure(rec, report, err)
        # departed nodes carry no denylist/drain baggage into a future
        # join under the same name
        self.denylist.discard(node_name)
        self.drained.discard(node_name)
        self._resume_logged.discard(node_name)
        return True

    def _launch_copy(self, rec: TaskRecord, *,
                     avoid_node: str | set[str] | None) -> TaskRecord | None:
        """Start a racing copy of ``rec`` on a different node.

        Shared by straggler speculation, preemptive migration and
        ``replicate(n)``: the copy shares the original's future and task
        id; whichever attempt finishes first wins (``_done_first``), and
        every losing attempt is cancelled.  ``avoid_node`` (a name or a
        set of names) steers placement; when every eligible node is
        avoided the copy degrades gracefully to any eligible node rather
        than not launching.
        """
        avoid = ({avoid_node} if isinstance(avoid_node, str)
                 else (avoid_node or set()))
        pool_name, _ = self._assignment.get(rec.task_id,
                                            (self.default_pool, None))
        ex = self.executors.get(pool_name or self.default_pool)
        if ex is None:
            return None
        copy = TaskRecord(
            task_id=rec.task_id, fn=rec.fn, name=rec.name, args=rec.args,
            kwargs=rec.kwargs, resources=rec.resources,
            max_retries=0, future=rec.future)
        copy.is_speculative = True
        candidates = [c for c in ex.eligible_nodes(copy)
                      if c.name not in avoid]
        target = self.scheduler.select(copy, candidates, pool=ex.pool)
        if target is not None:
            copy.target_node = target.name
        placed = ex.submit(copy)
        if placed is None:
            # no eligible node: the copy never queued, never runs, and must
            # not count as a live attempt the terminal path could wait on
            return None
        with self._lock:
            self._spec_copies.setdefault(rec.task_id, []).append(
                (copy, placed.name))
            self._live_copies[rec.task_id] = (
                self._live_copies.get(rec.task_id, 0) + 1)
        return copy

    def _launch_replicas(self, rec: TaskRecord, *, first_node: str) -> None:
        """Launch the racing copies requested by ``replicate(n)``.

        Runs once per task, right after the original's first placement;
        each copy steers away from the original's node *and* the nodes
        earlier copies landed on, so replication buys real placement
        diversity (degrading to reuse only when the pool is smaller than
        the replica count).  Replicated tasks join ``_speculated`` so the
        straggler watcher and the preemption path don't stack yet more
        copies on top of the race.
        """
        with self._lock:
            if rec.task_id in self._replicated:
                return
            if self._done_first.get(rec.task_id):
                # a sub-millisecond original already resolved the task (and
                # its loser-cancellation pass already ran): copies launched
                # now could never be cancelled and would execute for nothing
                return
            self._replicated.add(rec.task_id)
            self._speculated.add(rec.task_id)
        used: set[str] = {first_node}
        for _ in range(rec.replicas):
            copy = self._launch_copy(rec, avoid_node=used)
            if copy is None:
                break
            if copy.target_node:
                used.add(copy.target_node)
            self.stats["replicas"] += 1
        if self.monitor is not None:
            self.monitor.record_task_event(
                rec.task_id, "replicated", copies=rec.replicas,
                original_node=first_node)

    def _cancel_race_loser(self, winner: TaskRecord, task_id: str) -> None:
        """When one attempt resolves the task, cancel every other attempt."""
        if not self._spec_copies:
            # no speculation in flight anywhere: skip the lock round-trip
            # on the result hot path.  The unlocked emptiness read is
            # benign — a copy registered concurrently with this result is
            # already harmless, because a loser that keeps running is
            # dropped by the winner-takes-future guard at pickup/delivery
            return
        with self._lock:
            copies = self._spec_copies.pop(task_id, None)
            if copies is None:
                return
            pool_name, orig_node = self._assignment.get(task_id, (None, None))
            original = self.tasks.get(task_id)
        losers = [(c, n) for c, n in copies if c is not winner]
        if original is not None and original is not winner:
            losers.append((original, orig_node))
        ex = self.executors.get(pool_name or self.default_pool)
        for loser, loser_node in losers:
            loser.cancel_requested = True
            loser.cancel_reason = "lost the speculative race"
            if ex is not None and loser_node:
                ex.cancel_queued(task_id, loser_node)  # never runs if still queued

    # ------------------------------------------------------------------ #
    # results & failure routing
    # ------------------------------------------------------------------ #
    def _on_result(self, rec: TaskRecord, result: Any,
                   err: BaseException | None, worker: Any) -> None:
        tid = rec.task_id
        pool, node = self._assignment.get(tid, (None, None))
        # attribute the attempt to the node that actually ran it: for a
        # speculative copy the assignment table still points at the
        # straggler, which would credit the backup's fast finish to the
        # slow node and poison the placement history
        wnode = getattr(worker, "node", None)
        if wnode is not None:
            node = wnode.name
            pool = wnode.pool.name if wnode.pool is not None else pool
        primary = self.tasks.get(tid, rec)
        stack = primary.stack if primary.stack is not None else self.policies
        if err is None and not rec.cancel_requested and stack._validators:
            # result validation (e.g. replicate(validate=)): an invalid
            # result — from the original or any racing copy — is discarded
            # and converted into a failure of this attempt
            t0 = time.perf_counter()
            vexc = stack.on_result(primary, result, self.context())
            self.stats["wrath_overhead_s"] += time.perf_counter() - t0
            if vexc is not None:
                err = vexc
        duration = rec.end_time - rec.start_time
        rec.record_attempt(node=node or "?", pool=pool or "?",
                           worker=getattr(worker, "worker_id", "?"),
                           ok=err is None, error=type(err).__name__ if err else None,
                           duration=duration, now=self.clock.time())
        if self.monitor is not None:
            self.monitor.record_task_event(
                tid, "finished" if err is None else "error",
                node=node, pool=pool, duration=duration,
                error=type(err).__name__ if err else None)
            if node:
                self.monitor.record_task_placement(
                    rec.name, node, pool, ok=err is None, duration=duration,
                    memory_gb=rec.effective_resources().memory_gb)
        with self._lock:
            if self._done_first.get(tid):
                return  # another attempt (or a cancellation) resolved this task
            if err is None:
                self._done_first[tid] = True
                rec.state = TaskState.COMPLETED
                # a winning copy must also complete the *original* record —
                # it is the one registered in workflow scopes and stats
                if primary is not rec:
                    primary.state = TaskState.COMPLETED
                if rec.retry_count > 0:
                    self.stats["retry_success"] += 1
                self.stats["completed"] += 1
        if err is None:
            # only the attempt that claimed _done_first reaches here:
            # commit the winning value to the checkpoint stores (a losing
            # racing copy's different result must never overwrite what the
            # future actually resolved with)
            if stack._checkpointers and not rec.cancel_requested:
                t0 = time.perf_counter()
                stack.memo_commit(primary, result, self.context())
                self.stats["wrath_overhead_s"] += time.perf_counter() - t0
            self._pending_terminal.pop(tid, None)
            self._cancel_race_loser(rec, tid)
            self._finish(rec, result=result)
        else:
            if rec.is_speculative:
                # a racing copy failed; the original (or a stashed terminal
                # error awaiting the last copy) decides the task's fate
                self._copy_attempt_failed(rec)
                return
            report = self._make_report(rec, err, node=node, pool=pool,
                                       worker=getattr(worker, "worker_id", None))
            self._route_failure(rec, report, err)

    def _make_report(self, rec: TaskRecord, err: BaseException, *,
                     node: str | None = None, pool: str | None = None,
                     worker: str | None = None) -> FailureReport:
        profile: dict[str, float] = {}
        if node:
            n = self.cluster.find_node(node)
            if n is not None:
                profile = {
                    "node_memory_gb": n.memory_gb,
                    "node_mem_in_use_gb": n.mem_in_use_gb,
                    "node_speed": n.speed,
                    "node_healthy": float(n.healthy),
                    "node_ulimit_files": float(n.ulimit_files),
                }
        report = FailureReport.from_exception(
            err, task_id=rec.task_id, node=node, pool=pool, worker=worker,
            resource_profile=profile, requirements=rec.effective_resources().asdict(),
            retry_count=rec.retry_count, timestamp=self.clock.time())
        if self.monitor is not None:
            self.monitor.report_failure(report)
        return report

    def _route_failure(self, rec: TaskRecord, report: FailureReport,
                       err: BaseException) -> None:
        stack = rec.stack if rec.stack is not None else self.policies
        t0 = time.perf_counter()
        # the full middleware protocol: first decisive on_failure wins
        # (baseline retry as terminal fallback), then every policy's
        # review_decision pass (e.g. the proactive retry veto)
        decision = stack.decide(rec, report, self.context())
        self.stats["wrath_overhead_s"] += time.perf_counter() - t0

        # engine invariant: a child whose parent terminally failed can never
        # be re-executed (its arguments are unresolvable) — coerce to FAIL
        # even if a (buggy) handler says otherwise.
        if isinstance(err, DependencyError) and decision.action is not Action.FAIL:
            decision = RetryDecision(
                Action.FAIL, reason=f"dependency failure is terminal "
                                    f"(handler said {decision.action.value})")

        # a retry scheduled on a stopped event loop would never fire and
        # the future would hang: post-shutdown failures are terminal
        if self._shutting_down and decision.action is not Action.FAIL:
            decision = RetryDecision(
                Action.FAIL, reason="DataFlowKernel is shutting down: "
                                    "no further retries will run")

        if self.monitor is not None:
            self.monitor.record_task_event(
                rec.task_id, "retry_decision", action=decision.action.value,
                reason=decision.reason, rung=decision.rung,
                target_pool=decision.target_pool, target_node=decision.target_node)

        if decision.action is Action.DRAIN and report.node:
            # drain the failing node, then retry the task elsewhere
            self.drain_node(report.node, reason=decision.reason)

        if decision.action is Action.RESTART_AND_RETRY and decision.restart_component:
            kind, _, where = decision.restart_component.partition(":")
            if kind == "worker" and where:
                pool, _node = self._assignment.get(rec.task_id, (None, None))
                ex = self.executors.get(pool or self.default_pool)
                if ex is not None:
                    self.stats["restarts"] += ex.restart_workers(where)

        if decision.action in (Action.RETRY, Action.RESTART_AND_RETRY,
                               Action.PREEMPT, Action.DRAIN):
            target_node = decision.target_node
            if (decision.action is Action.PREEMPT and target_node is None
                    and report.node):
                # PREEMPT's contract is "migrate off the current node": with
                # no explicit pin, steer the re-dispatch away from it
                ex = self.executors.get(decision.target_pool
                                        or report.pool or self.default_pool)
                if ex is not None:
                    candidates = [n for n in ex.eligible_nodes(rec)
                                  if n.name != report.node]
                    picked = self.scheduler.select(rec, candidates, pool=ex.pool)
                    if picked is not None:
                        target_node = picked.name
            with self._lock:
                rec.retry_count += 1
                self.stats["retries"] += 1
                rec.state = TaskState.RETRYING
                rec.target_pool = decision.target_pool
                rec.target_node = target_node
                if decision.resource_overrides:
                    # copy-on-write: the record's default is a shared
                    # empty mapping that must never be mutated in place
                    rec.resource_overrides = {
                        **rec.resource_overrides,
                        **decision.resource_overrides}
            # delayed retries are ordinary events on the engine loop — no
            # per-retry Timer thread
            if decision.delay_s > 0:
                self.events.call_later(decision.delay_s, self._dispatch, rec,
                                       name="delayed-retry")
            else:
                self.events.call_soon(self._dispatch, rec, name="retry-dispatch")
            return

        # terminal failure — but racing copies may still save the task: a
        # healthy replica's result wins over the original's error (HPX
        # replicate semantics), so defer while any copy is in flight.
        # During shutdown queued copies die with the executors, so a stash
        # made after shutdown's flush would never resolve — fail directly.
        with self._lock:
            if (not self._shutting_down
                    and self._live_copies.get(rec.task_id, 0) > 0
                    and not self._done_first.get(rec.task_id)):
                self._pending_terminal[rec.task_id] = err
                return
        self._fail_terminally(rec, err)

    def _fail_terminally(self, rec: TaskRecord, err: BaseException) -> None:
        is_dep = isinstance(err, DependencyError)
        with self._lock:
            if self._done_first.get(rec.task_id):
                return
            self._done_first[rec.task_id] = True
            rec.state = TaskState.DEP_FAILED if is_dep else TaskState.FAILED
            rec.exception = err
            rec.terminal_time = self.clock.time()
            self.stats["dep_failed" if is_dep else "failed"] += 1
        self._finish(rec, error=err)
        if not is_dep:
            # hierarchical failure propagation: the task's innermost
            # workflow scope decides whether siblings/ancestors fast-fail.
            # DEP_FAILED children are excluded — their root cause already
            # propagated when the parent task terminally failed.
            self._propagate_workflow_failure(rec)

    def _copy_attempt_failed(self, copy: TaskRecord) -> None:
        """A racing copy failed: if the original already failed terminally
        and this was the last copy in flight, resolve the task now."""
        task_id = copy.task_id
        with self._lock:
            left = max(self._live_copies.get(task_id, 1) - 1, 0)
            self._live_copies[task_id] = left
            if left > 0 or self._done_first.get(task_id):
                return
            err = self._pending_terminal.pop(task_id, None)
        if err is not None:
            primary = self.tasks.get(task_id)
            if primary is not None:
                self._fail_terminally(primary, err)

    def _propagate_workflow_failure(self, rec: TaskRecord) -> None:
        if self._shutting_down or rec.workflow is None:
            return
        try:
            rec.workflow.on_member_failed(rec)
        except Exception as err:  # noqa: BLE001 - propagation bug must not kill routing
            self._on_event_error("workflow-propagate", err)

    def _finish(self, rec: TaskRecord, *, result: Any = None,
                error: BaseException | None = None) -> None:
        fut = rec.future
        assert fut is not None
        with self._all_done:
            if rec._finished or fut.done():
                return  # idempotent: speculation/races must not double-set
            rec._finished = True
            self._outstanding -= 1
            if self._outstanding <= 0:
                self._all_done.notify_all()
        if error is None:
            fut.set_result(result)
        else:
            fut.set_exception(error)

    # ------------------------------------------------------------------ #
    # watchers: heartbeat loss + stragglers (periodic events)
    # ------------------------------------------------------------------ #
    def _check_heartbeats(self) -> None:
        if self.monitor is None:
            return
        now = self.clock.time()
        stale_after = self.heartbeat_period * self.heartbeat_threshold
        for node_name, last in list(self.monitor.last_heartbeats().items()):
            node = self.cluster.find_node(node_name)
            if node is None:
                continue
            if now - last > stale_after:
                # silence re-arms the next resume transition even while the
                # node is denylisted — a second lost->resumed cycle must
                # produce a second heartbeat_resumed event
                self._resume_logged.discard(node_name)
                if node_name not in self.denylist:
                    # silent node: environment-layer failure detected via
                    # heartbeat loss (paper §III-B / §IV)
                    self.monitor.record_system_event(
                        "heartbeat_lost", node=node_name, stale_s=now - last)
                    self._fail_tasks_on_node(node_name)
            elif node_name in self.denylist:
                # node resumed communication: HTCondor-style un-denylist is
                # handled by the policy engine via monitor events.  Record
                # the resume once per transition, not on every check while
                # the node awaits un-denylisting.
                if node_name not in self._resume_logged:
                    self._resume_logged.add(node_name)
                    self.monitor.record_system_event(
                        "heartbeat_resumed", node=node_name)
            else:
                # healthy & trusted again: arm the next resume transition
                self._resume_logged.discard(node_name)

    def _fail_tasks_on_node(self, node_name: str) -> None:
        # snapshot under the lock: concurrent submits mutate self.tasks,
        # and an unguarded comprehension over the live dict can raise
        # "dictionary changed size during iteration" mid-sweep
        with self._lock:
            victims = [rec for tid, rec in self.tasks.items()
                       if self._assignment.get(tid, (None, None))[1] == node_name
                       and rec.state in (TaskState.SCHEDULED, TaskState.RUNNING)
                       and not self._done_first.get(tid)]
        for rec in victims:
            err = HardwareShutdownError(
                f"node {node_name} lost (heartbeat silent)", node=node_name)
            report = self._make_report(rec, err, node=node_name,
                                       pool=self._assignment[rec.task_id][0])
            self._route_failure(rec, report, err)

    def _straggler_estimate(self, rec: TaskRecord) -> float:
        """Expected duration for straggler detection.

        Profile-derived (template p95 from the monitoring database) when
        enough history exists; the static user-declared ``est_duration_s``
        is the cold-start fallback.  0.0 = no estimate, no detection.
        """
        if self.monitor is not None:
            est = self.monitor.expected_duration(rec.name)
            if est > 0:
                return est
        return rec.resources.est_duration_s

    def check_stragglers(self, *, factor: float | None = None,
                         scope: Any = None) -> None:
        """One straggler sweep: speculate on tasks running far beyond their
        expected duration.  Driven by :class:`~repro.engine.policies.
        StragglerPolicy` on the periodic policy tick; ``scope`` (a
        :class:`~repro.engine.workflow.Workflow`) restricts the watch to
        that scope's subtree."""
        factor = self.straggler_factor if factor is None else factor
        scope_ids: set[str] | None = None
        if scope is not None:
            scope_ids = {r.task_id for r in scope.tasks()}
        now = self.clock.time()
        for tid, rec in list(self.tasks.items()):
            if self._done_first.get(tid) or tid in self._speculated:
                continue
            if scope_ids is not None and tid not in scope_ids:
                continue
            # only tasks a worker actually picked up accrue runtime — the
            # RUNNING transition is set by the worker on pickup
            if rec.state is not TaskState.RUNNING or rec.start_time <= 0:
                continue
            est = self._straggler_estimate(rec)
            if est <= 0:
                continue
            if now - rec.start_time > factor * est:
                self._speculated.add(tid)
                self.stats["speculations"] += 1
                _, node = self._assignment.get(tid, (self.default_pool, None))
                copy = self._launch_copy(rec, avoid_node=node)
                if copy is not None and self.monitor is not None:
                    self.monitor.record_task_event(
                        tid, "speculative_copy", original_node=node)

    # ------------------------------------------------------------------ #
    # sync helpers
    # ------------------------------------------------------------------ #
    def _drive_until(self, predicate, timeout: float | None = None) -> bool:
        """Virtual-clock engines *drive* the event loop instead of blocking
        on it (the calling thread is the one that resolves tasks).
        ``timeout`` is virtual seconds — default a generous simulated hour.
        Returns the predicate's final value."""
        deadline = self.clock.now() + (timeout if timeout is not None
                                       else 3600.0)
        self.events.run_until(predicate, deadline=deadline)
        return bool(predicate())

    def wait_all(self, timeout: float | None = None) -> bool:
        if self.clock.virtual:
            return self._drive_until(lambda: self._outstanding <= 0, timeout)
        with self._all_done:
            if self._outstanding <= 0:
                return True
            return self._all_done.wait(timeout)

    def makespan(self) -> float:
        return self.clock.time() - self.stats["start_time"]

    def success_rates(self) -> dict[str, float]:
        total = self.stats["submitted"]
        retried = self.stats["retries"]
        return {
            "task_success_rate": self.stats["completed"] / total if total else 0.0,
            "retry_success_rate": (self.stats["retry_success"] / retried) if retried else 0.0,
            "tasks": total,
            "retries": retried,
        }

    def failed_task_ttfs(self, *, include_dep_failed: bool = False) -> list[float]:
        """Per-task time-to-failure (first dispatch -> terminal) of failed
        tasks; dependency-wait before the first placement is excluded.

        The proactive plane's headline metric: destined-to-fail tasks
        should terminate sooner (fig 4's normalized TTF < 1).  Dep-failed
        children are excluded by default: their terminal time is gated by
        when their *healthy* sibling parents finish, which says nothing
        about how fast the doomed parent itself was terminated.
        """
        states = ((TaskState.FAILED, TaskState.DEP_FAILED)
                  if include_dep_failed else (TaskState.FAILED,))
        return [rec.terminal_time - (rec.first_dispatch_time or rec.submit_time)
                for rec in self.tasks.values()
                if rec.terminal_time > 0 and rec.submit_time > 0
                and rec.state in states]
