"""Task definitions, futures, and per-task bookkeeping (Application layer).

Mirrors Parsl's ``python_app`` interface: decorating a function with
``@task`` yields a :class:`TaskDef`; invoking it while a
:class:`~repro.engine.dfk.DataFlowKernel` session is active returns an
:class:`AppFuture`.  Futures may be passed as arguments to other tasks to
express DAG dependencies.
"""
from __future__ import annotations

import enum
import itertools
import threading
import time
from concurrent.futures import CancelledError, Future, TimeoutError
from concurrent.futures._base import (
    CANCELLED as _CANCELLED,
    CANCELLED_AND_NOTIFIED as _CANCELLED_AND_NOTIFIED,
    FINISHED as _FINISHED,
    PENDING as _PENDING,
)
from dataclasses import dataclass, field
from typing import Any, Callable

from repro.engine.events import REAL_CLOCK


class TaskState(enum.Enum):
    PENDING = "pending"        # waiting on dependencies
    READY = "ready"            # dependencies met, waiting for dispatch
    SCHEDULED = "scheduled"    # handed to an executor
    RUNNING = "running"        # picked up by a worker
    RETRYING = "retrying"      # failed, retry decision pending/made
    COMPLETED = "completed"
    FAILED = "failed"          # terminally failed (no retries remain / fail-fast)
    DEP_FAILED = "dep_failed"  # a parent terminally failed


@dataclass(frozen=True)
class ResourceSpec:
    """Declared resource requirements of a task (Runtime-layer contract).

    ``memory_gb`` is matched against node capacity; ``packages`` against the
    node environment; ``open_files`` against the node ulimit.  These drive
    both the failure *injection* (a node that can't satisfy the spec fails
    the task the way a real machine would) and the WRATH resource analysis
    (the categorization engine compares spec vs. node profile).
    """

    memory_gb: float = 0.5
    cpus: int = 1
    packages: tuple[str, ...] = ()
    open_files: int = 16
    # estimated duration used by straggler detection (0 = unknown)
    est_duration_s: float = 0.0

    def asdict(self) -> dict[str, Any]:
        return {
            "memory_gb": self.memory_gb,
            "cpus": self.cpus,
            "packages": list(self.packages),
            "open_files": self.open_files,
            "est_duration_s": self.est_duration_s,
        }


# One process-wide condition shared by every AppFuture.
#
# ``threading.Condition()`` costs several microseconds and ~400 bytes per
# instance (RLock, waiter deque, bound-method rebinds) — the single
# largest allocation on the submit hot path when the engine mints one
# future per task at 100k-task scale.  Future's locking discipline makes
# sharing safe: every internal method holds ``_condition`` only for
# short state transitions (callbacks and waiter notification run outside
# it), and ``concurrent.futures.wait`` acquires the conditions of all
# waited futures in sequence — with one shared *recursive* lock those
# nested acquires simply re-enter.  The one semantic caveat is spurious
# wakeups: a completion of ANY future notifies the shared condition, so
# blocking reads must re-check state in a loop — which is exactly what
# :meth:`AppFuture.result` / :meth:`AppFuture.exception` below do,
# replacing the base class's single-``wait`` versions.
_SHARED_FUTURE_CONDITION = threading.Condition()


class AppFuture(Future):
    """Future for a task invocation; hashable and usable as a dependency."""

    def __init__(self, record: "TaskRecord"):
        # mirrors Future.__init__ field-for-field (asserted by the engine
        # test suite); the super() call is skipped only to avoid building
        # a throwaway per-instance Condition (see note above)
        self._condition = _SHARED_FUTURE_CONDITION
        self._state = _PENDING
        self._result = None
        self._exception = None
        self._waiters: list = []
        self._done_callbacks: list = []
        self.record = record

    def result(self, timeout: float | None = None) -> Any:
        """As :meth:`Future.result`, robust to the shared condition's
        spurious wakeups (wait in a deadline loop, not a single pass)."""
        with self._condition:
            deadline = (time.monotonic() + timeout
                        if timeout is not None else None)
            while True:
                if self._state in (_CANCELLED, _CANCELLED_AND_NOTIFIED):
                    raise CancelledError()
                if self._state == _FINISHED:
                    return self._Future__get_result()
                if deadline is None:
                    self._condition.wait()
                else:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        raise TimeoutError()
                    self._condition.wait(remaining)

    def exception(self, timeout: float | None = None) -> BaseException | None:
        """As :meth:`Future.exception`, spurious-wakeup robust."""
        with self._condition:
            deadline = (time.monotonic() + timeout
                        if timeout is not None else None)
            while True:
                if self._state in (_CANCELLED, _CANCELLED_AND_NOTIFIED):
                    raise CancelledError()
                if self._state == _FINISHED:
                    return self._exception
                if deadline is None:
                    self._condition.wait()
                else:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        raise TimeoutError()
                    self._condition.wait(remaining)

    @property
    def task_id(self) -> str:
        return self.record.task_id

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"<AppFuture {self.record.task_id} {self.record.state.value}>"


_task_counter = itertools.count()

# Shared empty-container defaults for TaskRecord's list/dict fields.
# Most records never retry, never get stolen, and never receive resource
# overrides, so four per-record empty containers at 100k-task scale are
# pure allocator pressure.  Every default below is a shared sentinel that
# is NEVER mutated in place — the appending sites (record_attempt,
# DataFlowKernel._record_steal, the rung-1 override merge) copy-on-write
# a private container into the field first.
_NO_DEPS: list = []
_NO_ATTEMPTS: list = []
_NO_OVERRIDES: dict = {}
_NO_STEALS: list = []


@dataclass(slots=True)
class TaskRecord:
    """Full bookkeeping for one task invocation (Framework layer state).

    ``slots=True`` matters at engine-throughput scale: a 100k-task sweep
    keeps 100k of these alive for the session, and slotted storage both
    drops the per-record ``__dict__`` allocation and keeps attribute reads
    on the dispatch/result hot paths at fixed offsets.
    """

    task_id: str
    fn: Callable[..., Any]
    name: str
    args: tuple
    kwargs: dict
    resources: ResourceSpec
    max_retries: int
    state: TaskState = TaskState.PENDING
    depends_on: list["TaskRecord"] = field(default_factory=lambda: _NO_DEPS)
    future: AppFuture | None = None
    # --- execution history ---------------------------------------------
    retry_count: int = 0
    attempts: list[dict[str, Any]] = field(
        default_factory=lambda: _NO_ATTEMPTS)
    # placement chosen by the scheduler / retry handler for next attempt
    target_pool: str | None = None
    target_node: str | None = None
    # resource overrides suggested by the resilience module (rung 1)
    resource_overrides: dict[str, Any] = field(
        default_factory=lambda: _NO_OVERRIDES)
    submit_time: float = 0.0
    # first time the DFK tried to place this task (dependencies resolved);
    # per-task TTF measures from here so dependency wait isn't billed
    first_dispatch_time: float = 0.0
    start_time: float = 0.0
    end_time: float = 0.0
    # terminal-failure wall-clock timestamp (0 = not terminally failed);
    # the per-task time-to-failure metric is terminal_time minus
    # first_dispatch_time (falling back to submit_time if never dispatched)
    terminal_time: float = 0.0
    exception: BaseException | None = None
    # cancellation (proactive plane): a worker that dequeues a record with
    # cancel_requested set drops it without executing
    cancel_requested: bool = False
    cancel_reason: str = ""
    # backup copy launched by straggler speculation / preemptive migration;
    # its result is only used if it finishes before the original
    is_speculative: bool = False
    # work-stealing migration history, one hop per steal (newest last):
    # ``{"from": victim, "to": thief, "time": wall}``.  The steal tree the
    # hierarchical response consults — a stolen task's failure must
    # categorize and propagate against the node that actually held it, not
    # the one the dispatcher originally picked
    steal_path: list[dict[str, Any]] = field(default_factory=lambda: _NO_STEALS)
    # --- hierarchy & policy plumbing (set by the DFK at submit) ---------
    # owning Workflow scope (None = engine root scope)
    workflow: Any = field(default=None, repr=False)
    # resolved per-invocation PolicyStack (task > workflow chain > engine)
    stack: Any = field(default=None, repr=False)
    # fallback pool when neither the task nor a retry decision pinned one
    # (the enclosing workflow's pool default)
    pool_default: str | None = None
    # racing copies requested by replicate(n) (launched after placement)
    replicas: int = 0
    # invocation hash (template + resolved args, which embed every parent's
    # result) computed at dispatch when a CheckpointPolicy is in the stack;
    # the key of this task's entry in the lineage-aware TaskStore
    lineage_key: str | None = None
    # engine callback fired by the worker on the RUNNING transition (only
    # set when some policy in the stack overrides on_running)
    on_running: Any = field(default=None, repr=False)
    # set (exactly once, under the DFK's _all_done condition) when the
    # engine resolves this task's future and releases its outstanding slot
    _finished: bool = field(default=False, repr=False)

    def effective_resources(self) -> ResourceSpec:
        """Resources after applying WRATH rung-1 overrides."""
        if not self.resource_overrides:
            return self.resources
        d = self.resources.asdict()
        d.update(self.resource_overrides)
        d["packages"] = tuple(d["packages"])
        return ResourceSpec(**d)

    def record_attempt(self, *, node: str, pool: str, worker: str,
                       ok: bool, error: str | None, duration: float,
                       now: float | None = None) -> None:
        if self.attempts is _NO_ATTEMPTS:
            self.attempts = []  # copy-on-write off the shared default
        self.attempts.append({
            "attempt": len(self.attempts),
            "node": node,
            "pool": pool,
            "worker": worker,
            "ok": ok,
            "error": error,
            "duration": duration,
            "time": now if now is not None else REAL_CLOCK.time(),
        })


@dataclass(frozen=True)
class TaskDef:
    """A task template produced by the :func:`task` decorator.

    Per-invocation placement and resilience are settable via
    :meth:`options`: ``pool=`` pins the target resource pool,
    ``workflow=`` routes the invocation into a specific
    :class:`~repro.engine.workflow.Workflow` scope (instead of the
    thread's active scope), and ``policy=`` pushes per-call resilience
    middleware (a :class:`~repro.engine.policies.ResiliencePolicy`, a
    list of them, or a bare retry-handler callable) that resolves ahead
    of the workflow's and the engine's stacks.
    """

    fn: Callable[..., Any]
    name: str
    resources: ResourceSpec
    max_retries: int | None
    pool: str | None = None
    workflow: Any = None
    policy: Any = None

    def __call__(self, *args: Any, **kwargs: Any) -> AppFuture:
        from repro.engine.dfk import DataFlowKernel

        dfk = DataFlowKernel.current()
        if dfk is None and self.workflow is not None:
            dfk = self.workflow.dfk
        if dfk is None:
            raise RuntimeError(
                f"task {self.name!r} invoked outside a DataFlowKernel session; "
                "use `with DataFlowKernel(...) as dfk:`"
            )
        return dfk.submit(self, args, kwargs)

    def options(self, **overrides: Any) -> "TaskDef":
        """Return a copy with modified resources / retry / placement /
        resilience settings (``pool=``, ``workflow=``, ``policy=``).

        For sweeps, build the policied TaskDef **once** and reuse it
        (``fd = f.options(policy=replay(3)); [fd(x) for x in xs]``): the
        engine caches one resolved stack per distinct policy object and
        registers each with the engine for its lifetime — constructing a
        fresh policy inside the loop grows that registry per call (the
        same lifetime the engine already gives task records).
        """
        res = dict(self.resources.asdict())
        max_retries = overrides.pop("max_retries", self.max_retries)
        pool = overrides.pop("pool", self.pool)
        workflow = overrides.pop("workflow", self.workflow)
        policy = overrides.pop("policy", self.policy)
        if policy is not None:
            # normalize once here, not per submission: a bare callable is
            # wrapped in a stable RetryHandlerPolicy so the engine's
            # resolved-stack cache hits for every invocation of this def
            from repro.engine.policies import normalize_policies
            policy = normalize_policies(policy)
        for k in list(overrides):
            if k in res:
                res[k] = overrides.pop(k)
        if overrides:
            raise TypeError(f"unknown task options: {sorted(overrides)}")
        res["packages"] = tuple(res["packages"])
        return TaskDef(self.fn, self.name, ResourceSpec(**res), max_retries,
                       pool=pool, workflow=workflow, policy=policy)


def task(
    fn: Callable[..., Any] | None = None,
    *,
    name: str | None = None,
    memory_gb: float = 0.5,
    cpus: int = 1,
    packages: tuple[str, ...] | list[str] = (),
    open_files: int = 16,
    est_duration_s: float = 0.0,
    max_retries: int | None = None,
) -> Any:
    """Declare a TBPP task (Parsl ``python_app`` analog).

    Example::

        @task(memory_gb=2, packages=("numpy",))
        def f(x):
            return x + 1
    """

    def deco(f: Callable[..., Any]) -> TaskDef:
        spec = ResourceSpec(
            memory_gb=memory_gb,
            cpus=cpus,
            packages=tuple(packages),
            open_files=open_files,
            est_duration_s=est_duration_s,
        )
        return TaskDef(f, name or f.__name__, spec, max_retries)

    if fn is not None:
        return deco(fn)
    return deco


def new_task_record(
    td: TaskDef, args: tuple, kwargs: dict, *, default_retries: int,
    now: float | None = None
) -> TaskRecord:
    tid = f"task-{next(_task_counter):06d}"
    rec = TaskRecord(
        task_id=tid,
        fn=td.fn,
        name=td.name,
        args=args,
        kwargs=kwargs,
        resources=td.resources,
        max_retries=td.max_retries if td.max_retries is not None else default_retries,
        submit_time=now if now is not None else REAL_CLOCK.time(),
    )
    rec.future = AppFuture(rec)
    return rec
