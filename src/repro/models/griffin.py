"""RG-LRU recurrent block (Griffin / RecurrentGemma — arXiv:2402.19427).

Block structure (the Griffin "recurrent block"): two parallel linear
branches from the input; branch 1 -> GeLU gate; branch 2 -> depthwise
causal conv -> RG-LRU; elementwise product; output projection.

RG-LRU recurrence (per channel):
    r_t = sigmoid(W_a x_t)                     (recurrence gate)
    i_t = sigmoid(W_x x_t)                     (input gate)
    log a_t = -c * softplus(Lambda) * r_t      (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training uses ``jax.lax.associative_scan`` over the sequence (the
recurrence is a linear first-order scan); decode is the O(1) update.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig, RGLRUCfg
from repro.models.layers import constrain
from repro.models.spec import pdef

_C = 8.0


def rglru_dims(cfg: ModelConfig) -> dict[str, int]:
    g: RGLRUCfg = cfg.rglru  # type: ignore[assignment]
    return {"lru_width": g.lru_width or cfg.d_model}


def make_rglru_defs(cfg: ModelConfig) -> dict:
    g: RGLRUCfg = cfg.rglru  # type: ignore[assignment]
    d = cfg.d_model
    w = rglru_dims(cfg)["lru_width"]
    return {
        "in_gate": pdef((d, "d_model"), (w, "d_ff")),       # GeLU branch
        "in_lin": pdef((d, "d_model"), (w, "d_ff")),        # conv+LRU branch
        "conv_w": pdef((g.conv_width, None), (w, "d_ff"), scale=0.5),
        "conv_b": pdef((w, "d_ff"), init="zeros"),
        "w_a": pdef((w, "d_ff"), (w, "d_ff"), scale=0.02),
        "b_a": pdef((w, "d_ff"), init="zeros", dtype=jnp.float32),
        "w_x": pdef((w, "d_ff"), (w, "d_ff"), scale=0.02),
        "b_x": pdef((w, "d_ff"), init="zeros", dtype=jnp.float32),
        "lam": pdef((w, "d_ff"), init="ones", dtype=jnp.float32),
        "out_proj": pdef((w, "d_ff"), (cfg.d_model, "d_model")),
    }


def _rglru_core(params: dict, x: jax.Array,
                h0: jax.Array | None = None) -> tuple[jax.Array, jax.Array]:
    """x: (B, L, W) post-conv activations -> (y, h_last)."""
    r = jax.nn.sigmoid((x @ params["w_a"]).astype(jnp.float32)
                       + params["b_a"][None, None])
    i = jax.nn.sigmoid((x @ params["w_x"]).astype(jnp.float32)
                       + params["b_x"][None, None])
    log_a = -_C * jax.nn.softplus(params["lam"])[None, None] * r   # (B,L,W)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6)) \
        * i * x.astype(jnp.float32)
    if h0 is not None:
        # fold the carried state into the first step: h_1 = a_1 h_0 + b_1
        gated = gated.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))
        # and neutralize a_1 so the scan composition stays correct
        a = a.at[:, 0].set(jnp.ones_like(a[:, 0]))

    def compose(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    a_out, h = jax.lax.associative_scan(compose, (a, gated), axis=1)
    return h.astype(x.dtype), h[:, -1]


def rglru_block_train(params: dict, x: jax.Array, cfg: ModelConfig, *,
                      return_state: bool = False):
    gate = jax.nn.gelu(x @ params["in_gate"])
    lin = x @ params["in_lin"]
    lin = constrain(lin, ("batch", "seq", "d_ff"))
    width = params["conv_w"].shape[0]
    state = jnp.zeros((x.shape[0], width - 1, lin.shape[-1]), lin.dtype)
    xp = jnp.concatenate([state, lin], axis=1)
    conv = sum(xp[:, i:i + lin.shape[1]] * params["conv_w"][i][None, None]
               for i in range(width)) + params["conv_b"][None, None]
    y, h_last = _rglru_core(params, conv)
    y = constrain(y, ("batch", "seq", "d_ff"))
    out = (y * gate) @ params["out_proj"]
    if return_state:
        return out, {"conv": lin[:, -(width - 1):],
                     "h": h_last.astype(x.dtype)}
    return out


def rglru_block_decode(params: dict, x: jax.Array, cache: dict,
                       cfg: ModelConfig) -> tuple[jax.Array, dict]:
    """cache: {"conv": (B, W-1, lru_width), "h": (B, lru_width)}."""
    gate = jax.nn.gelu(x @ params["in_gate"])            # (B,1,W)
    lin = x @ params["in_lin"]
    width = params["conv_w"].shape[0]
    xp = jnp.concatenate([cache["conv"], lin], axis=1)   # (B, W, lru)
    conv = (xp * params["conv_w"][None]).sum(axis=1, keepdims=True) \
        + params["conv_b"][None, None]
    new_conv = xp[:, 1:]
    xt = conv[:, 0]                                      # (B, W)
    r = jax.nn.sigmoid((xt @ params["w_a"]).astype(jnp.float32) + params["b_a"])
    i = jax.nn.sigmoid((xt @ params["w_x"]).astype(jnp.float32) + params["b_x"])
    log_a = -_C * jax.nn.softplus(params["lam"])[None] * r
    a = jnp.exp(log_a)
    h = a * cache["h"].astype(jnp.float32) \
        + jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6)) \
        * i * xt.astype(jnp.float32)
    y = (h.astype(x.dtype)[:, None] * gate) @ params["out_proj"]
    return y, {"conv": new_conv, "h": h.astype(x.dtype)}
