"""Core transformer layers: norms, RoPE, GQA/SWA/MLA attention, SwiGLU.

All functions are pure: ``params`` are dict pytrees (built from the
ParamDef trees in each ``make_*_defs``), activations are jnp arrays.

Activation sharding is injected through :func:`constrain`, which consults
the active sharding context (set by the distributed layer); without a
context it is the identity, so models run unmodified on one device.

Attention memory discipline: training/prefill attention is *blockwise* —
a ``lax.scan`` over query blocks so the full (S × S) score matrix is never
materialized (full-row softmax per block keeps it numerically exact).
Sliding-window layers slice only the in-window KV per query block, making
SWA genuinely sub-quadratic.  Decode uses ring-buffer KV caches.
"""
from __future__ import annotations

import contextvars
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.config import MLACfg, ModelConfig
from repro.models.spec import ParamDef, pdef

# ---------------------------------------------------------------------------
# activation-sharding context
# ---------------------------------------------------------------------------

_SHARD_CTX: contextvars.ContextVar[Callable[[jax.Array, tuple], jax.Array] | None] = \
    contextvars.ContextVar("wrath_shard_ctx", default=None)
# (mesh, rules) for code that needs explicit collectives (shard_map MoE)
_MESH_CTX: contextvars.ContextVar[Any | None] = \
    contextvars.ContextVar("wrath_mesh_ctx", default=None)


def set_shard_fn(fn: Callable[[jax.Array, tuple], jax.Array] | None,
                 mesh: Any | None = None):
    token2 = _MESH_CTX.set(mesh)
    return _SHARD_CTX.set(fn), token2


def reset_shard_fn(token) -> None:
    t1, t2 = token if isinstance(token, tuple) else (token, None)
    _SHARD_CTX.reset(t1)
    if t2 is not None:
        _MESH_CTX.reset(t2)


def current_mesh():
    return _MESH_CTX.get()


def constrain(x: jax.Array, axes: tuple[str | None, ...]) -> jax.Array:
    fn = _SHARD_CTX.get()
    return fn(x, axes) if fn is not None else x


# ---------------------------------------------------------------------------
# norms & embeddings
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    # mean-square via an f32-ACCUMULATING einsum: no materialized f32 copy
    # of x (a full f32 activation would get stacked into the layer-scan
    # residuals by XLA's convert hoisting, doubling activation memory)
    ms = jnp.einsum("...d,...d->...", x, x,
                    preferred_element_type=jnp.float32) / x.shape[-1]
    scale = jax.lax.rsqrt(ms + eps)[..., None].astype(x.dtype)
    return x * scale * (1.0 + w).astype(x.dtype)


def make_norm_def(d: int) -> ParamDef:
    # stored as (w - 1): init zeros => effective scale 1.0
    return pdef((d, "d_model"), init="zeros", dtype=jnp.float32)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D) or (B, S, D); positions: (S,) or (B, S)."""
    squeeze = x.ndim == 3
    if squeeze:                                        # (B, S, D) -> (B, S, 1, D)
        x = x[:, :, None, :]
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # ((B,)S, D/2)
    angles = angles[..., None, :]                      # head axis: ((B,)S, 1, D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    out = out.astype(x.dtype)
    return out[:, :, 0, :] if squeeze else out


# ---------------------------------------------------------------------------
# SwiGLU FFN
# ---------------------------------------------------------------------------


def make_ffn_defs(d_model: int, d_ff: int) -> dict[str, ParamDef]:
    return {
        "w1": pdef((d_model, "d_model"), (d_ff, "d_ff")),
        "w3": pdef((d_model, "d_model"), (d_ff, "d_ff")),
        "w2": pdef((d_ff, "d_ff"), (d_model, "d_model")),
    }


def swiglu(params: dict, x: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ params["w1"]) * (x @ params["w3"])
    h = constrain(h, ("batch", "seq", "d_ff"))
    return h @ params["w2"]


# ---------------------------------------------------------------------------
# attention parameter trees
# ---------------------------------------------------------------------------


def make_attention_defs(cfg: ModelConfig, *, cross: bool = False) -> dict[str, Any]:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv = cfg.n_heads, cfg.n_kv_heads
    return {
        "wq": pdef((d, "d_model"), (h * hd, "heads")),
        "wk": pdef((d, "d_model"), (kv * hd, "kv_heads")),
        "wv": pdef((d, "d_model"), (kv * hd, "kv_heads")),
        "wo": pdef((h * hd, "heads"), (d, "d_model")),
    }


def make_mla_defs(cfg: ModelConfig) -> dict[str, Any]:
    m: MLACfg = cfg.mla  # type: ignore[assignment]
    d, h = cfg.d_model, cfg.n_heads
    return {
        "wq_a": pdef((d, "d_model"), (m.q_lora_rank, None)),
        "q_norm": pdef((m.q_lora_rank, None), init="zeros", dtype=jnp.float32),
        "wq_b": pdef((m.q_lora_rank, None), (h * m.qk_head_dim, "heads")),
        "wkv_a": pdef((d, "d_model"), (m.kv_lora_rank, None)),
        "kv_norm": pdef((m.kv_lora_rank, None), init="zeros", dtype=jnp.float32),
        "wkv_b": pdef((m.kv_lora_rank, None),
                      (h * (m.qk_nope_head_dim + m.v_head_dim), "heads")),
        "wk_rope": pdef((d, "d_model"), (m.qk_rope_head_dim, None)),
        "wo": pdef((h * m.v_head_dim, "heads"), (d, "d_model")),
    }


# ---------------------------------------------------------------------------
# blockwise multi-head attention (training / prefill)
# ---------------------------------------------------------------------------


def _pick_q_block(s: int) -> int:
    for qb in (512, 256, 128, 64):
        if s % qb == 0 and s > qb:
            return qb
    return s


def mha(q: jax.Array, k: jax.Array, v: jax.Array, *,
        causal: bool, window: int = 0, q_offset: jax.Array | int = 0,
        kv_len: jax.Array | None = None) -> jax.Array:
    """Dense attention with GQA and optional sliding window.

    q: (B, Sq, H, D); k, v: (B, Sk, KV, D).  Returns (B, Sq, H, D).
    ``q_offset``: absolute position of q[0] (decode / blockwise).
    ``kv_len``: number of valid kv positions (ring-buffer decode).
    """
    b, sq, h, d = q.shape
    _, sk, kvh, _ = k.shape
    dv = v.shape[-1]                                   # may differ (MLA)
    g = h // kvh
    qh = q.reshape(b, sq, kvh, g, d)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qh, k,
                        preferred_element_type=jnp.float32)
    scores = scores / math.sqrt(d)
    qpos = (jnp.arange(sq) + q_offset)[:, None]        # (Sq, 1)
    kpos = jnp.arange(sk)[None, :]                     # (1, Sk)
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    if kv_len is not None:
        mask &= kpos < kv_len
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v)
    return out.reshape(b, sq, h, dv)


def blockwise_mha(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0) -> jax.Array:
    """Scan over query blocks; O(qb·S) live scores instead of O(S²).

    For sliding-window attention only the (window + qb)-wide KV slice is
    read per block, so SWA cost is O(S·window).
    """
    b, s, h, d = q.shape
    dv = v.shape[-1]
    qb = _pick_q_block(s)
    if qb == s:
        return mha(q, k, v, causal=causal, window=window)
    nq = s // qb
    qblocks = q.reshape(b, nq, qb, h, d).swapaxes(0, 1)    # (nq, B, qb, H, D)

    # flash-style rematerialization: checkpoint the per-block body so the
    # O(qb·S) score/probability blocks are recomputed in the backward pass
    # instead of being stacked as scan residuals (the dominant activation
    # cost of non-kernel attention).
    if window and window + qb <= s:
        ctx = window + qb

        def body(carry, inp):
            i, qi = inp
            start = jnp.clip(i * qb + qb - ctx, 0, s - ctx)
            ki = jax.lax.dynamic_slice_in_dim(k, start, ctx, axis=1)
            vi = jax.lax.dynamic_slice_in_dim(v, start, ctx, axis=1)
            out = mha(qi, ki, vi, causal=causal, window=window,
                      q_offset=i * qb - start)
            return carry, out
    else:
        def body(carry, inp):
            i, qi = inp
            out = mha(qi, k, v, causal=causal, window=window, q_offset=i * qb)
            return carry, out

    body = jax.checkpoint(body, prevent_cse=False)
    _, outs = jax.lax.scan(body, None, (jnp.arange(nq), qblocks))
    return outs.swapaxes(0, 1).reshape(b, s, h, dv)


# ---------------------------------------------------------------------------
# head padding (TP-mesh divisibility; see ModelConfig.head_pad)
# ---------------------------------------------------------------------------


def _pad_heads(q: jax.Array, k: jax.Array, v: jax.Array,
               cfg: ModelConfig) -> tuple[jax.Array, jax.Array, jax.Array, int]:
    """Pad q heads to cfg.head_pad and expand kv to the same count (MHA
    layout) so the head dim divides the model mesh axis.  Returns original
    head count for the caller to slice the output back."""
    h = q.shape[-2]
    hp = cfg.head_pad
    if not hp or hp <= h:
        return q, k, v, h
    kvh = k.shape[-2]
    if kvh != h:                              # GQA -> full MHA expansion
        k = jnp.repeat(k, h // kvh, axis=-2)
        v = jnp.repeat(v, h // kvh, axis=-2)
    pad = [(0, 0)] * q.ndim
    pad[-2] = (0, hp - h)
    q = jnp.pad(q, pad)
    k = jnp.pad(k, pad)
    v = jnp.pad(v, pad)
    return q, k, v, h


# ---------------------------------------------------------------------------
# full attention blocks (train path)
# ---------------------------------------------------------------------------


def attention_train(params: dict, x: jax.Array, cfg: ModelConfig, *,
                    window: int = 0, bidirectional: bool = False,
                    kv_source: jax.Array | None = None,
                    positions: jax.Array | None = None,
                    return_kv: bool = False):
    """Self- (or cross-) attention over a full sequence.

    kv_source: if given (encoder output), cross-attention without RoPE.
    return_kv: also return the (roped) K/V for prefill cache capture.
    """
    b, s, _ = x.shape
    hd, h, kv = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    src = x if kv_source is None else kv_source
    sk = src.shape[1]
    q = (x @ params["wq"]).reshape(b, s, h, hd)
    k = (src @ params["wk"]).reshape(b, sk, kv, hd)
    v = (src @ params["wv"]).reshape(b, sk, kv, hd)
    if kv_source is None:
        pos = positions if positions is not None else jnp.arange(s)
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos if sk == s else jnp.arange(sk), cfg.rope_theta)
    kv_for_cache = {"k": k, "v": v}
    q, k, v, h_orig = _pad_heads(q, k, v, cfg)
    q = constrain(q, ("batch", "seq", "heads", None))
    k = constrain(k, ("batch", "seq", "heads" if cfg.head_pad else "kv_heads",
                      None))
    v = constrain(v, ("batch", "seq", "heads" if cfg.head_pad else "kv_heads",
                      None))
    causal = (kv_source is None) and not bidirectional
    out = blockwise_mha(q, k, v, causal=causal, window=window)
    out = out[..., :h_orig, :]
    out = constrain(out, ("batch", "seq", "heads", None))
    out = out.reshape(b, s, h * hd) @ params["wo"]
    if return_kv:
        return out, kv_for_cache
    return out


def mla_train(params: dict, x: jax.Array, cfg: ModelConfig, *,
              return_cache: bool = False):
    """DeepSeek-V3 multi-head latent attention (training path)."""
    m: MLACfg = cfg.mla  # type: ignore[assignment]
    b, s, _ = x.shape
    h = cfg.n_heads
    pos = jnp.arange(s)
    cq = rms_norm(x @ params["wq_a"], params["q_norm"], cfg.norm_eps)
    q = (cq @ params["wq_b"]).reshape(b, s, h, m.qk_head_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, pos, cfg.rope_theta)

    ckv = rms_norm(x @ params["wkv_a"], params["kv_norm"], cfg.norm_eps)
    kvu = (ckv @ params["wkv_b"]).reshape(b, s, h, m.qk_nope_head_dim + m.v_head_dim)
    k_nope, v = jnp.split(kvu, [m.qk_nope_head_dim], axis=-1)
    k_rope = apply_rope(x @ params["wk_rope"], pos, cfg.rope_theta)  # (B,S,rope)
    k_rope = jnp.broadcast_to(k_rope[:, :, None, :], (b, s, h, m.qk_rope_head_dim))

    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate([k_nope, k_rope], axis=-1)
    q_full = constrain(q_full, ("batch", "seq", "heads", None))
    k_full = constrain(k_full, ("batch", "seq", "heads", None))
    out = blockwise_mha(q_full, k_full, v, causal=True)
    out = constrain(out, ("batch", "seq", "heads", None))
    out = out.reshape(b, s, h * m.v_head_dim) @ params["wo"]
    if return_cache:
        k_rope_flat = apply_rope(x @ params["wk_rope"], pos, cfg.rope_theta)
        return out, {"ckv": ckv, "k_rope": k_rope_flat}
    return out


# ---------------------------------------------------------------------------
# decode (single new token against a ring-buffer cache)
# ---------------------------------------------------------------------------


def attention_decode(params: dict, x: jax.Array, cache: dict, cfg: ModelConfig, *,
                     window: int = 0,
                     cross_memory: dict | None = None) -> tuple[jax.Array, dict]:
    """x: (B, 1, d).  cache: {"k","v": (B, Smax, KV, hd), "len": (B,) or ()}.

    Ring-buffer semantics: the new KV overwrites position ``len % Smax``.
    Cross-attention (enc-dec) passes ``cross_memory`` = {"k","v"} instead;
    the cache is untouched.
    """
    b = x.shape[0]
    hd, h, kvh = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    q = (x @ params["wq"]).reshape(b, 1, h, hd)
    if cross_memory is not None:
        k, v = cross_memory["k"], cross_memory["v"]
        out = mha(q, k, v, causal=False)
        return out.reshape(b, 1, h * hd) @ params["wo"], cache

    smax = cache["k"].shape[1]
    cur = cache["len"]                                  # scalar int32
    k_new = (x @ params["wk"]).reshape(b, 1, kvh, hd)
    v_new = (x @ params["wv"]).reshape(b, 1, kvh, hd)
    posq = jnp.full((1,), cur, dtype=jnp.int32)
    q = apply_rope(q, posq, cfg.rope_theta)
    k_new = apply_rope(k_new, posq, cfg.rope_theta)
    slot = jnp.mod(cur, smax)
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), slot, axis=1)
    n_valid = jnp.minimum(cur + 1, smax)
    # decode scores over the whole buffer; invalid slots masked via n_valid.
    # window masking is implicit: the swa buffer is only `window` wide.
    g = h // kvh
    qh = q.reshape(b, 1, kvh, g, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qh, ck,
                        preferred_element_type=jnp.float32) / math.sqrt(hd)
    kpos = jnp.arange(smax)[None, :]
    mask = kpos < n_valid
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, cv).reshape(b, 1, h * hd)
    new_cache = {"k": ck, "v": cv, "len": cur + 1}
    return out @ params["wo"], new_cache


def mla_decode(params: dict, x: jax.Array, cache: dict,
               cfg: ModelConfig) -> tuple[jax.Array, dict]:
    """Absorbed MLA decode: scores/outputs computed against the compressed
    latent cache (c_kv, k_rope) without materializing per-head K/V."""
    m: MLACfg = cfg.mla  # type: ignore[assignment]
    b = x.shape[0]
    h = cfg.n_heads
    smax = cache["ckv"].shape[1]
    cur = cache["len"]
    posq = jnp.full((1,), cur, dtype=jnp.int32)

    cq = rms_norm(x @ params["wq_a"], params["q_norm"], cfg.norm_eps)
    q = (cq @ params["wq_b"]).reshape(b, 1, h, m.qk_head_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, posq, cfg.rope_theta)

    ckv_new = rms_norm(x @ params["wkv_a"], params["kv_norm"], cfg.norm_eps)
    kr_new = apply_rope(x @ params["wk_rope"], posq, cfg.rope_theta)
    slot = jnp.mod(cur, smax)
    ckv = jax.lax.dynamic_update_slice_in_dim(
        cache["ckv"], ckv_new[:, None].astype(cache["ckv"].dtype)
        if ckv_new.ndim == 2 else ckv_new.astype(cache["ckv"].dtype), slot, axis=1)
    krope = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], kr_new.astype(cache["k_rope"].dtype), slot, axis=1)

    # absorb wkv_b's K half into q_nope:  q_abs (B,1,H,kv_lora)
    wkv_b = params["wkv_b"].reshape(m.kv_lora_rank, h, m.qk_nope_head_dim + m.v_head_dim)
    w_k = wkv_b[:, :, :m.qk_nope_head_dim]              # (r, H, nope)
    w_v = wkv_b[:, :, m.qk_nope_head_dim:]              # (r, H, v)
    q_abs = jnp.einsum("bqhd,rhd->bqhr", q_nope, w_k)
    scores = (jnp.einsum("bqhr,bsr->bhqs", q_abs, ckv,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bqhd,bsd->bhqs", q_rope, krope,
                           preferred_element_type=jnp.float32))
    scores = scores / math.sqrt(m.qk_head_dim)
    n_valid = jnp.minimum(cur + 1, smax)
    mask = jnp.arange(smax)[None, :] < n_valid
    scores = jnp.where(mask[None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bhqs,bsr->bqhr", p, ckv)          # (B,1,H,r)
    out = jnp.einsum("bqhr,rhd->bqhd", ctx, w_v).reshape(b, 1, h * m.v_head_dim)
    new_cache = {"ckv": ckv, "k_rope": krope, "len": cur + 1}
    return out @ params["wo"], new_cache
