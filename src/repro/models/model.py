"""Model assembly: TransformerLM over per-layer block kinds.

Layers are grouped into scan *segments* (``ModelConfig.scan_segments``):
each segment stacks its parameters along a leading axis and is executed
with ``jax.lax.scan`` so compile time and HLO size are O(#segments), not
O(#layers).  Within a segment's scan body the (mixer, ffn) unit is applied
position by position (unit lengths are tiny: 1–6).

Public API (all pure functions, bound to a ModelConfig):

* ``param_defs(cfg)``                       — ParamDef tree
* ``forward_train(params, batch, cfg)``     — logits (+ aux losses)
* ``loss_fn(params, batch, cfg)``           — scalar fp32 loss (chunked CE)
* ``cache_defs(cfg, batch, seq_len)``       — decode-state ParamDef tree
* ``decode_step(params, state, batch, cfg)``— one-token serve step
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import griffin, moe as moe_mod, ssm
from repro.models.config import BlockKind, ModelConfig
from repro.models.layers import (
    attention_decode,
    attention_train,
    constrain,
    make_attention_defs,
    make_ffn_defs,
    make_mla_defs,
    make_norm_def,
    mla_decode,
    mla_train,
    rms_norm,
)
from repro.models.spec import ParamDef, pdef, stack_defs

# ---------------------------------------------------------------------------
# per-block parameter trees
# ---------------------------------------------------------------------------


def block_defs(cfg: ModelConfig, kind: BlockKind, *, cross: bool = False) -> dict:
    mixer, ffn = kind
    d: dict[str, Any] = {"ln1": make_norm_def(cfg.d_model)}
    if mixer in ("attn", "swa", "bidir"):
        d["attn"] = make_attention_defs(cfg)
    elif mixer == "mla":
        d["attn"] = make_mla_defs(cfg)
    elif mixer == "ssd":
        d["ssd"] = ssm.make_ssd_defs(cfg)
    elif mixer == "rglru":
        d["rglru"] = griffin.make_rglru_defs(cfg)
    else:  # pragma: no cover
        raise ValueError(mixer)
    if cross:
        d["ln_x"] = make_norm_def(cfg.d_model)
        d["cross"] = make_attention_defs(cfg, cross=True)
    if ffn == "dense":
        d["ln2"] = make_norm_def(cfg.d_model)
        d["ffn"] = make_ffn_defs(cfg.d_model, cfg.d_ff)
    elif ffn == "moe":
        d["ln2"] = make_norm_def(cfg.d_model)
        d["moe"] = moe_mod.make_moe_defs(cfg)
    return d


def _apply_ffn(params: dict, x: jax.Array, cfg: ModelConfig,
               kind: BlockKind) -> tuple[jax.Array, jax.Array]:
    _, ffn = kind
    aux = jnp.zeros((), jnp.float32)
    if ffn == "none":
        return x, aux
    h = rms_norm(x, params["ln2"], cfg.norm_eps)
    if ffn == "dense":
        from repro.models.layers import swiglu
        y = swiglu(params["ffn"], h)
    else:
        y, aux = moe_mod.moe_ffn(params["moe"], h, cfg)
    return x + y, aux


def block_train(params: dict, x: jax.Array, cfg: ModelConfig, kind: BlockKind,
                *, enc_out: jax.Array | None = None,
                bidirectional: bool = False) -> tuple[jax.Array, jax.Array]:
    mixer, _ = kind
    h = rms_norm(x, params["ln1"], cfg.norm_eps)
    if mixer in ("attn", "bidir"):
        y = attention_train(params["attn"], h, cfg,
                            bidirectional=bidirectional or mixer == "bidir")
    elif mixer == "swa":
        y = attention_train(params["attn"], h, cfg, window=cfg.window)
    elif mixer == "mla":
        y = mla_train(params["attn"], h, cfg)
    elif mixer == "ssd":
        y = ssm.ssd_block_train(params["ssd"], h, cfg)
    else:
        y = griffin.rglru_block_train(params["rglru"], h, cfg)
    x = x + y
    if enc_out is not None and "cross" in params:
        h = rms_norm(x, params["ln_x"], cfg.norm_eps)
        x = x + attention_train(params["cross"], h, cfg, kv_source=enc_out)
    x, aux = _apply_ffn(params, x, cfg, kind)
    x = constrain(x, ("batch", "seq_res", "d_model"))
    return x, aux


def block_decode(params: dict, x: jax.Array, cache: dict, cfg: ModelConfig,
                 kind: BlockKind, *, cross_memory: dict | None = None
                 ) -> tuple[jax.Array, dict]:
    mixer, _ = kind
    h = rms_norm(x, params["ln1"], cfg.norm_eps)
    new_cache = dict(cache)
    if mixer in ("attn", "swa"):
        y, c = attention_decode(params["attn"], h, cache["attn"], cfg,
                                window=cfg.window if mixer == "swa" else 0)
        new_cache["attn"] = c
    elif mixer == "mla":
        y, c = mla_decode(params["attn"], h, cache["attn"], cfg)
        new_cache["attn"] = c
    elif mixer == "ssd":
        y, c = ssm.ssd_block_decode(params["ssd"], h, cache["ssd"], cfg)
        new_cache["ssd"] = c
    else:
        y, c = griffin.rglru_block_decode(params["rglru"], h, cache["rglru"], cfg)
        new_cache["rglru"] = c
    x = x + y
    mem = cross_memory if cross_memory is not None else cache.get("cross")
    if mem is not None and "cross" in params:
        h = rms_norm(x, params["ln_x"], cfg.norm_eps)
        y, _ = attention_decode(params["cross"], h, {}, cfg, cross_memory=mem)
        x = x + y
    x, _ = _apply_ffn(params, x, cfg, kind)
    return x, new_cache


# ---------------------------------------------------------------------------
# cache parameter trees (decode state)
# ---------------------------------------------------------------------------


def _block_cache_defs(cfg: ModelConfig, kind: BlockKind, batch: int,
                      seq_len: int) -> dict:
    mixer, _ = kind
    hd, kv = cfg.resolved_head_dim, cfg.n_kv_heads
    # enc-dec decoder blocks carry a static cross-attention KV memory
    # (precomputed from the encoder output at prefill time)
    cross: dict = {}
    if cfg.encoder_layers:
        cross = {"cross": {
            "k": pdef((batch, "batch"), (seq_len, "seq"), (kv, "kv_heads"),
                      (hd, None), init="zeros"),
            "v": pdef((batch, "batch"), (seq_len, "seq"), (kv, "kv_heads"),
                      (hd, None), init="zeros"),
        }}
    if mixer in ("attn", "bidir"):
        smax = seq_len
        return {"attn": {
            "k": pdef((batch, "batch"), (smax, "seq"), (kv, "kv_heads"), (hd, None),
                      init="zeros"),
            "v": pdef((batch, "batch"), (smax, "seq"), (kv, "kv_heads"), (hd, None),
                      init="zeros"),
            "len": pdef(init="zeros", dtype=jnp.int32),
        }, **cross}
    if mixer == "swa":
        smax = min(cfg.window, seq_len)
        return {"attn": {
            "k": pdef((batch, "batch"), (smax, None), (kv, "kv_heads"), (hd, None),
                      init="zeros"),
            "v": pdef((batch, "batch"), (smax, None), (kv, "kv_heads"), (hd, None),
                      init="zeros"),
            "len": pdef(init="zeros", dtype=jnp.int32),
        }}
    if mixer == "mla":
        m = cfg.mla
        return {"attn": {
            "ckv": pdef((batch, "batch"), (seq_len, "seq"), (m.kv_lora_rank, None),
                        init="zeros"),
            "k_rope": pdef((batch, "batch"), (seq_len, "seq"),
                           (m.qk_rope_head_dim, None), init="zeros"),
            "len": pdef(init="zeros", dtype=jnp.int32),
        }}
    if mixer == "ssd":
        s = cfg.ssm
        dims = ssm.ssm_dims(cfg)
        return {"ssd": {
            "conv": pdef((batch, "batch"), (s.conv_width - 1, None),
                         (dims["conv_dim"], "heads"), init="zeros"),
            "state": pdef((batch, "batch"), (dims["n_heads"], "heads"),
                          (s.head_dim, None), (s.d_state, None), init="zeros"),
        }}
    if mixer == "rglru":
        g = cfg.rglru
        w = griffin.rglru_dims(cfg)["lru_width"]
        return {"rglru": {
            "conv": pdef((batch, "batch"), (g.conv_width - 1, None), (w, "d_ff"),
                         init="zeros"),
            "h": pdef((batch, "batch"), (w, "d_ff"), init="zeros"),
        }}
    raise ValueError(mixer)  # pragma: no cover


# ---------------------------------------------------------------------------
# whole-model parameter trees
# ---------------------------------------------------------------------------


def param_defs(cfg: ModelConfig) -> dict:
    cfg.validate()
    cross = cfg.encoder_layers > 0
    segments = cfg.scan_segments()
    defs: dict[str, Any] = {
        "embed": pdef((cfg.vocab_size, "vocab"), (cfg.d_model, "d_model"),
                      scale=1.0),
        "final_norm": make_norm_def(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        defs["head"] = pdef((cfg.d_model, "d_model"), (cfg.vocab_size, "vocab"))
    defs["segments"] = [
        {str(u): stack_defs(block_defs(cfg, kind, cross=cross), repeats)
         for u, kind in enumerate(unit)}
        for unit, repeats in segments
    ]
    if cross:
        enc_kind: BlockKind = ("bidir", "dense")
        defs["encoder"] = {
            "blocks": stack_defs(block_defs(cfg, enc_kind), cfg.encoder_layers),
            "final_norm": make_norm_def(cfg.d_model),
        }
    if cfg.mtp:
        defs["mtp"] = {
            "proj": pdef((2 * cfg.d_model, "d_model"), (cfg.d_model, "d_model")),
            "block": block_defs(cfg, (cfg.pattern[-1][0], "dense")),
            "norm_h": make_norm_def(cfg.d_model),
            "norm_e": make_norm_def(cfg.d_model),
        }
    return defs


def cache_defs(cfg: ModelConfig, batch: int, seq_len: int) -> dict:
    """Decode-state tree matching the segment structure."""
    cfg.validate()
    segs = cfg.scan_segments()
    return {
        "segments": [
            {str(u): stack_defs(_block_cache_defs(cfg, kind, batch, seq_len),
                                repeats)
             for u, kind in enumerate(unit)}
            for unit, repeats in segs
        ],
    }


# ---------------------------------------------------------------------------
# forward (train)
# ---------------------------------------------------------------------------


def _run_segments_train(params: dict, x: jax.Array, cfg: ModelConfig, *,
                        enc_out: jax.Array | None, remat: bool) -> tuple[jax.Array, jax.Array]:
    aux_total = jnp.zeros((), jnp.float32)
    for seg_params, (unit, repeats) in zip(params["segments"], cfg.scan_segments()):
        def body(carry, layer_params, _unit=unit):
            h, aux = carry
            for u, kind in enumerate(_unit):
                h, a = block_train(layer_params[str(u)], h, cfg, kind,
                                   enc_out=enc_out)
                aux = aux + a
            return (h, aux), None

        if remat:
            body = jax.checkpoint(body, prevent_cse=False)
        if repeats == 1:
            squeezed = jax.tree.map(lambda p: p[0], seg_params)
            (x, aux_total), _ = body((x, aux_total), squeezed)
        else:
            (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), seg_params)
    return x, aux_total


def embed_inputs(params: dict, batch: dict, cfg: ModelConfig) -> jax.Array:
    if cfg.input_kind == "embeds":
        x = batch["embeds"]
    else:
        x = params["embed"][batch["inputs"]]
    return constrain(x.astype(cfg.cdtype), ("batch", "seq", "d_model"))


def _encoder_forward(params: dict, batch: dict, cfg: ModelConfig, *,
                     remat: bool) -> jax.Array:
    enc = params["encoder"]
    x = constrain(batch["enc_embeds"].astype(cfg.cdtype),
                  ("batch", "seq", "d_model"))

    def body(carry, layer_params):
        h, = carry
        h, _ = block_train(layer_params, h, cfg, ("bidir", "dense"),
                           bidirectional=True)
        return (h,), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    (x,), _ = jax.lax.scan(body, (x,), enc["blocks"])
    return rms_norm(x, enc["final_norm"], cfg.norm_eps)


def forward_train(params: dict, batch: dict, cfg: ModelConfig, *,
                  remat: bool = True) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (hidden (B,S,d), enc_out|None, aux_loss)."""
    enc_out = None
    if cfg.encoder_layers:
        enc_out = _encoder_forward(params, batch, cfg, remat=remat)
    x = embed_inputs(params, batch, cfg)
    x, aux = _run_segments_train(params, x, cfg, enc_out=enc_out, remat=remat)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, enc_out, aux


def _logits(params: dict, h: jax.Array, cfg: ModelConfig) -> jax.Array:
    w = params["embed"].T if cfg.tie_embeddings else params["head"]
    return (h @ w.astype(h.dtype)).astype(jnp.float32)


def _ce_chunk(params: dict, h: jax.Array, targets: jax.Array,
              cfg: ModelConfig) -> tuple[jax.Array, jax.Array]:
    """Cross entropy + z-loss for one sequence chunk; returns (sum_ce, count)."""
    logits = _logits(params, h, cfg)
    logits = constrain(logits, ("batch", "seq", "vocab"))
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    ce = lse - gold
    zloss = 1e-4 * lse ** 2
    valid = (targets >= 0).astype(jnp.float32)
    return jnp.sum((ce + zloss) * valid), jnp.sum(valid)


def loss_fn(params: dict, batch: dict, cfg: ModelConfig, *,
            remat: bool = True, ce_chunk: int = 512) -> tuple[jax.Array, dict]:
    h, enc_out, aux = forward_train(params, batch, cfg, remat=remat)
    targets = batch["targets"]
    b, s = targets.shape
    if ce_chunk and s > ce_chunk and s % ce_chunk == 0:
        nc = s // ce_chunk
        hc = h.reshape(b, nc, ce_chunk, cfg.d_model).swapaxes(0, 1)
        tc = targets.reshape(b, nc, ce_chunk).swapaxes(0, 1)

        def body(carry, xs):
            tot, cnt = carry
            hh, tt = xs
            l, c = _ce_chunk(params, hh, tt, cfg)
            return (tot + l, cnt + c), None

        body = jax.checkpoint(body, prevent_cse=False) if remat else body
        (tot, cnt), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
            (hc, tc))
    else:
        tot, cnt = _ce_chunk(params, h, targets, cfg)
    loss = tot / jnp.maximum(cnt, 1.0)

    metrics = {"ce_loss": loss, "aux_loss": aux}
    if cfg.mtp:
        mtp_loss = _mtp_loss(params, h, batch, cfg)
        metrics["mtp_loss"] = mtp_loss
        loss = loss + 0.3 * mtp_loss
    return loss + aux, metrics


def _mtp_loss(params: dict, h: jax.Array, batch: dict, cfg: ModelConfig) -> jax.Array:
    """DeepSeek-V3 multi-token prediction: one extra depth predicting t+2.

    h'_t = W [RMSNorm(h_t) ; RMSNorm(Emb(target_{t+1}))] -> block -> head.
    """
    mtp = params["mtp"]
    targets = batch["targets"]
    # teacher embedding of the next token (shift targets left by one)
    nxt = jnp.concatenate([targets[:, 1:], targets[:, -1:]], axis=1)
    e = params["embed"][jnp.maximum(nxt, 0)].astype(h.dtype)
    # anchor the gather output sharding (otherwise SPMD replicates the
    # full (B,S,d) lookup while resharding - XLA b/433785288)
    e = constrain(e, ("batch", "seq_res", "d_model"))
    hn = rms_norm(h, mtp["norm_h"], cfg.norm_eps)
    en = rms_norm(e, mtp["norm_e"], cfg.norm_eps)
    hm = jnp.concatenate([hn, en], axis=-1) @ mtp["proj"]
    hm, _ = block_train(mtp["block"], hm, cfg, (cfg.pattern[-1][0], "dense"))
    # predict t+2: shift targets by 2
    t2 = jnp.concatenate([targets[:, 2:], targets[:, -2:]], axis=1)
    tot, cnt = _ce_chunk(params, hm, t2, cfg)
    return tot / jnp.maximum(cnt, 1.0)


def block_prefill(params: dict, x: jax.Array, cfg: ModelConfig,
                  kind: BlockKind, *, seq_len: int,
                  enc_out: jax.Array | None = None
                  ) -> tuple[jax.Array, dict]:
    """Like block_train but also captures the decode cache (prefill path)."""
    mixer, _ = kind
    h = rms_norm(x, params["ln1"], cfg.norm_eps)
    entry: dict
    s = x.shape[1]
    if mixer == "attn":
        y, kvs = attention_train(params["attn"], h, cfg, return_kv=True)
        entry = {"attn": {**kvs, "len": jnp.asarray(s, jnp.int32)}}
    elif mixer == "swa":
        y, kvs = attention_train(params["attn"], h, cfg, window=cfg.window,
                                 return_kv=True)
        w = min(cfg.window, seq_len)
        if s > w:
            # ring-buffer layout: token p lives at slot p % w
            kvs = {k: jnp.roll(v[:, -w:], s % w, axis=1) for k, v in kvs.items()}
        entry = {"attn": {**kvs, "len": jnp.asarray(s, jnp.int32)}}
    elif mixer == "mla":
        y, c = mla_train(params["attn"], h, cfg, return_cache=True)
        entry = {"attn": {**c, "len": jnp.asarray(s, jnp.int32)}}
    elif mixer == "ssd":
        y, c = ssm.ssd_block_train(params["ssd"], h, cfg, return_state=True)
        entry = {"ssd": c}
    else:
        y, c = griffin.rglru_block_train(params["rglru"], h, cfg,
                                         return_state=True)
        entry = {"rglru": c}
    x = x + y
    if enc_out is not None and "cross" in params:
        hx = rms_norm(x, params["ln_x"], cfg.norm_eps)
        out, kvs = attention_train(params["cross"], hx, cfg,
                                   kv_source=enc_out, return_kv=True)
        x = x + out
        entry["cross"] = kvs
    x, _ = _apply_ffn(params, x, cfg, kind)
    x = constrain(x, ("batch", "seq_res", "d_model"))
    return x, entry


def prefill_forward(params: dict, batch: dict, cfg: ModelConfig, *,
                    remat: bool = True) -> tuple[jax.Array, dict]:
    """Full-sequence prefill: returns (last-token logits, decode cache)."""
    enc_out = None
    if cfg.encoder_layers:
        enc_out = _encoder_forward(params, batch, cfg, remat=remat)
    x = embed_inputs(params, batch, cfg)
    seq_len = x.shape[1]
    segments_cache = []
    for seg_params, (unit, repeats) in zip(params["segments"],
                                           cfg.scan_segments()):
        def body(h, layer_params, _unit=unit):
            entries = {}
            for u, kind in enumerate(_unit):
                h, e = block_prefill(layer_params[str(u)], h, cfg, kind,
                                     seq_len=seq_len, enc_out=enc_out)
                entries[str(u)] = e
            return h, entries

        if remat:
            body = jax.checkpoint(body, prevent_cse=False)
        if repeats == 1:
            squeezed = jax.tree.map(lambda p: p[0], seg_params)
            x, entries = body(x, squeezed)
            entries = jax.tree.map(lambda p: p[None], entries)
        else:
            x, entries = jax.lax.scan(body, x, seg_params)
        segments_cache.append(entries)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _logits(params, x[:, -1:], cfg)
    return logits, {"segments": segments_cache}


def prefill_cross_memory(params: dict, cache: dict, enc_out: jax.Array,
                         cfg: ModelConfig) -> dict:
    """Precompute per-decoder-layer cross-attention K/V from the encoder
    output and store them in the decode cache (enc-dec serving prefill)."""
    hd, kv = cfg.resolved_head_dim, cfg.n_kv_heads
    b, s, _ = enc_out.shape
    new_segments = []
    for seg_params, seg_cache, (unit, repeats) in zip(
            params["segments"], cache["segments"], cfg.scan_segments()):
        seg_new = {}
        for u, kind in enumerate(unit):
            entry = dict(seg_cache[str(u)])
            cross_p = seg_params[str(u)].get("cross")
            if cross_p is not None and "cross" in entry:
                k = jnp.einsum("bsd,rdf->rbsf", enc_out,
                               cross_p["wk"].astype(enc_out.dtype))
                v = jnp.einsum("bsd,rdf->rbsf", enc_out,
                               cross_p["wv"].astype(enc_out.dtype))
                entry["cross"] = {
                    "k": k.reshape(repeats, b, s, kv, hd).astype(
                        entry["cross"]["k"].dtype),
                    "v": v.reshape(repeats, b, s, kv, hd).astype(
                        entry["cross"]["v"].dtype),
                }
            seg_new[str(u)] = entry
        new_segments.append(seg_new)
    return {"segments": new_segments}


# ---------------------------------------------------------------------------
# decode (serve_step)
# ---------------------------------------------------------------------------


def decode_step(params: dict, state: dict, batch: dict, cfg: ModelConfig
                ) -> tuple[jax.Array, dict]:
    """One-token decode.  batch: {"inputs": (B,1) ids} or {"embeds": (B,1,d)};
    optional {"cross_memory": [...]} for enc-dec.  Returns (logits, state)."""
    if cfg.input_kind == "embeds" and "embeds" in batch:
        x = batch["embeds"].astype(cfg.cdtype)
    else:
        x = params["embed"][batch["inputs"]].astype(cfg.cdtype)
    x = constrain(x, ("batch", "seq_res", "d_model"))
    cross_mem = batch.get("cross_memory")

    new_segments = []
    for seg_params, seg_cache, (unit, repeats) in zip(
            params["segments"], state["segments"], cfg.scan_segments()):
        def body(h, xs, _unit=unit):
            layer_params, layer_cache = xs
            new_cache = {}
            for u, kind in enumerate(_unit):
                h, c = block_decode(layer_params[str(u)], h, layer_cache[str(u)],
                                    cfg, kind, cross_memory=cross_mem)
                new_cache[str(u)] = c
            return h, new_cache

        if repeats == 1:
            sp = jax.tree.map(lambda p: p[0], seg_params)
            sc = jax.tree.map(lambda p: p[0], seg_cache)
            x, nc = body(x, (sp, sc))
            nc = jax.tree.map(lambda p: p[None], nc)
        else:
            x, nc = jax.lax.scan(body, x, (seg_params, seg_cache))
        new_segments.append(nc)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _logits(params, x, cfg)
    return logits, {"segments": new_segments}
