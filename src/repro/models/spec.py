"""Parameter specification trees.

Every model defines its parameters once as a pytree of :class:`ParamDef`
(shape + *logical axes* + init).  From that single definition we derive:

* ``materialize(defs, key)``      — real initialized arrays (smoke tests);
* ``abstract(defs)``              — ``jax.ShapeDtypeStruct`` stand-ins
                                    (multi-pod dry-run, no allocation);
* ``logical_axes(defs)``          — the logical-axis pytree consumed by the
                                    sharding-rule engine to build
                                    ``PartitionSpec`` trees.

Logical axis names (see ``repro.distributed.sharding`` for the mesh
mapping): ``batch seq d_model heads kv_heads head_dim d_ff vocab experts
state conv none ...``
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamDef:
    """One parameter: shape, logical axes (one name per dim), init scale."""

    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"           # normal | zeros | ones | scaled
    scale: float | None = None     # None -> 1/sqrt(fan_in)
    dtype: Any = jnp.bfloat16

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes} rank mismatch")


def pdef(*shape_axes: tuple[int, str | None], init: str = "normal",
         scale: float | None = None, dtype: Any = jnp.bfloat16) -> ParamDef:
    """``pdef((512,'d_model'), (2048,'d_ff'))``"""
    shape = tuple(s for s, _ in shape_axes)
    axes = tuple(a for _, a in shape_axes)
    return ParamDef(shape, axes, init=init, scale=scale, dtype=dtype)


def is_def(x: Any) -> bool:
    return isinstance(x, ParamDef)


def _tree_map(fn: Callable[[ParamDef], Any], defs: Any) -> Any:
    return jax.tree.map(fn, defs, is_leaf=is_def)


def abstract(defs: Any) -> Any:
    """ShapeDtypeStruct tree — zero allocation, dry-run input."""
    return _tree_map(lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), defs)


def logical_axes(defs: Any) -> Any:
    return _tree_map(lambda d: d.axes, defs)


def param_count(defs: Any) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=is_def)
    return sum(int(np.prod(d.shape)) for d in leaves)


def param_bytes(defs: Any) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=is_def)
    return sum(int(np.prod(d.shape)) * jnp.dtype(d.dtype).itemsize for d in leaves)


def materialize(defs: Any, key: jax.Array) -> Any:
    """Real arrays.  Deterministic per-leaf keys via fold_in of a leaf index."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_def)

    def init_one(i: int, d: ParamDef) -> jax.Array:
        if d.init == "zeros":
            return jnp.zeros(d.shape, d.dtype)
        if d.init == "ones":
            return jnp.ones(d.shape, d.dtype)
        k = jax.random.fold_in(key, i)
        fan_in = d.shape[0] if len(d.shape) >= 2 else max(d.shape[0], 1)
        scale = d.scale if d.scale is not None else 1.0 / math.sqrt(fan_in)
        return (jax.random.normal(k, d.shape, jnp.float32) * scale).astype(d.dtype)

    return jax.tree.unflatten(treedef, [init_one(i, d) for i, d in enumerate(leaves)])


def stack_defs(defs: Any, n: int, axis_name: str = "layers") -> Any:
    """Stack a layer's ParamDef tree n times along a new leading 'layers' axis
    (the scan-over-layers representation)."""
    return _tree_map(
        lambda d: ParamDef((n,) + d.shape, (axis_name,) + d.axes,
                           init=d.init, scale=d.scale, dtype=d.dtype),
        defs)
