"""Mamba-2 SSD (state-space duality) block — arXiv:2405.21060.

Training path: the chunked SSD algorithm — intra-chunk "attention-like"
quadratic term + inter-chunk linear state recurrence (a ``lax.scan`` over
chunks).  Decode path: O(1) per-token state update.

Block structure (Mamba-2): in_proj -> (z, x, B, C, dt); depthwise causal
conv over (x, B, C); SSD core; gated RMSNorm; out_proj.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig, SSMCfg
from repro.models.layers import constrain, rms_norm
from repro.models.spec import pdef


def ssm_dims(cfg: ModelConfig) -> dict[str, int]:
    s: SSMCfg = cfg.ssm  # type: ignore[assignment]
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.n_groups * s.d_state
    return {
        "d_inner": d_inner,
        "n_heads": n_heads,
        "conv_dim": conv_dim,
        "d_in_proj": 2 * d_inner + 2 * s.n_groups * s.d_state + n_heads,
    }


def make_ssd_defs(cfg: ModelConfig) -> dict:
    s: SSMCfg = cfg.ssm  # type: ignore[assignment]
    dims = ssm_dims(cfg)
    return {
        "in_proj": pdef((cfg.d_model, "d_model"), (dims["d_in_proj"], "heads")),
        "conv_w": pdef((s.conv_width, None), (dims["conv_dim"], "heads"),
                       scale=0.5),
        "conv_b": pdef((dims["conv_dim"], "heads"), init="zeros"),
        "a_log": pdef((dims["n_heads"], "heads"), init="ones", dtype=jnp.float32),
        "d_skip": pdef((dims["n_heads"], "heads"), init="ones", dtype=jnp.float32),
        "dt_bias": pdef((dims["n_heads"], "heads"), init="zeros", dtype=jnp.float32),
        "norm": pdef((dims["d_inner"], "heads"), init="zeros", dtype=jnp.float32),
        "out_proj": pdef((dims["d_inner"], "heads"), (cfg.d_model, "d_model")),
    }


def _segsum(a: jax.Array) -> jax.Array:
    """a: (..., Q) -> (..., Q, Q) lower-tri pairwise cumulative sums:
    out[..., i, j] = sum(a[..., j+1 : i+1]) for i >= j."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_scan(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
             c: jax.Array, chunk: int,
             initial_state: jax.Array | None = None
             ) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD.

    x:  (B, L, H, P) values
    dt: (B, L, H)    softplus'd step sizes
    a:  (H,)         negative decay rates (A = -exp(a_log))
    b:  (B, L, G, N) input projections  (broadcast G -> H)
    c:  (B, L, G, N) output projections
    Returns (y (B, L, H, P), final_state (B, H, P, N)).
    """
    bb, l, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    assert l % chunk == 0, f"L={l} not divisible by chunk={chunk}"
    nc = l // chunk
    rep = h // g

    xc = x.reshape(bb, nc, chunk, h, p)
    dtc = dt.reshape(bb, nc, chunk, h)
    bc = jnp.repeat(b.reshape(bb, nc, chunk, g, n), rep, axis=3)
    cc = jnp.repeat(c.reshape(bb, nc, chunk, g, n), rep, axis=3)

    da = dtc * a[None, None, None, :]                    # (B,nc,Q,H)
    da_cs = jnp.cumsum(da, axis=2)                       # within-chunk cumsum
    # intra-chunk (diagonal blocks): attention-like with decay mask
    lmask = jnp.exp(_segsum(da.transpose(0, 1, 3, 2)))   # (B,nc,H,Q,Q)
    xdt = xc * dtc[..., None]
    y_diag = jnp.einsum("bcqhn,bckhn,bchqk,bckhp->bcqhp", cc, bc, lmask, xdt)

    # per-chunk end states
    decay_states = jnp.exp(da_cs[:, :, -1:, :] - da_cs)  # (B,nc,Q,H)
    states = jnp.einsum("bckhn,bckh,bckhp->bchpn", bc, decay_states, xdt)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(da_cs[:, :, -1, :])            # (B,nc,H)
    s0 = (initial_state if initial_state is not None
          else jnp.zeros((bb, h, p, n), x.dtype))

    def step(s_prev, inp):
        st, dec = inp                                    # (B,H,P,N), (B,H)
        s_new = s_prev * dec[..., None, None] + st
        return s_new, s_prev

    (s_final, s_prevs) = jax.lax.scan(
        step, s0.astype(jnp.float32),
        (states.astype(jnp.float32).transpose(1, 0, 2, 3, 4),
         chunk_decay.transpose(1, 0, 2)))
    s_prevs = s_prevs.transpose(1, 0, 2, 3, 4).astype(x.dtype)  # (B,nc,H,P,N)

    # off-diagonal contribution from carried state
    state_decay = jnp.exp(da_cs)                         # (B,nc,Q,H)
    y_off = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp", cc, s_prevs, state_decay)
    y = (y_diag + y_off).reshape(bb, l, h, p)
    return y, s_final.astype(x.dtype)


def _causal_conv(x: jax.Array, w: jax.Array, bias: jax.Array,
                 state: jax.Array | None = None
                 ) -> tuple[jax.Array, jax.Array]:
    """Depthwise causal conv1d.  x: (B, L, C); w: (W, C).

    Returns (y (B,L,C), new_state (B, W-1, C)) — state carries the last
    W-1 inputs for decode continuation.
    """
    width = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)             # (B, L+W-1, C)
    y = sum(xp[:, i:i + x.shape[1]] * w[i][None, None] for i in range(width))
    new_state = xp[:, -(width - 1):] if width > 1 else state
    return jax.nn.silu(y + bias[None, None]), new_state


def ssd_block_train(params: dict, x: jax.Array, cfg: ModelConfig, *,
                    return_state: bool = False):
    s: SSMCfg = cfg.ssm  # type: ignore[assignment]
    dims = ssm_dims(cfg)
    bsz, l, _ = x.shape
    h, p, n, g = dims["n_heads"], s.head_dim, s.d_state, s.n_groups

    zxbcdt = x @ params["in_proj"]
    z, xin, bc_in, dt_raw = jnp.split(
        zxbcdt, [dims["d_inner"], 2 * dims["d_inner"],
                 2 * dims["d_inner"] + 2 * g * n], axis=-1)
    conv_in = jnp.concatenate([xin, bc_in], axis=-1)
    conv_out, _ = _causal_conv(conv_in, params["conv_w"], params["conv_b"])
    xin, b_in, c_in = jnp.split(conv_out, [dims["d_inner"],
                                           dims["d_inner"] + g * n], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"][None, None])
    a = -jnp.exp(params["a_log"])
    xh = xin.reshape(bsz, l, h, p)
    xh = constrain(xh, ("batch", "seq", "heads", None))
    y, final_state = ssd_scan(xh, dt.astype(x.dtype), a.astype(x.dtype),
                              b_in.reshape(bsz, l, g, n),
                              c_in.reshape(bsz, l, g, n),
                              chunk=min(s.chunk, l))
    y = y + params["d_skip"].astype(x.dtype)[None, None, :, None] * xh
    y = y.reshape(bsz, l, dims["d_inner"])
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    out = y @ params["out_proj"]
    if return_state:
        conv_state = conv_in[:, -(s.conv_width - 1):]
        return out, {"conv": conv_state, "state": final_state}
    return out


def ssd_block_decode(params: dict, x: jax.Array, cache: dict,
                     cfg: ModelConfig) -> tuple[jax.Array, dict]:
    """Single-token update.  cache: {"conv": (B, W-1, conv_dim),
    "state": (B, H, P, N)}."""
    s: SSMCfg = cfg.ssm  # type: ignore[assignment]
    dims = ssm_dims(cfg)
    bsz = x.shape[0]
    h, p, n, g = dims["n_heads"], s.head_dim, s.d_state, s.n_groups

    zxbcdt = x @ params["in_proj"]                       # (B, 1, ·)
    z, xin, bc_in, dt_raw = jnp.split(
        zxbcdt, [dims["d_inner"], 2 * dims["d_inner"],
                 2 * dims["d_inner"] + 2 * g * n], axis=-1)
    conv_in = jnp.concatenate([xin, bc_in], axis=-1)
    conv_out, conv_state = _causal_conv(conv_in, params["conv_w"],
                                        params["conv_b"], state=cache["conv"])
    xin, b_in, c_in = jnp.split(conv_out, [dims["d_inner"],
                                           dims["d_inner"] + g * n], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"][None, None])[:, 0]   # (B,H)
    a = -jnp.exp(params["a_log"])                        # (H,)
    xh = xin.reshape(bsz, h, p)
    bh = jnp.repeat(b_in.reshape(bsz, g, n), h // g, axis=1)      # (B,H,N)
    ch = jnp.repeat(c_in.reshape(bsz, g, n), h // g, axis=1)
    decay = jnp.exp(dt * a[None]).astype(x.dtype)        # (B,H)
    upd = jnp.einsum("bhp,bhn,bh->bhpn", xh, bh, dt.astype(x.dtype))
    state = cache["state"] * decay[..., None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", state, ch)
    y = y + params["d_skip"].astype(x.dtype)[None, :, None] * xh
    y = y.reshape(bsz, 1, dims["d_inner"])
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    return y @ params["out_proj"], {"conv": conv_state, "state": state}
