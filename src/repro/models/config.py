"""Unified model configuration for all assigned architectures.

One ``ModelConfig`` expresses dense GQA transformers, sliding-window
hybrids (gemma3), MLA+MoE (deepseek-v3), classic MoE (olmoe), SSM
(mamba2), RG-LRU hybrids (recurrentgemma), encoder-decoder backbones
(seamless) and VLM backbones (llava) through a per-layer *block kind*
pattern ``(mixer, ffn)``:

* mixer ∈ ``attn`` (global causal), ``swa`` (sliding window), ``mla``
  (multi-head latent attention), ``ssd`` (Mamba-2 state-space dual),
  ``rglru`` (RecurrentGemma gated linear recurrent unit), ``bidir``
  (encoder self-attention)
* ffn ∈ ``dense`` (SwiGLU), ``moe`` (shared + routed experts), ``none``

The pattern is compressed into scan *segments* (unit × repeats) so the
lowered HLO is O(#distinct segments), not O(#layers).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any

Mixer = str
Ffn = str
BlockKind = tuple[Mixer, Ffn]

MIXERS = ("attn", "swa", "mla", "ssd", "rglru", "bidir")
FFNS = ("dense", "moe", "none")


@dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    d_ff_shared: int = 0            # 0 -> n_shared * d_ff_expert
    capacity_factor: float = 1.25
    # dispatch implementation: 'gshard' (einsum one-hot; exact, small scale)
    # or 'scatter' (scatter/gather dispatch; scale, dry-run default)
    dispatch: str = "scatter"
    router_aux_weight: float = 0.001

    @property
    def shared_ff(self) -> int:
        return self.d_ff_shared or self.n_shared * self.d_ff_expert


@dataclass(frozen=True)
class MLACfg:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    @property
    def qk_head_dim(self) -> int:
        return self.qk_nope_head_dim + self.qk_rope_head_dim


@dataclass(frozen=True)
class SSMCfg:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    chunk: int = 128
    conv_width: int = 4
    n_groups: int = 1


@dataclass(frozen=True)
class RGLRUCfg:
    conv_width: int = 4
    lru_width: int = 0              # 0 -> d_model


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | vlm | audio | moe | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // n_heads
    pattern: tuple[BlockKind, ...] = (("attn", "dense"),)
    window: int = 1024              # sliding-window size for 'swa'
    first_k_dense: int = 0          # deepseek-v3: first k layers use dense ffn
    moe: MoECfg | None = None
    mla: MLACfg | None = None
    ssm: SSMCfg | None = None
    rglru: RGLRUCfg | None = None
    # encoder-decoder: n_layers = decoder depth; encoder_layers > 0 adds an
    # encoder stack + cross-attention in every decoder block
    encoder_layers: int = 0
    # input modality: 'tokens' (ids -> embedding) or 'embeds' (precomputed
    # frame/patch embeddings from the stubbed modality frontend)
    input_kind: str = "tokens"
    mtp: bool = False               # deepseek-v3 multi-token prediction head
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    compute_dtype: str = "bfloat16"   # activations dtype ("float32" in tests)
    # pad attention heads to this count inside the attention ops so the
    # head dim divides the TP mesh axis (EXPERIMENTS.md §Perf: 24 or 56
    # heads cannot shard 16 ways; padding trades ≤33% extra attention
    # FLOPs against 16× replication).  0 = no padding.  KV heads are
    # expanded to the padded count as well.
    head_pad: int = 0
    # long-context support marker (sub-quadratic path exists) — drives the
    # long_500k shape-skip logic (DESIGN.md §4)
    subquadratic: bool = False

    # ------------------------------------------------------------------ #
    @property
    def cdtype(self):
        import jax.numpy as jnp

        return jnp.dtype(self.compute_dtype)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def q_group(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    def block_kinds(self) -> list[BlockKind]:
        """Per-layer (mixer, ffn) list of length n_layers."""
        kinds: list[BlockKind] = []
        i = 0
        while len(kinds) < self.n_layers:
            kinds.append(self.pattern[i % len(self.pattern)])
            i += 1
        for j in range(min(self.first_k_dense, self.n_layers)):
            kinds[j] = (kinds[j][0], "dense")
        return kinds

    def scan_segments(self) -> list[tuple[tuple[BlockKind, ...], int]]:
        """Compress per-layer kinds into (unit, repeats) scan segments."""
        kinds = self.block_kinds()
        segs: list[tuple[tuple[BlockKind, ...], int]] = []
        unit = tuple(self.pattern)
        i = 0
        while i < len(kinds):
            # try full copies of the configured pattern unit first
            if tuple(kinds[i:i + len(unit)]) == unit:
                r = 0
                while tuple(kinds[i + r * len(unit):i + (r + 1) * len(unit)]) == unit:
                    r += 1
                segs.append((unit, r))
                i += r * len(unit)
                continue
            # fall back to a run of the single current kind
            k = kinds[i]
            r = 1
            while i + r < len(kinds) and kinds[i + r] == k:
                r += 1
            segs.append(((k,), r))
            i += r
        assert sum(len(u) * r for u, r in segs) == self.n_layers
        return segs

    def validate(self) -> None:
        for mixer, ffn in self.pattern:
            if mixer not in MIXERS:
                raise ValueError(f"unknown mixer {mixer!r}")
            if ffn not in FFNS:
                raise ValueError(f"unknown ffn {ffn!r}")
        if any(f == "moe" for _, f in self.block_kinds()) and self.moe is None:
            raise ValueError("moe pattern requires moe config")
        if any(m == "mla" for m, _ in self.block_kinds()) and self.mla is None:
            raise ValueError("mla pattern requires mla config")
        if any(m == "ssd" for m, _ in self.block_kinds()) and self.ssm is None:
            raise ValueError("ssd pattern requires ssm config")
        if any(m == "rglru" for m, _ in self.block_kinds()) and self.rglru is None:
            raise ValueError("rglru pattern requires rglru config")
        if self.input_kind not in ("tokens", "embeds"):
            raise ValueError(f"bad input_kind {self.input_kind!r}")

    def scaled(self, **overrides: Any) -> "ModelConfig":
        """Reduced-config variant for smoke tests."""
        return dataclasses.replace(self, **overrides)
