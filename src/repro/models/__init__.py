"""Unified model zoo: one config system covering dense GQA, SWA hybrids,
MLA+MoE, classic MoE, Mamba-2 SSD, RG-LRU hybrids, enc-dec and VLM
backbones (the 10 assigned architectures)."""
from repro.models.config import (
    BlockKind,
    MLACfg,
    ModelConfig,
    MoECfg,
    RGLRUCfg,
    SSMCfg,
)
from repro.models.model import (
    cache_defs,
    decode_step,
    forward_train,
    loss_fn,
    param_defs,
)
from repro.models.spec import (
    ParamDef,
    abstract,
    logical_axes,
    materialize,
    param_bytes,
    param_count,
)

__all__ = [
    "ModelConfig", "MoECfg", "MLACfg", "SSMCfg", "RGLRUCfg", "BlockKind",
    "param_defs", "cache_defs", "forward_train", "loss_fn", "decode_step",
    "ParamDef", "abstract", "logical_axes", "materialize",
    "param_count", "param_bytes",
]
