"""Mixture-of-Experts FFN: shared + routed experts, top-k routing.

Two dispatch implementations (selected by ``MoECfg.dispatch``):

* ``gshard``  — classic einsum one-hot dispatch/combine.  Exact and simple
  but its dispatch einsum costs O(T·E·C·d) FLOPs, so it is reserved for
  small smoke-test scales where it doubles as the correctness oracle.
* ``scatter`` — scatter/gather dispatch: token→expert routing is done with
  a capacity-bounded scatter into an (E, C, d) buffer and a gather back.
  Data movement is O(T·k·d) and the expert matmuls dominate FLOPs, which
  is the correct roofline structure at DeepSeek-V3 scale.  Under pjit with
  tokens sharded on ``data`` and experts on ``model``, XLA materializes
  the expert-parallel collectives around the scatter/gather.

Both return ``(y, aux)`` where ``aux`` carries the load-balancing loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig, MoECfg
from repro.models.layers import constrain
from repro.models.spec import pdef


def make_moe_defs(cfg: ModelConfig) -> dict:
    m: MoECfg = cfg.moe  # type: ignore[assignment]
    d = cfg.d_model
    defs: dict = {
        "router": pdef((d, "d_model"), (m.n_experts, None), dtype=jnp.float32),
        "experts": {
            "w1": pdef((m.n_experts, "experts"), (d, "d_model"), (m.d_ff_expert, "d_ff")),
            "w3": pdef((m.n_experts, "experts"), (d, "d_model"), (m.d_ff_expert, "d_ff")),
            "w2": pdef((m.n_experts, "experts"), (m.d_ff_expert, "d_ff"), (d, "d_model")),
        },
    }
    if m.n_shared:
        defs["shared"] = {
            "w1": pdef((d, "d_model"), (m.shared_ff, "d_ff")),
            "w3": pdef((d, "d_model"), (m.shared_ff, "d_ff")),
            "w2": pdef((m.shared_ff, "d_ff"), (d, "d_model")),
        }
    return defs


def _route(params: dict, xf: jax.Array, m: MoECfg) -> tuple[jax.Array, jax.Array, jax.Array]:
    """xf: (T, d) -> (weights (T,k), idx (T,k), aux_loss scalar)."""
    logits = (xf.astype(jnp.float32) @ params["router"])          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, m.top_k)                        # (T, k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balancing auxiliary loss
    me = probs.mean(0)                                            # (E,)
    ce = jnp.zeros((m.n_experts,), jnp.float32).at[idx.reshape(-1)].add(
        1.0 / (idx.size))
    aux = m.n_experts * jnp.sum(me * ce) * m.router_aux_weight
    return w.astype(xf.dtype), idx, aux


def _expert_ffn(experts: dict, h_in: jax.Array) -> jax.Array:
    """h_in: (E, C, d) -> (E, C, d); per-expert SwiGLU."""
    a = jnp.einsum("ecd,edf->ecf", h_in, experts["w1"])
    b = jnp.einsum("ecd,edf->ecf", h_in, experts["w3"])
    h = jax.nn.silu(a) * b
    h = constrain(h, ("experts", None, "d_ff"))
    return jnp.einsum("ecf,efd->ecd", h, experts["w2"])


def _capacity(m: MoECfg, t: int) -> int:
    c = int(m.capacity_factor * t * m.top_k / m.n_experts)
    return max(8, min(t, -(-c // 8) * 8))  # round up to 8, clamp


def moe_gshard(params: dict, x: jax.Array, cfg: ModelConfig) -> tuple[jax.Array, jax.Array]:
    """Einsum one-hot dispatch (exact oracle, small scale)."""
    m: MoECfg = cfg.moe  # type: ignore[assignment]
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    w, idx, aux = _route(params, xf, m)
    cap = _capacity(m, t)
    onehot = jax.nn.one_hot(idx, m.n_experts, dtype=jnp.int32)    # (T,k,E)
    pos = jnp.cumsum(onehot.sum(1), axis=0) - onehot.sum(1)       # (T,E) slots before t
    pos_k = pos[:, None, :] + jnp.cumsum(onehot, axis=1) - onehot  # (T,k,E)
    in_cap = (pos_k < cap) & (onehot > 0)
    pos_oh = jax.nn.one_hot(jnp.where(in_cap, pos_k, cap), cap + 1,
                            dtype=xf.dtype)[..., :cap]            # (T,k,E,C)
    dispatch = (pos_oh * in_cap[..., None]).sum(1)                # (T,E,C)
    combine = (pos_oh * (w[..., None, None] * in_cap[..., None])).sum(1)
    h_in = jnp.einsum("tec,td->ecd", dispatch, xf)
    h_out = _expert_ffn(params["experts"], h_in)
    y = jnp.einsum("tec,ecd->td", combine, h_out)
    if m.n_shared:
        sh = params["shared"]
        y = y + (jax.nn.silu(xf @ sh["w1"]) * (xf @ sh["w3"])) @ sh["w2"]
    return y.reshape(b, s, d), aux


def _positions_hierarchical(e_flat: jax.Array, n_experts: int) -> jax.Array:
    """Position of each assignment within its expert, via a two-level scan:
    shard-local cumsum (no cross-device dependency) + an exclusive cumsum
    of tiny per-chunk counts.  Replaces the global (T·k × E) cumsum whose
    sequential cross-shard dependency made XLA all-gather the one-hot
    matrix (EXPERIMENTS.md §Perf, deepseek-v3 hillclimb)."""
    tk = e_flat.shape[0]
    n_chunks = 1
    for cand in (64, 32, 16, 8, 4, 2):
        if tk % cand == 0 and tk // cand >= 1:
            n_chunks = cand
            break
    l = tk // n_chunks
    ec = e_flat.reshape(n_chunks, l)
    oh = jax.nn.one_hot(ec, n_experts, dtype=jnp.int32)          # (C, L, E)
    oh = constrain(oh, ("batch", None, None))
    local = jnp.cumsum(oh, axis=1) - oh                          # within chunk
    counts = oh.sum(axis=1)                                      # (C, E)
    offsets = jnp.cumsum(counts, axis=0) - counts                # exclusive
    pos = jnp.take_along_axis(local + offsets[:, None, :],
                              ec[..., None], axis=2)[..., 0]
    return pos.reshape(tk)


def moe_scatter(params: dict, x: jax.Array, cfg: ModelConfig) -> tuple[jax.Array, jax.Array]:
    """Scatter/gather dispatch (scale path; dry-run default)."""
    m: MoECfg = cfg.moe  # type: ignore[assignment]
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    w, idx, aux = _route(params, xf, m)
    cap = _capacity(m, t)

    e_flat = idx.reshape(-1)                                      # (T*k,)
    pos_flat = _positions_hierarchical(e_flat, m.n_experts)
    keep = pos_flat < cap
    slot_e = jnp.where(keep, e_flat, 0)
    slot_c = jnp.where(keep, pos_flat, 0)

    x_rep = jnp.repeat(xf, m.top_k, axis=0)                       # (T*k, d)
    x_rep = jnp.where(keep[:, None], x_rep, 0)
    buf = jnp.zeros((m.n_experts, cap, d), xf.dtype)
    buf = buf.at[slot_e, slot_c].add(x_rep, mode="drop")
    buf = constrain(buf, ("experts", None, "d_model"))

    h_out = _expert_ffn(params["experts"], buf)                   # (E, C, d)
    h_out = constrain(h_out, ("experts", None, "d_model"))

    gathered = h_out[slot_e, slot_c]                              # (T*k, d)
    gathered = jnp.where(keep[:, None], gathered, 0)
    y = (gathered.reshape(t, m.top_k, d)
         * w[..., None].astype(xf.dtype)).sum(axis=1)
    if m.n_shared:
        sh = params["shared"]
        y = y + (jax.nn.silu(xf @ sh["w1"]) * (xf @ sh["w3"])) @ sh["w2"]
    return y.reshape(b, s, d), aux


def moe_shard_map(params: dict, x: jax.Array, cfg: ModelConfig
                  ) -> tuple[jax.Array, jax.Array]:
    """Expert-parallel MoE with explicit all-to-all (shard_map).

    pjit's auto-partitioned scatter/gather dispatch materializes the full
    (E, C, d) buffer per device and ALL-REDUCES it (≈2 PB/step at
    deepseek-v3 scale, EXPERIMENTS.md §Perf).  The canonical fix routes
    tokens with two ``all_to_all``s over the ``model`` (expert) axis:

      local dispatch (scatter into the per-SENDER capacity buffer)
      → all_to_all → local expert FFN → all_to_all back
      → local combine → psum over the model axis.

    Collective bytes drop from O(E·C_global·d · n_dev) to O(T·k·cf·d).
    Falls back to ``moe_scatter`` when no mesh context is active.
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    from repro.models.layers import current_mesh

    mesh = current_mesh()
    m: MoECfg = cfg.moe  # type: ignore[assignment]
    if mesh is None or "model" not in mesh.axis_names:
        return moe_scatter(params, x, cfg)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_model = sizes["model"]
    if m.n_experts % n_model:
        return moe_scatter(params, x, cfg)
    data_axes = tuple(a for a in mesh.axis_names if a != "model")
    b, s, d = x.shape
    n_data = 1
    for a in data_axes:
        n_data *= sizes[a]
    # token slices must divide over the model axis per data shard; decode
    # steps (one token per sequence) fall back to the scatter dispatch
    if b % n_data or (b // n_data) * s % n_model:
        return moe_scatter(params, x, cfg)
    e_loc = m.n_experts // n_model
    # expert weights enter in their FSDP layout (d_model sharded over the
    # data axes) and are all-gathered explicitly inside; the transpose of
    # all_gather is reduce_scatter, so weight grads leave the microbatch
    # loop as reduce-scatters instead of full all-reduces (§Perf iter 4)
    n_fsdp = 1
    for a in data_axes:
        n_fsdp *= sizes[a]
    fsdp_ok = d % n_fsdp == 0

    def local_moe(xb, router_w, w1, w3, w2):
        # xb: (B_loc, S, d) — this data-shard's tokens, replicated over
        # 'model'.  Each model shard dispatches its own 1/M token slice
        # (token parallelism over the expert axis), so the expert FFNs see
        # distinct rows from every peer; outputs are reassembled with an
        # all_gather.  w1/w3/w2: (E_loc, d/n_fsdp, ...) FSDP shards.
        if fsdp_ok and n_fsdp > 1:
            w1 = jax.lax.all_gather(w1, data_axes, axis=1, tiled=True)
            w3 = jax.lax.all_gather(w3, data_axes, axis=1, tiled=True)
            w2 = jax.lax.all_gather(w2, data_axes, axis=2, tiled=True)
        bl, sl, dl = xb.shape
        t = bl * sl
        assert t % n_model == 0, (t, n_model)
        ts = t // n_model
        j = jax.lax.axis_index("model")
        xf = jax.lax.dynamic_slice_in_dim(
            xb.reshape(t, dl), j * ts, ts, axis=0)               # (Ts, d)
        logits = xf.astype(jnp.float32) @ router_w               # (Ts, E)
        probs = jax.nn.softmax(logits, axis=-1)
        wgt, idx = jax.lax.top_k(probs, m.top_k)
        wgt = (wgt / jnp.maximum(wgt.sum(-1, keepdims=True), 1e-9)).astype(xb.dtype)
        me = probs.mean(0)
        ce = jnp.zeros((m.n_experts,), jnp.float32).at[idx.reshape(-1)].add(
            1.0 / idx.size)
        aux = m.n_experts * jnp.sum(me * ce) * m.router_aux_weight
        aux = jax.lax.pmean(aux, "model")

        cap = _capacity(m, ts)                                   # per sender
        e_flat = idx.reshape(-1)                                 # (Ts*k,)
        oh = jax.nn.one_hot(e_flat, m.n_experts, dtype=jnp.int32)
        pos = jnp.take_along_axis(jnp.cumsum(oh, axis=0) - oh,
                                  e_flat[:, None], axis=1)[:, 0]
        keep = pos < cap
        se = jnp.where(keep, e_flat, 0)
        sc = jnp.where(keep, pos, 0)
        x_rep = jnp.repeat(xf, m.top_k, axis=0)
        x_rep = jnp.where(keep[:, None], x_rep, 0)
        send = jnp.zeros((m.n_experts, cap, dl), xb.dtype)
        send = send.at[se, sc].add(x_rep, mode="drop")           # local scatter

        # route to expert owners: split E across 'model', gather senders
        recv = jax.lax.all_to_all(
            send.reshape(n_model, e_loc, cap, dl), "model",
            split_axis=0, concat_axis=0, tiled=False)            # (M, E_loc, C, d)
        h_in = recv.transpose(1, 0, 2, 3).reshape(e_loc, n_model * cap, dl)
        a = jnp.einsum("ecd,edf->ecf", h_in, w1)
        g = jnp.einsum("ecd,edf->ecf", h_in, w3)
        h = jax.nn.silu(a) * g
        h_out = jnp.einsum("ecf,efd->ecd", h, w2)                # (E_loc, M*C, d)
        back = h_out.reshape(e_loc, n_model, cap, dl).transpose(1, 0, 2, 3)
        mine = jax.lax.all_to_all(back, "model", split_axis=0,
                                  concat_axis=0, tiled=False)    # (M, E_loc, C, d)
        mine = mine.reshape(m.n_experts, cap, dl)

        gathered = mine[se, sc]
        gathered = jnp.where(keep[:, None], gathered, 0)
        y = (gathered.reshape(ts, m.top_k, dl) * wgt[..., None]).sum(axis=1)
        # reassemble the full token set from the M slices
        y = jax.lax.all_gather(y, "model", axis=0, tiled=True)   # (T, d)
        return y.reshape(bl, sl, dl), aux

    xspec = P(data_axes if len(data_axes) > 1 else data_axes[0], None, None)
    fs = (data_axes if len(data_axes) > 1 else data_axes[0]) if fsdp_ok else None
    e12 = P("model", fs, None)      # w1/w3: (E, d_model, ff)
    e21 = P("model", None, fs)      # w2:    (E, ff, d_model)
    y, aux = shard_map(
        local_moe, mesh=mesh,
        in_specs=(xspec, P(None, None), e12, e12, e21),
        out_specs=(xspec, P()),
        check_rep=False,
    )(x, params["router"], params["experts"]["w1"], params["experts"]["w3"],
      params["experts"]["w2"])

    if m.n_shared:
        sh = params["shared"]
        xf = x.reshape(b * s, d)
        y = y + ((jax.nn.silu(xf @ sh["w1"]) * (xf @ sh["w3"])) @ sh["w2"]
                 ).reshape(b, s, d)
    return y, aux


def moe_ffn(params: dict, x: jax.Array, cfg: ModelConfig) -> tuple[jax.Array, jax.Array]:
    m: MoECfg = cfg.moe  # type: ignore[assignment]
    if m.dispatch == "gshard":
        return moe_gshard(params, x, cfg)
    if m.dispatch == "shard_map":
        return moe_shard_map(params, x, cfg)
    return moe_scatter(params, x, cfg)
