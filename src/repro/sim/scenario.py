"""Scenario DSL: scripted and seeded-random failure schedules.

A :class:`Scenario` is a fully-declarative description of one simulated
run — the cluster shape, the task arrivals (with per-task virtual
durations, DAG edges and injected Table III failure behaviours, reusing
:mod:`repro.injection.engines`'s function-replacement / spec-modification
split) and a timed :class:`Fault` schedule (node loss, heartbeat silence,
worker kills, drains, workflow cancellation).

Scenarios come from two places:

* hand-written — ``Scenario(seed=0, nodes=[...], tasks=[...],
  faults=[...])`` for regression tests that pin one interleaving;
* sampled — :meth:`Scenario.random` draws every choice from one
  ``random.Random(seed)``, so **the seed is the scenario**: printing a
  failing campaign's seed is a complete reproduction recipe.
"""
from __future__ import annotations

import json
import random
from dataclasses import asdict, dataclass, field
from typing import Any

from repro.injection.engines import FN_REPLACEMENT, SPEC_MODIFICATION

__all__ = ["Fault", "NodeSpec", "SimTaskSpec", "Scenario", "FAULT_KINDS",
           "TASK_FAILURE_KINDS", "CORRELATED_FAULT_KINDS"]

#: scripted fault-event kinds the harness knows how to apply
FAULT_KINDS = ("node_down", "node_up", "hb_pause", "hb_resume",
               "worker_kill", "drain", "undrain", "cancel_workflow",
               "engine_crash",
               # correlated / elastic kinds (coverage-guided chaos search)
               "zone_down", "zone_up", "partition", "partition_heal",
               "mass_preempt", "node_join", "node_leave")

#: the correlated-outage subset: one fault touches many components at once
CORRELATED_FAULT_KINDS = ("zone_down", "zone_up", "partition",
                          "partition_heal", "mass_preempt",
                          "node_join", "node_leave")

#: kinds that must name a single target node
_NODE_SCOPED = ("node_down", "node_up", "hb_pause", "hb_resume",
                "worker_kill", "drain", "undrain", "partition",
                "partition_heal", "node_leave")

#: injectable per-task failure behaviours (Table III, both flavours)
TASK_FAILURE_KINDS = tuple(FN_REPLACEMENT) + tuple(SPEC_MODIFICATION)


@dataclass(frozen=True)
class Fault:
    """One timed environment/runtime fault.

    ``engine_crash`` is engine-scoped (no node/workflow target): the
    harness tears the whole :class:`~repro.engine.dfk.DataFlowKernel`
    down mid-run and rebuilds it against the same lineage-aware
    :class:`~repro.checkpoint.task_store.TaskStore`, replaying the
    workflow script — the checkpoint/restart plane's chaos scenario.

    Correlated kinds model real outages that hit many components in one
    tick:

    * ``zone_down`` / ``zone_up`` — a whole node group (rack/zone) lost
      or restored at once (``nodes=`` names the group);
    * ``partition`` / ``partition_heal`` — a network partition that cuts
      the *task/data* path to ``node`` while its **heartbeats keep
      flowing**: queued work stalls, in-flight completions are held until
      the heal, and the engine sees a healthy-looking node that delivers
      nothing (the straggler plane's blind spot);
    * ``mass_preempt`` — spot-instance reclaim: a seeded ``fraction`` of
      all alive workers killed in one tick, busy ones first;
    * ``node_join`` / ``node_leave`` — elastic membership: a new node
      (``spec=``) joins the running cluster mid-scenario, or an existing
      ``node`` is decommissioned (its queued/running work reroutes
      through the normal failure path).
    """

    at: float                      # virtual seconds from scenario start
    kind: str                      # one of FAULT_KINDS
    node: str | None = None        # target node (node-scoped kinds)
    workflow: str | None = None    # target scope (cancel_workflow)
    nodes: tuple[str, ...] = ()    # target group (zone_down / zone_up)
    fraction: float = 0.0          # killed worker fraction (mass_preempt)
    spec: "NodeSpec | None" = None  # joining node's shape (node_join)

    def __post_init__(self) -> None:
        # Validate the target fields per kind at construction: a
        # mis-targeted fault used to crash deep inside the harness
        # mid-campaign with an opaque KeyError/AttributeError; failing
        # here names the field that is wrong.
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {FAULT_KINDS}")
        if self.kind in _NODE_SCOPED and not self.node:
            raise ValueError(
                f"fault kind {self.kind!r} is node-scoped and requires "
                f"node=<name> (got node={self.node!r})")
        if self.kind == "cancel_workflow" and not self.workflow:
            raise ValueError(
                "fault kind 'cancel_workflow' requires workflow=<scope "
                f"name> (got workflow={self.workflow!r})")
        if self.kind in ("zone_down", "zone_up") and not self.nodes:
            raise ValueError(
                f"fault kind {self.kind!r} targets a node group and "
                f"requires nodes=(<name>, ...) (got nodes={self.nodes!r})")
        if self.kind == "mass_preempt" and not 0.0 < self.fraction <= 1.0:
            raise ValueError(
                f"fault kind 'mass_preempt' requires 0 < fraction <= 1 "
                f"(got fraction={self.fraction!r})")
        if self.kind == "node_join":
            if self.spec is None:
                raise ValueError(
                    "fault kind 'node_join' requires spec=NodeSpec(...) "
                    "describing the joining node")
            if self.node is not None and self.node != self.spec.name:
                raise ValueError(
                    f"node_join node={self.node!r} contradicts "
                    f"spec.name={self.spec.name!r}")


@dataclass(frozen=True)
class NodeSpec:
    """Shape of one simulated node (single ``sim`` pool)."""

    name: str
    memory_gb: float = 192.0
    speed: float = 1.0
    workers: int = 2
    packages: tuple[str, ...] = ("numpy", "jax")
    ulimit_files: int = 1024


@dataclass(frozen=True)
class SimTaskSpec:
    """One task arrival.

    ``fail`` is ``None`` (healthy) or a Table III behaviour:
    function-replacement kinds (``zero_division``/``exception``/
    ``worker_killed``/``dependency``) always fail wherever they run —
    the "destined to fail" tasks; spec-modification kinds (``memory``/
    ``import``/``ulimit``) rewrite the resource spec so the task fails on
    inadequate nodes but succeeds on adequate ones — the *resolvable*
    failures WRATH fixes by re-placement.
    """

    at: float
    name: str
    duration: float = 0.05
    fail: str | None = None
    memory_gb: float = 0.5
    depends_on: tuple[int, ...] = ()   # indices of earlier SimTaskSpecs
    max_retries: int | None = None
    workflow: str | None = None        # scope name (None = engine root)


@dataclass
class Scenario:
    """A complete seeded simulation script."""

    seed: int
    nodes: list[NodeSpec] = field(default_factory=list)
    tasks: list[SimTaskSpec] = field(default_factory=list)
    faults: list[Fault] = field(default_factory=list)
    #: virtual-time budget; the campaign flags any future unresolved by then
    horizon: float = 120.0
    #: propagation mode per workflow scope name used by tasks/faults
    workflows: dict[str, str] = field(default_factory=dict)

    def __post_init__(self) -> None:
        if not self.nodes:
            self.nodes = [NodeSpec(name=f"sim-n{i:02d}") for i in range(3)]
        for i, t in enumerate(self.tasks):
            for d in t.depends_on:
                if not 0 <= d < i:
                    raise ValueError(
                        f"task {i} depends on {d}: edges must point at "
                        f"earlier tasks")

    # ------------------------------------------------------------------ #
    @property
    def durations(self) -> dict[str, float]:
        """Template-name → nominal virtual duration (SimExecutor script)."""
        return {t.name: t.duration for t in self.tasks}

    def describe(self) -> str:
        injected = sum(1 for t in self.tasks if t.fail)
        return (f"Scenario(seed={self.seed}): {len(self.nodes)} nodes, "
                f"{len(self.tasks)} tasks ({injected} injected), "
                f"{len(self.faults)} faults, horizon={self.horizon}s")

    # ------------------------------------------------------------------ #
    # Serialization: scenarios travel as JSON (repro corpus under tests/,
    # nightly CI artifacts, shrinker byte-identical re-checks).
    def to_dict(self) -> dict[str, Any]:
        d = asdict(self)
        for f in d["faults"]:
            f["nodes"] = list(f["nodes"])
        return d

    def to_json(self, *, indent: int | None = None) -> str:
        """Canonical JSON: sorted keys, no float noise beyond repr."""
        return json.dumps(self.to_dict(), sort_keys=True, indent=indent)

    @staticmethod
    def from_dict(d: dict[str, Any]) -> "Scenario":
        nodes = [NodeSpec(**{**n, "packages": tuple(n.get("packages", ()))})
                 for n in d.get("nodes", [])]
        tasks = [SimTaskSpec(**{**t,
                                "depends_on": tuple(t.get("depends_on", ()))})
                 for t in d.get("tasks", [])]
        faults = []
        for f in d.get("faults", []):
            spec = f.get("spec")
            if isinstance(spec, dict):
                spec = NodeSpec(**{**spec,
                                   "packages": tuple(spec.get("packages", ()))})
            faults.append(Fault(**{**f, "nodes": tuple(f.get("nodes", ())),
                                   "spec": spec}))
        return Scenario(seed=d["seed"], nodes=nodes, tasks=tasks,
                        faults=faults, horizon=d.get("horizon", 120.0),
                        workflows=dict(d.get("workflows", {})))

    @staticmethod
    def from_json(text: str) -> "Scenario":
        return Scenario.from_dict(json.loads(text))

    # ------------------------------------------------------------------ #
    @staticmethod
    def random(seed: int, *,
               max_nodes: int = 5,
               max_tasks: int = 24,
               task_failure_rate: float = 0.3,
               fault_rate: float = 0.5,
               with_workflows: bool = True,
               crash_rate: float = 0.2,
               correlated_rate: float = 0.0,
               horizon: float = 120.0) -> "Scenario":
        """Sample a chaos scenario; every choice flows from the seed.

        The sampled cluster always keeps at least one fully-healthy node
        (no fault ever targets it) so the paper's *resolvable* failures
        stay resolvable — assertable properties need a floor of
        feasibility.  A big-memory node, a ``wrathpkg`` node and a raised
        ulimit appear with fixed probabilities so each spec-modification
        behaviour is sometimes fixable by re-placement and sometimes
        genuinely infeasible.

        ``correlated_rate > 0`` additionally samples the correlated-outage
        kinds (zone loss, data/heartbeat partition, spot mass-preemption,
        elastic join/leave) and a cascading-OOM task chain whose
        ``memory_gb`` demand doubles along a dependency chain.  The block
        is fully gated: at the default 0.0 no extra RNG draws happen, so
        pre-existing seeds keep their byte-identical traces.
        """
        rng = random.Random(seed)
        n_nodes = rng.randint(2, max_nodes)
        nodes: list[NodeSpec] = []
        for i in range(n_nodes):
            nodes.append(NodeSpec(
                name=f"sim-n{i:02d}",
                memory_gb=rng.choice([16.0, 64.0, 192.0, 192.0]),
                speed=rng.choice([1.0, 1.0, 1.0, 0.25]),
                workers=rng.randint(1, 2)))
        if rng.random() < 0.5:          # §VII-C big-memory escalation target
            nodes.append(NodeSpec(name=f"sim-n{n_nodes:02d}",
                                  memory_gb=6144.0))
        if rng.random() < 0.4:          # with-package pool analog
            nodes.append(NodeSpec(name=f"sim-pkg{len(nodes):02d}",
                                  packages=("numpy", "jax", "wrathpkg")))
        if rng.random() < 0.3:          # raised-ulimit node
            nodes.append(NodeSpec(name=f"sim-fd{len(nodes):02d}",
                                  ulimit_files=2_000_000))

        workflows: dict[str, str] = {}
        wf_name: str | None = None
        wf_members: set[int] = set()
        n_tasks = rng.randint(6, max_tasks)
        if with_workflows and rng.random() < 0.5:
            wf_name = "chaos-scope"
            workflows[wf_name] = rng.choice(["none", "none", "siblings"])
            lo = rng.randrange(max(1, n_tasks // 2))
            wf_members = set(range(lo, min(n_tasks, lo + rng.randint(2, 6))))

        tasks: list[SimTaskSpec] = []
        t = 0.0
        for i in range(n_tasks):
            t += rng.uniform(0.0, horizon / (4 * n_tasks))
            fail = None
            if rng.random() < task_failure_rate:
                fail = rng.choice(TASK_FAILURE_KINDS)
            deps: tuple[int, ...] = ()
            if i > 0 and rng.random() < 0.3:
                deps = tuple(sorted(rng.sample(
                    range(i), k=min(i, rng.randint(1, 2)))))
            tasks.append(SimTaskSpec(
                at=round(t, 6), name=f"t{i:03d}",
                duration=round(rng.uniform(0.01, 2.0), 6),
                fail=fail,
                memory_gb=rng.choice([0.5, 1.0, 4.0]),
                depends_on=deps,
                workflow=wf_name if i in wf_members else None))

        faults: list[Fault] = []
        # node 0 is the guaranteed-healthy floor: never targeted
        for spec in nodes[1:]:
            if rng.random() >= fault_rate:
                continue
            kind = rng.choice(["node_down", "hb_pause", "worker_kill",
                               "drain"])
            at = round(rng.uniform(0.1, horizon / 3), 6)
            faults.append(Fault(at=at, kind=kind, node=spec.name))
            if kind == "node_down" and rng.random() < 0.5:
                faults.append(Fault(at=round(at + rng.uniform(1.0, 10.0), 6),
                                    kind="node_up", node=spec.name))
            elif kind == "hb_pause":
                faults.append(Fault(at=round(at + rng.uniform(0.5, 5.0), 6),
                                    kind="hb_resume", node=spec.name))
            elif kind == "drain" and rng.random() < 0.5:
                faults.append(Fault(at=round(at + rng.uniform(0.5, 5.0), 6),
                                    kind="undrain", node=spec.name))
        if wf_name is not None and rng.random() < 0.5:
            faults.append(Fault(at=round(rng.uniform(0.1, horizon / 3), 6),
                                kind="cancel_workflow", workflow=wf_name))
        if rng.random() < crash_rate:
            # whole-engine crash/restart: the harness rebuilds the DFK
            # against the same TaskStore and replays the script — only the
            # incomplete frontier should re-execute
            faults.append(Fault(at=round(rng.uniform(0.5, horizon / 3), 6),
                                kind="engine_crash"))
        if correlated_rate > 0.0:
            # correlated outages; node 0 stays the untouchable floor
            pool = [n.name for n in nodes[1:]]
            if len(pool) >= 2 and rng.random() < correlated_rate:
                zone = tuple(sorted(rng.sample(pool,
                                               rng.randint(2, min(3, len(pool))))))
                at = round(rng.uniform(0.1, horizon / 3), 6)
                faults.append(Fault(at=at, kind="zone_down", nodes=zone))
                if rng.random() < 0.7:
                    faults.append(Fault(
                        at=round(at + rng.uniform(1.0, 8.0), 6),
                        kind="zone_up", nodes=zone))
            if pool and rng.random() < correlated_rate:
                victim = rng.choice(pool)
                at = round(rng.uniform(0.1, horizon / 3), 6)
                faults.append(Fault(at=at, kind="partition", node=victim))
                # partitions always heal: a permanent one is node loss,
                # which node_down already covers
                faults.append(Fault(at=round(at + rng.uniform(0.5, 6.0), 6),
                                    kind="partition_heal", node=victim))
            if rng.random() < correlated_rate:
                faults.append(Fault(
                    at=round(rng.uniform(0.1, horizon / 3), 6),
                    kind="mass_preempt",
                    fraction=round(rng.uniform(0.25, 0.75), 2)))
            if rng.random() < correlated_rate:
                spec = NodeSpec(name=f"sim-el{len(nodes):02d}",
                                memory_gb=rng.choice([64.0, 192.0]),
                                workers=rng.randint(1, 2))
                join_at = round(rng.uniform(0.1, horizon / 3), 6)
                faults.append(Fault(at=join_at, kind="node_join", spec=spec))
                if rng.random() < 0.5:
                    faults.append(Fault(
                        at=round(join_at + rng.uniform(1.0, 8.0), 6),
                        kind="node_leave", node=spec.name))
            if pool and rng.random() < correlated_rate * 0.5:
                faults.append(Fault(
                    at=round(rng.uniform(0.1, horizon / 3), 6),
                    kind="node_leave", node=rng.choice(pool)))
            if rng.random() < correlated_rate:
                # cascading OOM: a dependency chain whose memory demand
                # doubles hop over hop — early hops fit anywhere, later
                # hops only on the big-memory node (if one exists), so
                # pressure propagates down the DAG exactly like a real
                # memory amplification cascade
                base = len(tasks)
                mem = rng.choice([1.0, 2.0])
                start = round(rng.uniform(0.1, horizon / 4), 6)
                for j in range(rng.randint(3, 6)):
                    tasks.append(SimTaskSpec(
                        at=round(start + 0.05 * j, 6), name=f"oomc{j:02d}",
                        duration=round(rng.uniform(0.01, 0.5), 6),
                        memory_gb=mem,
                        depends_on=(base + j - 1,) if j else ()))
                    mem *= 2.0
        faults.sort(key=lambda f: (f.at, f.kind, f.node or "", f.workflow or ""))
        return Scenario(seed=seed, nodes=nodes, tasks=tasks, faults=faults,
                        horizon=horizon, workflows=workflows)


def _task_failure_probe() -> dict[str, Any]:  # pragma: no cover - debug aid
    """Tiny introspection helper: which injected kinds exist."""
    return {"fn_replacement": sorted(FN_REPLACEMENT),
            "spec_modification": sorted(SPEC_MODIFICATION)}
