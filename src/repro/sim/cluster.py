"""Thread-free cluster execution for the deterministic simulation plane.

The real :class:`~repro.engine.executor.Executor` runs a pilot job per
node: a heartbeat thread plus worker threads pulling tasks off the node
queue.  :class:`SimExecutor` keeps the exact same surface — node
selection, queueing, memory/package/ulimit enforcement, worker-killed
semantics, heartbeats, cancellation, worker respawn — but runs all of it
as *events on the engine's single event loop*:

* task pickup is a ``sim-pump`` event; the task's function executes
  **inline on the loop thread** (scenario task bodies are cheap and
  pure), while its *scripted duration* is virtual: the result is
  delivered by a ``sim-complete`` event ``duration / node.speed`` virtual
  seconds later, holding the node's memory in between;
* heartbeats are periodic ``sim-hb:<node>`` events stamping the engine
  clock's time, so the DFK's heartbeat watcher, the proactive sentinel's
  silence trend and the policy engine's resume rule all see one timebase;
* Table III failure behaviours arise exactly as on the real cluster: an
  unsatisfiable spec raises :class:`EnvironmentMismatchError` /
  :class:`MemoryError` / :class:`UlimitExceededError` at pickup,
  :func:`~repro.engine.cluster.kill_current_worker` inside a task body
  kills the :class:`SimWorker`, and scripted faults (node loss, heartbeat
  silence, worker kill) are applied between events by the scenario
  harness.

No real thread exists anywhere, so a whole failure scenario executes in
(timestamp, FIFO) order on one thread — deterministically.
"""
from __future__ import annotations

import queue
import traceback
from concurrent.futures._base import PENDING as _F_PENDING
from typing import Any, Callable

from repro.core.failures import PilotJobInitError, WorkerLostError
from repro.engine.cluster import (
    Cluster,
    Node,
    ResourcePool,
    _WorkerKilled,
    _current,
    enforce_and_reserve,
)
from repro.engine.events import EventLoop
from repro.engine.executor import Executor
from repro.engine.task import TaskRecord, TaskState

__all__ = ["SimCluster", "SimExecutor", "SimWorker", "SimNodeManager",
           "sim_duration"]


def sim_duration(seconds: float):
    """Decorator: script a task function's *virtual* duration.

    ``@sim_duration(0.3)`` on a task body makes every simulated run of it
    occupy its worker for 0.3 virtual seconds (scaled by node speed) —
    the sim-plane replacement for ``time.sleep(0.3)`` in test tasks.
    """
    def deco(fn):
        fn.sim_duration = seconds
        return fn
    return deco


class SimCluster(Cluster):
    """A :class:`~repro.engine.cluster.Cluster` earmarked for simulation.

    Structurally identical (same pools, same :class:`Node` dataclass);
    exists so harness code can assert it is not accidentally handed to a
    real, thread-spawning engine and as the home of the sim convenience
    constructors.
    """

    @staticmethod
    def from_cluster(cluster: Cluster) -> "SimCluster":
        return SimCluster(list(cluster.pools.values()))

    @staticmethod
    def homogeneous(n_nodes: int = 4, **kwargs: Any) -> "SimCluster":
        return SimCluster.from_cluster(Cluster.homogeneous(n_nodes, **kwargs))

    @staticmethod
    def paper_testbed(*args: Any, **kwargs: Any) -> "SimCluster":
        return SimCluster.from_cluster(Cluster.paper_testbed(*args, **kwargs))


class SimWorker:
    """Worker-process analog without the process: a capacity slot.

    Duck-types the fields the engine reads off a real
    :class:`~repro.engine.cluster.Worker` (``alive``, ``busy``, ``node``,
    ``worker_id``) plus the in-flight bookkeeping the sim needs to cancel
    a completion when its node dies.
    """

    __slots__ = ("node", "worker_id", "alive", "busy", "current",
                 "completion", "held_gb")

    def __init__(self, node: Node, worker_id: str):
        self.node = node
        self.worker_id = worker_id
        self.alive = True
        self.busy = False
        self.current: TaskRecord | None = None
        self.completion: Any = None          # pending sim-complete event
        self.held_gb = 0.0


class SimNodeManager:
    """Pilot-job node manager as pure event-loop state (no threads)."""

    def __init__(self, node: Node, executor: "SimExecutor"):
        self.node = node
        self.executor = executor
        self._spawned = 0
        self._hb_paused = False
        self._hb_event: Any = None
        # pump coalescing: a submission burst to this node schedules ONE
        # sim-pump event, not one per record (the flag is cleared when the
        # event fires, single-threaded and therefore deterministic)
        self._pump_scheduled = False
        # network partition: the *data* path is cut while heartbeats keep
        # flowing — no pickups, and in-flight completions are buffered
        # here until the partition heals (or dropped if the node dies)
        self._partitioned = False
        self._held_deliveries: list[tuple[Any, Any, Any, BaseException | None]] = []

    # -- lifecycle --------------------------------------------------------
    def start(self) -> None:
        if not self.node.healthy:
            raise PilotJobInitError(
                f"pilot job failed to initialize on {self.node.name}",
                node=self.node.name)
        for _ in range(self.node.workers_per_node):
            self.spawn_worker()
        # the real NodeManager's heartbeat thread beats immediately on
        # start, then every period — mirror both
        self.executor.events.call_soon(self.beat,
                                       name=f"sim-hb:{self.node.name}")
        self._hb_event = self.executor.events.schedule_periodic(
            self.executor._heartbeat_period, self.beat,
            name=f"sim-hb:{self.node.name}")

    def stop(self) -> None:
        if self._hb_event is not None:
            self._hb_event.cancel()
        for w in self.node.workers:
            w.alive = False

    # -- heartbeat / worker supervision (NodeManager._hb_loop parity) -----
    def beat(self) -> None:
        if not self.node.healthy:
            return
        if self.executor._heartbeat is not None and not self._hb_paused:
            self.executor._heartbeat(self.node.name,
                                     self.executor.clock.time())
        self.restart_dead_workers()
        self.pump()

    def spawn_worker(self) -> SimWorker:
        self._spawned += 1
        w = SimWorker(self.node, f"{self.node.name}/sw{self._spawned:04d}")
        self.node.workers.append(w)
        return w

    def alive_workers(self) -> list[SimWorker]:
        return [w for w in self.node.workers if w.alive]

    def restart_dead_workers(self) -> int:
        n = 0
        self.node.workers = [w for w in self.node.workers if w.alive]
        while len(self.node.workers) < self.node.workers_per_node:
            self.spawn_worker()
            n += 1
        return n

    def cancel(self, task_id: str) -> TaskRecord | None:
        return self.node.remove_queued(task_id)

    def pause_heartbeats(self) -> None:
        self._hb_paused = True

    def resume_heartbeats(self) -> None:
        self._hb_paused = False

    # -- scripted faults ---------------------------------------------------
    def hardware_down(self) -> None:
        """The node died: heartbeats stop, no new pickups happen.

        Real-cluster parity end to end: a busy worker's in-flight task
        still *delivers* at its scheduled completion (the real worker
        thread finishes its fn), but the ensuing heartbeat silence
        normally trips the DFK's watcher first, which fails and re-routes
        the task — the §III-B manifestation chain — and the late delivery
        is dropped by the winner-takes-future guard.  If the node is
        restored *before* the watcher's staleness window (a quick blip),
        the in-flight task simply succeeds and queued records are picked
        back up by fresh workers, exactly like the real cluster; queue
        entries whose task the watcher already re-routed and resolved are
        skipped at pickup.
        """
        self.node.healthy = False
        for w in self.node.workers:
            w.alive = False
        # completions trapped behind a partition die with the node
        for held_worker, _rec, _res, _err in self._held_deliveries:
            self._release(held_worker)
        self._held_deliveries.clear()

    def kill_worker(self, worker: SimWorker | None = None) -> bool:
        """Externally SIGKILL one (busy, else any alive) worker."""
        if worker is None:
            worker = next((w for w in self.node.workers if w.alive and w.busy),
                          None) or next(
                (w for w in self.node.workers if w.alive), None)
        if worker is None:
            return False
        worker.alive = False
        rec = worker.current
        # a completion already buffered behind a partition dies with its
        # worker — the loss error below supersedes it
        self._held_deliveries = [h for h in self._held_deliveries
                                 if h[0] is not worker]
        if rec is not None:
            if worker.completion is not None:
                worker.completion.cancel()
            self._release(worker)
            err = WorkerLostError("worker killed by injected failure",
                                  node=self.node.name, worker=worker.worker_id)
            self.executor.events.call_soon(
                self.executor._deliver, worker, rec, None, err,
                name="sim-complete")
        return True

    # -- network partition (data path cut, heartbeats flowing) ------------
    def partition(self) -> None:
        self._partitioned = True

    def heal_partition(self) -> None:
        """Reconnect the data path: flush completions that finished behind
        the partition (in completion order), then resume pickups."""
        if not self._partitioned:
            return
        self._partitioned = False
        held, self._held_deliveries = self._held_deliveries, []
        for worker, rec, result, err in held:
            self.executor._deliver(worker, rec, result, err)
        self.schedule_pump()

    # -- execution ---------------------------------------------------------
    def schedule_pump(self) -> None:
        """Request a pickup pass; coalesces into one pending pump event."""
        if self._pump_scheduled:
            return
        self._pump_scheduled = True
        self.executor.events.call_soon(self._pump_event, name="sim-pump")

    def _pump_event(self) -> None:
        self._pump_scheduled = False
        self.pump()

    def pump(self) -> None:
        """Assign queued records to free workers (the pickup event).

        When this node's own queue is dry and a free worker remains, the
        pump tries to *steal* the newest queued record off a loaded
        sibling (a no-op unless the engine enabled work stealing) — the
        event-loop analog of the real worker's steal-on-idle, running
        deterministically in (timestamp, FIFO) event order.
        """
        if not self.node.healthy or self._partitioned:
            return
        while True:
            # plain loop, not next(genexp): restart_dead_workers() may
            # rebind node.workers mid-drain (a task body killing the last
            # worker triggers an inline respawn), so re-read it each pass
            worker = None
            for w in self.node.workers:
                if w.alive and not w.busy:
                    worker = w
                    break
            if worker is None:
                return
            try:
                rec = self.node.task_queue.get_nowait()
            except queue.Empty:
                rec = self.executor.steal_task(self.node)
                if rec is None:
                    return
            if rec is None or rec.cancel_requested or (
                    rec.future is not None
                    and rec.future._state != _F_PENDING):
                # cancelled while queued, or a stale entry whose task was
                # already re-routed and resolved elsewhere (e.g. failed by
                # the heartbeat watcher while this node was down): drop.
                # The raw _state read (vs. future.done(), which takes the
                # condition) is safe here: the sim is single-threaded, and
                # engine futures only ever leave PENDING to terminal states
                continue
            self.executor._start_task(self, worker, rec)

    def _release(self, worker: SimWorker) -> None:
        if worker.held_gb:
            with self.node._mem_lock:
                self.node.mem_in_use_gb -= worker.held_gb
            worker.held_gb = 0.0
        if worker.busy:
            self.node.adjust_busy(-1)
        worker.busy = False
        worker.current = None
        worker.completion = None


class SimExecutor(Executor):
    """Executor whose pool executes as events on the engine's loop.

    Construction mirrors :class:`~repro.engine.executor.Executor` plus the
    loop itself and an optional duration script::

        SimExecutor(pool, on_result, events=dfk.events, clock=vclock,
                    durations={"train_step": 0.5})

    ``durations`` maps task-template names to *nominal* virtual seconds
    (or is a callable ``(record, node) -> seconds | None``); unscripted
    tasks fall back to an ``@sim_duration`` attribute on the function,
    then to the spec's ``est_duration_s``.  Nominal time divides by
    ``node.speed``, so stragglers straggle in virtual time too.
    """

    def __init__(self, pool: ResourcePool,
                 on_result: Callable[..., Any], *,
                 events: EventLoop,
                 durations: dict[str, float] | Callable[..., Any] | None = None,
                 **kwargs: Any):
        super().__init__(pool, on_result, **kwargs)
        self.events = events
        self.durations = durations
        self.managers: dict[str, SimNodeManager] = {}

    @classmethod
    def factory(cls, durations: dict[str, float] | Callable[..., Any] | None
                = None) -> Callable[..., "SimExecutor"]:
        """An ``executor_factory`` for :class:`~repro.engine.dfk.
        DataFlowKernel`: ``DataFlowKernel(..., clock=vclock,
        executor_factory=SimExecutor.factory(durations))``."""
        def make(dfk: Any, pool: ResourcePool) -> "SimExecutor":
            hb = dfk.monitor.heartbeat if dfk.monitor is not None else None
            return cls(pool, dfk._on_result, events=dfk.events,
                       durations=durations, scheduler=dfk.scheduler,
                       heartbeat=hb,
                       denylisted=dfk.denylist.__contains__,
                       heartbeat_period=dfk.heartbeat_period,
                       clock=dfk.clock,
                       steal=getattr(dfk, "work_stealing", False),
                       on_steal=dfk._record_steal)
        return make

    # -- pilot-job lifecycle ----------------------------------------------
    def _make_manager(self, node: Node) -> SimNodeManager:  # type: ignore[override]
        # the base Executor's start()/add_node() call this, so elastic
        # join reuses the real executor's membership path verbatim
        return SimNodeManager(node, self)

    def stop(self) -> None:
        for mgr in self.managers.values():
            mgr.stop()
        self._started = False

    # -- scheduling ---------------------------------------------------------
    def submit(self, record: TaskRecord) -> Node | None:
        node = super().submit(record)
        if node is not None:
            mgr = self.managers.get(node.name)
            if mgr is not None:
                mgr.schedule_pump()
        return node

    # -- scripted faults ----------------------------------------------------
    def fail_node(self, node_name: str) -> None:
        """Hardware loss: node down, heartbeats stop, in-flight tasks lost."""
        mgr = self.managers.get(node_name)
        if mgr is not None:
            mgr.hardware_down()

    def restore_node(self, node_name: str) -> None:
        node = next((n for n in self.pool.nodes if n.name == node_name), None)
        if node is not None:
            node.restore_hardware()
        mgr = self.managers.get(node_name)
        if mgr is not None:
            mgr.restart_dead_workers()
            # records still queued from before the outage get picked back up
            mgr.schedule_pump()

    # -- inline execution ---------------------------------------------------
    def _duration(self, rec: TaskRecord, node: Node,
                  spec: Any = None) -> float:
        base: float | None = None
        if callable(self.durations):
            base = self.durations(rec, node)
        elif self.durations is not None:
            base = self.durations.get(rec.name)
        if base is None:
            base = getattr(rec.fn, "sim_duration", None)
        if base is None:
            base = (spec if spec is not None
                    else rec.effective_resources()).est_duration_s
        if not base:
            return 0.0
        return max(float(base), 0.0) / max(node.speed, 1e-6)

    def _start_task(self, mgr: SimNodeManager, worker: SimWorker,
                    rec: TaskRecord) -> None:
        """One pickup: enforce the environment, run the body inline, and
        schedule the completion at +duration virtual seconds.

        Enforcement is the *same* :func:`~repro.engine.cluster.
        enforce_and_reserve` chain the real worker runs — the paper's
        "200 GB task on a 192 GB node" arises naturally here too, not by
        scripting the error.
        """
        node = mgr.node
        spec = rec.effective_resources()
        rec.start_time = self.clock.time()
        if rec.state in (TaskState.READY, TaskState.SCHEDULED,
                         TaskState.RETRYING):
            rec.state = TaskState.RUNNING
            if rec.on_running is not None:
                try:
                    rec.on_running(rec)
                except Exception:  # noqa: BLE001 - policy bug must not kill the sim
                    pass
        err: BaseException | None = None
        result: Any = None
        duration = 0.0
        try:
            worker.held_gb = enforce_and_reserve(node, spec)
        except BaseException as e:  # noqa: BLE001 - env failures deliver at +0
            err = e
        if err is None:
            # expose the node/worker through the same thread-local the real
            # Worker sets, so task bodies calling current_node() behave
            # identically under simulation
            _current.node, _current.worker = node, worker
            try:
                result = rec.fn(*rec.args, **rec.kwargs)
                duration = self._duration(rec, node, spec)
            except _WorkerKilled as wk:
                worker.alive = False
                err = WorkerLostError(str(wk), node=node.name,
                                      worker=worker.worker_id)
            except BaseException as e:  # noqa: BLE001 - capture everything
                err = e
                err._wrath_traceback = traceback.format_exc()  # type: ignore[attr-defined]
            finally:
                _current.node = _current.worker = None
        if duration == 0.0:
            # Inline delivery: a zero-duration completion scheduled at +0
            # virtual seconds would fire at this same timestamp anyway, so
            # skipping the sim-complete round-trip (heap push/pop, release,
            # re-pump) changes no virtual time and no task outcome — it
            # removes the dominant per-task event cost of large sweeps.
            # The worker is never marked busy: it is free again before the
            # pump loop's next pickup, exactly as after a +0 delivery.
            if worker.held_gb:
                with node._mem_lock:
                    node.mem_in_use_gb -= worker.held_gb
                worker.held_gb = 0.0
            rec.end_time = rec.start_time
            self.on_result(rec, result, err, worker)
            return
        worker.busy = True
        node.adjust_busy(+1)
        worker.current = rec
        worker.completion = self.events.call_later(
            duration, self._deliver, worker, rec, result, err,
            name="sim-complete")

    def _deliver(self, worker: SimWorker, rec: TaskRecord, result: Any,
                 err: BaseException | None) -> None:
        """The completion event: release resources, hand the DFK the result."""
        mgr = self.managers.get(worker.node.name)
        if mgr is not None and mgr._partitioned:
            # data path cut: the task finished on the far side but the
            # result can't cross; buffer until partition_heal (or drop on
            # node death).  Heartbeats keep flowing elsewhere, so the
            # engine sees a healthy node that delivers nothing.
            mgr._held_deliveries.append((worker, rec, result, err))
            return
        if mgr is not None:
            mgr._release(worker)
        rec.end_time = self.clock.time()
        self.on_result(rec, result, err, worker)
        if mgr is not None:
            mgr.schedule_pump()
