"""Chaos-campaign CLI: ``python -m repro.sim --scenarios 500``.

Runs N seeded scenarios on the deterministic simulation plane, checks
the engine invariants plus same-seed trace determinism, and exits
non-zero on any violation.  A failing seed is a complete reproduction
recipe::

    python -m repro.sim --scenarios 1 --base-seed <seed> --show-trace
"""
from __future__ import annotations

import argparse
import sys

from repro.engine.policies import ProactivePolicy, WrathPolicy
from repro.sim.harness import campaign, run_scenario
from repro.sim.scenario import Scenario


def _policy_factory(name: str):
    if name == "wrath":
        return lambda: WrathPolicy()
    if name == "wrath+proactive":
        return lambda: [ProactivePolicy(), WrathPolicy()]
    if name == "baseline":
        return lambda: None
    raise SystemExit(f"unknown --policy {name!r}")


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.sim",
        description="seeded deterministic chaos campaign")
    ap.add_argument("--scenarios", type=int, default=200,
                    help="number of seeded scenarios (default 200)")
    ap.add_argument("--base-seed", type=int, default=0)
    ap.add_argument("--policy", default="wrath",
                    choices=["baseline", "wrath", "wrath+proactive"])
    ap.add_argument("--determinism-checks", type=int, default=3,
                    help="re-run this many scenarios and compare traces")
    ap.add_argument("--max-tasks", type=int, default=16)
    ap.add_argument("--show-trace", action="store_true",
                    help="print the first scenario's full event trace")
    ap.add_argument("--work-stealing", action="store_true",
                    help="run every scenario with decentralized work "
                         "stealing enabled (determinism checks included)")
    args = ap.parse_args(argv)

    engine_kwargs = {"work_stealing": True} if args.work_stealing else None
    if args.show_trace:
        result = run_scenario(
            Scenario.random(args.base_seed, max_tasks=args.max_tasks),
            policy_factory=_policy_factory(args.policy),
            engine_kwargs=engine_kwargs)
        print(result.scenario.describe())
        print(result.trace)
        print(result.summary())
        return 0 if result.ok else 1

    report = campaign(
        args.scenarios, base_seed=args.base_seed,
        policy_factory=_policy_factory(args.policy),
        determinism_checks=args.determinism_checks,
        scenario_kwargs={"max_tasks": args.max_tasks},
        engine_kwargs=engine_kwargs)
    print(report.summary())
    if not report.ok:
        for seed, viol in report.violations[:20]:
            print(f"  seed={seed}: {viol}")
        print("reproduce: python -m repro.sim --scenarios 1 "
              "--base-seed <seed> --show-trace")
        return 1
    return 0


if __name__ == "__main__":  # pragma: no cover - CLI entry
    sys.exit(main())
