"""Chaos-campaign CLI: ``python -m repro.sim --scenarios 500``.

Runs N seeded scenarios on the deterministic simulation plane, checks
the engine invariants plus same-seed trace determinism, and exits
non-zero on any violation.  A failing seed is a complete reproduction
recipe::

    python -m repro.sim --scenarios 1 --base-seed <seed> --show-trace

``--mode guided`` switches to the coverage-guided search
(:mod:`repro.sim.search`): novelty-weighted mutation over monitor-event
n-gram coverage, correlated fault kinds enabled, violations shrunk to
minimal repros.  With ``--repro-out`` the shrunk repros are written as
corpus-format JSON seeds (the nightly CI artifact), and with
``--corpus-dir`` the exit code is the *corpus gate*: non-zero only for a
violation class whose repro is not yet promoted under the corpus.
"""
from __future__ import annotations

import argparse
import sys

from repro.engine.policies import ProactivePolicy, WrathPolicy
from repro.sim.harness import campaign, run_scenario
from repro.sim.scenario import Scenario
from repro.sim.search import guided_campaign, promote_repro


def _policy_factory(name: str):
    if name == "wrath":
        return lambda: WrathPolicy()
    if name == "wrath+proactive":
        return lambda: [ProactivePolicy(), WrathPolicy()]
    if name == "baseline":
        return lambda: None
    raise SystemExit(f"unknown --policy {name!r}")


def _guided(args: argparse.Namespace, engine_kwargs: dict | None) -> int:
    result = guided_campaign(
        args.scenarios, base_seed=args.base_seed, ngram=args.ngram,
        policy_factory=_policy_factory(args.policy),
        determinism_checks=args.determinism_checks,
        scenario_kwargs={"max_tasks": args.max_tasks,
                         "correlated_rate": args.correlated_rate},
        engine_kwargs=engine_kwargs)
    print(result.summary())
    if args.repro_out:
        for scenario, expect in result.repros:
            path = promote_repro(
                scenario, expect, args.repro_out,
                note=f"shrunk by guided search (base_seed="
                     f"{args.base_seed}, budget={args.scenarios})")
            print(f"  wrote {path}")
    for failure in result.determinism_failures:
        print(f"  DETERMINISM: {failure}")
    if result.determinism_failures:
        return 2
    if not result.violations:
        return 0
    for sid, sig, viol, _ in result.violations[:20]:
        print(f"  scenario {sid} [{sig}]: {viol}")
    if args.corpus_dir is not None:
        uncovered = result.uncovered_signatures(args.corpus_dir)
        if not uncovered:
            print("all violation classes already pinned in the corpus "
                  f"({args.corpus_dir}); passing")
            return 0
        print(f"violation classes NOT in corpus: {uncovered}")
        print("promote the shrunk repros (see --repro-out) into "
              f"{args.corpus_dir} after fixing or triaging")
    return 1


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.sim",
        description="seeded deterministic chaos campaign")
    ap.add_argument("--scenarios", type=int, default=200,
                    help="number of seeded scenarios (default 200)")
    ap.add_argument("--base-seed", type=int, default=0)
    ap.add_argument("--policy", default="wrath",
                    choices=["baseline", "wrath", "wrath+proactive"])
    ap.add_argument("--determinism-checks", type=int, default=3,
                    help="re-run this many scenarios and compare traces")
    ap.add_argument("--max-tasks", type=int, default=16)
    ap.add_argument("--show-trace", action="store_true",
                    help="print the first scenario's full event trace")
    ap.add_argument("--work-stealing", action="store_true",
                    help="run every scenario with decentralized work "
                         "stealing enabled (determinism checks included)")
    ap.add_argument("--mode", default="uniform",
                    choices=["uniform", "guided"],
                    help="uniform = independent seeded samples; guided = "
                         "coverage-guided mutation search with correlated "
                         "faults and repro shrinking")
    ap.add_argument("--ngram", type=int, default=3,
                    help="coverage n-gram order for --mode guided")
    ap.add_argument("--correlated-rate", type=float, default=0.35,
                    help="correlated-fault sampling rate (guided mode)")
    ap.add_argument("--corpus-dir", default=None,
                    help="repro corpus directory; with --mode guided the "
                         "exit code fails only on violation classes not "
                         "yet pinned there")
    ap.add_argument("--repro-out", default=None,
                    help="write shrunk minimal repros (corpus-format "
                         "JSON) into this directory")
    args = ap.parse_args(argv)

    engine_kwargs = {"work_stealing": True} if args.work_stealing else None
    if args.mode == "guided" and not args.show_trace:
        return _guided(args, engine_kwargs)
    if args.show_trace:
        result = run_scenario(
            Scenario.random(args.base_seed, max_tasks=args.max_tasks),
            policy_factory=_policy_factory(args.policy),
            engine_kwargs=engine_kwargs)
        print(result.scenario.describe())
        print(result.trace)
        print(result.summary())
        return 0 if result.ok else 1

    report = campaign(
        args.scenarios, base_seed=args.base_seed,
        policy_factory=_policy_factory(args.policy),
        determinism_checks=args.determinism_checks,
        scenario_kwargs={"max_tasks": args.max_tasks},
        engine_kwargs=engine_kwargs)
    print(report.summary())
    if not report.ok:
        for seed, viol in report.violations[:20]:
            print(f"  seed={seed}: {viol}")
        print("reproduce: python -m repro.sim --scenarios 1 "
              "--base-seed <seed> --show-trace")
        return 1
    return 0


if __name__ == "__main__":  # pragma: no cover - CLI entry
    sys.exit(main())
