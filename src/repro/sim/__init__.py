"""Deterministic simulation plane (virtual time + seeded chaos).

WRATH's claims are statements about behaviour under *many* failure
interleavings; wall-clock tests can afford a handful.  This package runs
the **real engine** — scheduler, event loop, retries, heartbeat and
straggler watchers, proactive sentinel, policy stacks, workflow
propagation — on a :class:`VirtualClock`: no threads, no sleeps, events
execute inline in timestamp order, and a 60-second failure scenario
costs microseconds.  On top of that sit a scenario DSL
(:class:`Scenario`, seeded generation), a test harness
(:class:`SimHarness`) and a :func:`campaign` runner that executes
thousands of seeded chaos scenarios per CI run and checks the engine's
invariants — reproducibly: **same seed, same event trace, byte for
byte**.

Quick start::

    from repro.sim import SimCluster, SimHarness

    with SimHarness(SimCluster.homogeneous(2),
                    durations={"work": 0.3}) as h:
        fut = work(7)                       # @task-decorated as usual
        h.run_until(fut.done)
        assert fut.result(timeout=0) == 7

Chaos campaign (also ``python -m repro.sim --scenarios 500``)::

    from repro.sim import campaign
    report = campaign(500, base_seed=0)
    assert report.ok, report.summary()
"""
from repro.sim.clock import VirtualClock
from repro.sim.coverage import CoverageMap, trace_ngrams, trace_tokens
from repro.sim.search import (
    GuidedCampaignResult,
    guided_campaign,
    load_corpus,
    mutate_scenario,
    promote_repro,
    scenario_id,
    shrink_scenario,
    uniform_campaign_coverage,
    violation_signature,
)
from repro.sim.cluster import (
    SimCluster,
    SimExecutor,
    SimNodeManager,
    SimWorker,
    sim_duration,
)
from repro.sim.harness import (
    CampaignResult,
    ScenarioResult,
    SimHarness,
    build_trace,
    campaign,
    run_scenario,
)
from repro.sim.scenario import (
    CORRELATED_FAULT_KINDS,
    FAULT_KINDS,
    TASK_FAILURE_KINDS,
    Fault,
    NodeSpec,
    Scenario,
    SimTaskSpec,
)
from repro.sim.serve import (
    SERVE_FAULT_KINDS,
    ServeFault,
    ServeRequestSpec,
    ServeScenario,
    ServeScenarioResult,
    run_serve_scenario,
    serve_campaign,
)

__all__ = [
    "VirtualClock",
    "SimCluster",
    "SimExecutor",
    "SimNodeManager",
    "SimWorker",
    "sim_duration",
    "SimHarness",
    "ScenarioResult",
    "CampaignResult",
    "run_scenario",
    "campaign",
    "build_trace",
    "Scenario",
    "SimTaskSpec",
    "NodeSpec",
    "Fault",
    "FAULT_KINDS",
    "CORRELATED_FAULT_KINDS",
    "TASK_FAILURE_KINDS",
    "CoverageMap",
    "trace_tokens",
    "trace_ngrams",
    "GuidedCampaignResult",
    "guided_campaign",
    "uniform_campaign_coverage",
    "mutate_scenario",
    "shrink_scenario",
    "scenario_id",
    "violation_signature",
    "promote_repro",
    "load_corpus",
    "ServeFault",
    "ServeRequestSpec",
    "ServeScenario",
    "ServeScenarioResult",
    "run_serve_scenario",
    "serve_campaign",
    "SERVE_FAULT_KINDS",
]
