"""Coverage-guided chaos search over the simulation plane.

The uniform campaign (:func:`repro.sim.harness.campaign`) samples every
scenario independently; this module turns the campaign into a *search*:

* **coverage** — n-grams over the canonical monitor-event trace
  (:mod:`repro.sim.coverage`): a scenario is interesting iff its run
  emitted an event ordering no earlier scenario emitted;
* **mutation** — interesting scenarios become parents; children perturb
  the fault schedule and task arrivals (shift/retarget/add/drop faults,
  duplicate tasks into bursts, graft cascading-OOM chains) toward novel
  engine states, with parents chosen novelty-weighted and the
  fresh-sample/mutation split steered by a per-arm novelty bandit;
* **shrinking** — any invariant-violating scenario is minimized greedily
  (drop faults, then tasks with dependency re-indexing, then idle nodes,
  while the violation still reproduces), then re-run twice and checked
  byte-identical so the minimal repro is deterministic;
* **promotion** — shrunk repros serialize into a corpus of JSON seeds
  under ``tests/chaos_corpus/`` that tier-1 replays forever.

Everything is seeded: the same ``base_seed`` and budget replay the exact
same search, mutation for mutation.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import random
import time as _wall
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable

from repro.sim.coverage import CoverageMap
from repro.sim.harness import ScenarioResult, run_scenario
from repro.sim.scenario import (
    TASK_FAILURE_KINDS,
    Fault,
    NodeSpec,
    Scenario,
    SimTaskSpec,
)

__all__ = ["scenario_id", "violation_signature", "mutate_scenario",
           "shrink_scenario", "guided_campaign", "uniform_campaign_coverage",
           "GuidedCampaignResult", "CoverageReport", "promote_repro",
           "load_corpus", "corpus_signatures"]


# --------------------------------------------------------------------------
# identities
# --------------------------------------------------------------------------
def scenario_id(scenario: Scenario) -> str:
    """Content hash of the canonical scenario JSON (stable repro id)."""
    return hashlib.sha256(scenario.to_json().encode()).hexdigest()[:12]


#: invariant-violation text -> stable signature (prefix match, first wins)
_SIGNATURE_PREFIXES = (
    ("unresolved futures at horizon", "unresolved-futures"),
    ("only ", "missed-submissions"),
    ("records resolved but not terminal", "non-terminal-records"),
    ("task conservation broken", "conservation-broken"),
    ("cancelled scope", "cancelled-scope-leak"),
    ("nondeterminism", "nondeterminism"),
)


def violation_signature(text: str) -> str:
    """Collapse a violation message to a stable class signature.

    Signatures (not full messages) key the corpus gate: a message embeds
    task names and counts that differ between the found scenario and its
    shrunk repro, the *class* of broken invariant does not.
    """
    for prefix, sig in _SIGNATURE_PREFIXES:
        if text.startswith(prefix):
            return sig
    return "other-" + hashlib.sha256(text.encode()).hexdigest()[:8]


# --------------------------------------------------------------------------
# mutation
# --------------------------------------------------------------------------
_FAULT_MENU = (
    # (kind, weight) — correlated kinds weighted up: they are the reason
    # the search exists
    ("node_down", 2), ("hb_pause", 2), ("worker_kill", 2), ("drain", 1),
    ("engine_crash", 1), ("zone_down", 2), ("partition", 3),
    ("mass_preempt", 2), ("node_join", 2), ("node_leave", 2),
)


def _targets(scenario: Scenario) -> list[str]:
    """Fault-targetable node names (node 0 is the untouchable floor)."""
    return [n.name for n in scenario.nodes[1:]]


def _add_fault(scenario: Scenario, rng: random.Random,
               faults: list[Fault]) -> None:
    pool = _targets(scenario)
    kinds = [k for k, w in _FAULT_MENU for _ in range(w)]
    kind = rng.choice(kinds)
    at = round(rng.uniform(0.05, scenario.horizon / 3), 6)
    if kind == "zone_down":
        if len(pool) < 2:
            kind = "node_down"
        else:
            zone = tuple(sorted(rng.sample(pool, rng.randint(2, min(3, len(pool))))))
            faults.append(Fault(at=at, kind="zone_down", nodes=zone))
            if rng.random() < 0.7:
                faults.append(Fault(at=round(at + rng.uniform(0.5, 6.0), 6),
                                    kind="zone_up", nodes=zone))
            return
    if kind == "partition":
        if not pool:
            return
        victim = rng.choice(pool)
        faults.append(Fault(at=at, kind="partition", node=victim))
        faults.append(Fault(at=round(at + rng.uniform(0.3, 5.0), 6),
                            kind="partition_heal", node=victim))
        return
    if kind == "mass_preempt":
        faults.append(Fault(at=at, kind="mass_preempt",
                            fraction=round(rng.uniform(0.25, 0.8), 2)))
        return
    if kind == "node_join":
        spec = NodeSpec(name=f"sim-mj{rng.randrange(100):02d}",
                        memory_gb=rng.choice([64.0, 192.0]),
                        workers=rng.randint(1, 2))
        if any(n.name == spec.name for n in scenario.nodes):
            return
        faults.append(Fault(at=at, kind="node_join", spec=spec))
        return
    if kind == "engine_crash":
        faults.append(Fault(at=at, kind="engine_crash"))
        return
    if not pool:
        return
    node = rng.choice(pool)
    faults.append(Fault(at=at, kind=kind, node=node))
    follow = {"node_down": "node_up", "hb_pause": "hb_resume",
              "drain": "undrain"}.get(kind)
    if follow and rng.random() < 0.6:
        faults.append(Fault(at=round(at + rng.uniform(0.5, 6.0), 6),
                            kind=follow, node=node))


def mutate_scenario(scenario: Scenario, rng: random.Random, *,
                    ops: int = 2, donor: Scenario | None = None) -> Scenario:
    """Perturb a parent toward a neighbouring schedule (1..``ops`` edits).

    Mutations preserve scenario well-formedness: dependency edges stay
    forward-pointing, node 0 stays untargeted, partitions always heal,
    and every :class:`Fault` passes construction-time validation (an
    operation that would not is simply skipped).  With a ``donor``, the
    splice op can graft the donor's fault schedule onto the parent
    (crossover) — empirically the highest-novelty operator, it combines
    two interesting failure timelines into one run."""
    nodes = list(scenario.nodes)
    tasks = list(scenario.tasks)
    faults = list(scenario.faults)
    # retime/splice weighted up: measured novelty-per-child is ~2x the
    # local edits'
    menu = ["shift_fault", "drop_fault", "add_fault", "retarget_fault",
            "dup_task", "perturb_task", "task_burst", "oom_chain",
            "retime_tasks", "retime_tasks"]
    if donor is not None:
        menu += ["splice_faults", "splice_faults"]
    for _ in range(rng.randint(1, max(1, ops))):
        op = rng.choice(menu)
        try:
            if op == "shift_fault" and faults:
                i = rng.randrange(len(faults))
                f = faults[i]
                faults[i] = dataclasses.replace(
                    f, at=round(min(max(f.at * rng.uniform(0.3, 1.7), 0.01),
                                    scenario.horizon / 2), 6))
            elif op == "drop_fault" and faults:
                del faults[rng.randrange(len(faults))]
            elif op == "add_fault":
                _add_fault(scenario, rng, faults)
            elif op == "retarget_fault" and faults and _targets(scenario):
                i = rng.randrange(len(faults))
                f = faults[i]
                if f.node is not None and f.kind != "node_join":
                    faults[i] = dataclasses.replace(
                        f, node=rng.choice(_targets(scenario)))
            elif op == "dup_task" and tasks:
                i = rng.randrange(len(tasks))
                t = tasks[i]
                tasks.append(dataclasses.replace(
                    t, name=f"m{len(tasks):03d}",
                    at=round(max(t.at * rng.uniform(0.5, 1.5), 0.0), 6)))
            elif op == "perturb_task" and tasks:
                i = rng.randrange(len(tasks))
                t = tasks[i]
                which = rng.random()
                if which < 0.4:
                    tasks[i] = dataclasses.replace(
                        t, fail=rng.choice(TASK_FAILURE_KINDS + (None, None)))
                elif which < 0.7:
                    tasks[i] = dataclasses.replace(
                        t, duration=round(rng.uniform(0.01, 3.0), 6))
                else:
                    tasks[i] = dataclasses.replace(
                        t, memory_gb=rng.choice([0.5, 4.0, 64.0, 256.0]))
            elif op == "task_burst" and tasks:
                # arrival burst: several copies landing the same tick
                # stresses batched dispatch + queue contention paths
                t = tasks[rng.randrange(len(tasks))]
                at = round(rng.uniform(0.05, scenario.horizon / 4), 6)
                for _ in range(rng.randint(2, 4)):
                    tasks.append(dataclasses.replace(
                        t, name=f"m{len(tasks):03d}", at=at, depends_on=()))
            elif op == "retime_tasks" and tasks:
                # compress/stretch the whole arrival schedule: the same
                # faults against a shifted workload is a different
                # interleaving end to end
                k = rng.uniform(0.3, 2.5)
                tasks = [dataclasses.replace(
                    t, at=round(min(t.at * k, scenario.horizon / 2), 6))
                    for t in tasks]
            elif op == "splice_faults" and donor is not None:
                names = {n.name for n in nodes}
                for f in donor.faults:
                    if f.kind == "node_join":
                        continue       # joins carry a spec tied to the donor
                    if (f.node is None or f.node in names) and \
                            all(nm in names for nm in f.nodes):
                        faults.append(f)
            elif op == "oom_chain":
                base = len(tasks)
                mem = rng.choice([1.0, 2.0])
                start = round(rng.uniform(0.05, scenario.horizon / 4), 6)
                for j in range(rng.randint(3, 5)):
                    tasks.append(SimTaskSpec(
                        at=round(start + 0.05 * j, 6),
                        name=f"m{len(tasks):03d}",
                        duration=round(rng.uniform(0.01, 0.4), 6),
                        memory_gb=mem,
                        depends_on=(base + j - 1,) if j else ()))
                    mem *= 2.0
        except (ValueError, IndexError):
            continue
    faults.sort(key=lambda f: (f.at, f.kind, f.node or "", f.workflow or ""))
    return Scenario(seed=scenario.seed, nodes=nodes, tasks=tasks,
                    faults=faults, horizon=scenario.horizon,
                    workflows=dict(scenario.workflows))


# --------------------------------------------------------------------------
# shrinking
# --------------------------------------------------------------------------
def _drop_task(scenario: Scenario, i: int) -> Scenario:
    """Remove task ``i``, re-indexing dependency edges past it."""
    tasks = []
    for j, t in enumerate(scenario.tasks):
        if j == i:
            continue
        deps = tuple((d - 1 if d > i else d) for d in t.depends_on if d != i)
        tasks.append(dataclasses.replace(t, depends_on=deps))
    return dataclasses.replace(scenario, tasks=tasks)


def shrink_scenario(scenario: Scenario,
                    predicate: Callable[[ScenarioResult], bool], *,
                    max_runs: int = 300,
                    policy_factory: Callable[[], Any] | None = None,
                    engine_kwargs: dict[str, Any] | None = None,
                    ) -> tuple[Scenario, int]:
    """Greedy minimization: drop faults, then tasks, then idle nodes,
    keeping each removal only if ``predicate(run_scenario(candidate))``
    still holds.  Loops to a fixpoint (a removal can unlock another) and
    returns ``(minimal_scenario, runs_used)``.

    The caller should re-run the minimal scenario twice and compare
    traces byte-for-byte before promoting it (guided_campaign does)."""
    runs = 0

    def reproduces(cand: Scenario) -> bool:
        nonlocal runs
        if runs >= max_runs:
            return False
        runs += 1
        try:
            return predicate(run_scenario(
                cand, policy_factory=policy_factory,
                engine_kwargs=engine_kwargs))
        except Exception:  # noqa: BLE001 - a crashing candidate is not a repro
            return False

    if not reproduces(scenario):
        raise ValueError("shrink_scenario: the starting scenario does not "
                         "reproduce the failure predicate")
    current = scenario
    changed = True
    while changed and runs < max_runs:
        changed = False
        for i in reversed(range(len(current.faults))):
            cand = dataclasses.replace(
                current, faults=[f for j, f in enumerate(current.faults)
                                 if j != i])
            if reproduces(cand):
                current, changed = cand, True
        for i in reversed(range(len(current.tasks))):
            cand = _drop_task(current, i)
            if cand.tasks and reproduces(cand):
                current, changed = cand, True
        referenced = {f.node for f in current.faults if f.node} | \
            {n for f in current.faults for n in f.nodes}
        for i in reversed(range(1, len(current.nodes))):
            if current.nodes[i].name in referenced:
                continue
            cand = dataclasses.replace(
                current, nodes=[n for j, n in enumerate(current.nodes)
                                if j != i])
            if reproduces(cand):
                current, changed = cand, True
    return current, runs


# --------------------------------------------------------------------------
# repro corpus (tests/chaos_corpus/*.json)
# --------------------------------------------------------------------------
def promote_repro(scenario: Scenario, expect: list[str], directory: Any, *,
                  note: str = "") -> Path:
    """Serialize a shrunk repro as a corpus seed.

    ``expect`` is the list of violation *signatures* the scenario must
    reproduce (empty = the scenario must hold every invariant — a fixed
    bug pinned forever)."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    entry = {"schema": 1, "note": note, "expect": sorted(set(expect)),
             "scenario": scenario.to_dict()}
    tag = expect[0] if expect else "clean"
    path = directory / f"repro_{tag}_{scenario_id(scenario)}.json"
    path.write_text(json.dumps(entry, sort_keys=True, indent=2) + "\n")
    return path


def load_corpus(directory: Any) -> list[tuple[Path, Scenario, list[str], str]]:
    """All corpus entries: ``(path, scenario, expected_signatures, note)``."""
    out = []
    directory = Path(directory)
    if not directory.is_dir():
        return out
    for path in sorted(directory.glob("*.json")):
        entry = json.loads(path.read_text())
        out.append((path, Scenario.from_dict(entry["scenario"]),
                    list(entry.get("expect", [])), entry.get("note", "")))
    return out


def corpus_signatures(directory: Any) -> set[str]:
    """Violation signatures the corpus already pins."""
    sigs: set[str] = set()
    for _, _, expect, _ in load_corpus(directory):
        sigs.update(expect)
    return sigs


# --------------------------------------------------------------------------
# the guided campaign
# --------------------------------------------------------------------------
@dataclass
class CoverageReport:
    """Uniform-campaign coverage baseline (the comparison arm)."""

    distinct: int = 0
    history: list[int] = field(default_factory=list)
    executed: int = 0


@dataclass
class GuidedCampaignResult:
    budget: int = 0
    executed: int = 0
    from_seeds: int = 0
    mutated: int = 0
    coverage: CoverageMap = field(default_factory=CoverageMap)
    #: cumulative distinct n-grams after each budgeted run
    history: list[int] = field(default_factory=list)
    #: (scenario_id, signature, violation text, scenario) per violation
    violations: list[tuple[str, str, str, Scenario]] = field(
        default_factory=list)
    #: shrunk minimal repros: (scenario, [signatures]) — byte-identical
    #: re-checked before landing here
    repros: list[tuple[Scenario, list[str]]] = field(default_factory=list)
    shrink_runs: int = 0
    determinism_failures: list[str] = field(default_factory=list)
    wall_seconds: float = 0.0

    @property
    def ok(self) -> bool:
        return not self.violations and not self.determinism_failures

    def distinct(self) -> int:
        return self.coverage.distinct()

    def uncovered_signatures(self, corpus_dir: Any) -> list[str]:
        """Violation signatures with no repro in the corpus — the CI
        gate: a nightly search that finds a *new* way to break an
        invariant fails until its shrunk repro is promoted."""
        known = corpus_signatures(corpus_dir)
        return sorted({sig for _, sig, _, _ in self.violations
                       if sig not in known})

    def summary(self) -> str:
        head = (f"guided campaign: {self.executed} scenarios "
                f"({self.from_seeds} seeded + {self.mutated} mutated), "
                f"{self.distinct()} distinct {self.coverage.n}-gram states, "
                f"{self.wall_seconds:.2f}s wall")
        if self.ok:
            return head + " — all invariants held"
        sigs = sorted({s for _, s, _, _ in self.violations})
        return (head + f" — {len(self.violations)} violations "
                f"({', '.join(sigs)}), {len(self.repros)} shrunk repros")


def uniform_campaign_coverage(
        budget: int, *, base_seed: int = 0, ngram: int = 3,
        policy_factory: Callable[[], Any] | None = None,
        scenario_kwargs: dict[str, Any] | None = None,
        engine_kwargs: dict[str, Any] | None = None) -> CoverageReport:
    """The status-quo arm: ``budget`` independent uniform samples, scored
    with the same coverage metric (equal-budget baseline for the guided
    search)."""
    cov = CoverageMap(ngram)
    report = CoverageReport()
    kw = scenario_kwargs or {}
    for k in range(budget):
        result = run_scenario(Scenario.random(base_seed + k, **kw),
                              policy_factory=policy_factory,
                              engine_kwargs=engine_kwargs)
        cov.add(result.trace)
        report.history.append(cov.distinct())
        report.executed += 1
    report.distinct = cov.distinct()
    return report


def guided_campaign(
        budget: int, *, base_seed: int = 0, ngram: int = 3,
        seed_fraction: float = 0.3,
        policy_factory: Callable[[], Any] | None = None,
        determinism_checks: int = 1,
        shrink: bool = True, max_shrink_runs: int = 200,
        scenario_kwargs: dict[str, Any] | None = None,
        engine_kwargs: dict[str, Any] | None = None) -> GuidedCampaignResult:
    """Coverage-guided search: seeded exploration + adaptive mutation.

    Phase 1 runs ``budget * seed_fraction`` uniform samples (with the
    correlated fault kinds enabled) to seed the parent pool.  Phase 2
    spends the rest of the budget on a two-armed bandit between **fresh**
    correlated samples (exploration — independent draws carry the full
    generator entropy) and **mutation** of novelty-weighted parents
    (exploitation — small perturbations of schedules that already reached
    rare states).  Each arm is scored by its smoothed novelty-per-run so
    the search plays whichever is currently paying, with a forced flip
    every fifth round so neither arm starves; as fresh-sample marginal
    novelty decays the budget shifts toward mutation automatically.  Any
    invariant violation is recorded, then (``shrink=True``) minimized to
    a scenario that still reproduces the same violation *class*, re-run
    twice, and kept only if the two traces are byte-identical.

    Fully deterministic for a given ``(budget, base_seed, ...)`` tuple.
    """
    rng = random.Random(base_seed ^ 0x5EED)
    kw = dict(scenario_kwargs or {})
    kw.setdefault("correlated_rate", 0.35)
    out = GuidedCampaignResult(budget=budget, coverage=CoverageMap(ngram))
    parents: list[tuple[Scenario, int]] = []     # (scenario, novelty)
    # bandit arms: per-run novelty history; the seed phase pre-loads "fresh"
    arm_novelty: dict[str, list[int]] = {"fresh": [], "mutate": []}
    start = _wall.perf_counter()

    def execute(s: Scenario, arm: str) -> tuple[ScenarioResult, int]:
        result = run_scenario(s, policy_factory=policy_factory,
                              engine_kwargs=engine_kwargs)
        out.executed += 1
        new = out.coverage.add(result.trace)
        out.history.append(out.coverage.distinct())
        arm_novelty[arm].append(new)
        if new:
            parents.append((s, new))
        for viol in result.violations:
            out.violations.append(
                (scenario_id(s), violation_signature(viol), viol, s))
        return result, new

    n_seeds = min(budget, max(1, round(budget * seed_fraction)))
    for k in range(n_seeds):
        scenario = Scenario.random(base_seed + k, **kw)
        result, _ = execute(scenario, "fresh")
        out.from_seeds += 1
        if k < determinism_checks:
            replay = run_scenario(Scenario.random(base_seed + k, **kw),
                                  policy_factory=policy_factory,
                                  engine_kwargs=engine_kwargs)
            if replay.trace != result.trace:
                out.determinism_failures.append(
                    f"seed {base_seed + k}: same seed produced a different "
                    f"event trace")

    def arm_score(arm: str) -> float:
        # smoothed novelty-per-run over a sliding window: a windowed
        # score tracks the *current* marginal yield (fresh-sample novelty
        # decays as the generator's reachable states saturate), and the
        # +20 prior keeps an untried arm competitive until it has data
        recent = arm_novelty[arm][-10:]
        return (sum(recent) + 20) / (len(recent) + 1)

    def pick_parent() -> Scenario:
        return rng.choices(parents,
                           weights=[nov for _, nov in parents])[0][0]

    fresh = 0
    rounds = 0
    while out.executed < budget:
        rounds += 1
        arm = "fresh" if arm_score("fresh") >= arm_score("mutate") \
            else "mutate"
        if rounds % 5 == 0:      # forced exploration of the losing arm
            arm = "mutate" if arm == "fresh" else "fresh"
        if arm == "mutate" and not parents:
            arm = "fresh"
        if arm == "mutate":
            # ops=3: deeper edits per child measurably out-earn single
            # tweaks once the easy neighbourhood of a parent is covered
            scenario = mutate_scenario(pick_parent(), rng, ops=3,
                                       donor=pick_parent())
            out.mutated += 1
        else:
            # continue the uniform seed sequence: the fresh arm draws the
            # exact scenarios the equal-budget uniform baseline would,
            # so guided coverage dominates a uniform prefix and the
            # comparison isolates the value of the mutation budget
            scenario = Scenario.random(base_seed + n_seeds + fresh, **kw)
            fresh += 1
            out.from_seeds += 1
        execute(scenario, arm)

    if shrink:
        shrunk_sigs: set[str] = set()
        for _, sig, _, scenario in out.violations:
            if sig in shrunk_sigs:
                continue
            shrunk_sigs.add(sig)

            def hits(result: ScenarioResult, sig: str = sig) -> bool:
                return any(violation_signature(v) == sig
                           for v in result.violations)

            try:
                minimal, used = shrink_scenario(
                    scenario, hits, max_runs=max_shrink_runs,
                    policy_factory=policy_factory,
                    engine_kwargs=engine_kwargs)
            except ValueError:
                continue       # did not reproduce in isolation: not a repro
            out.shrink_runs += used
            once = run_scenario(minimal, policy_factory=policy_factory,
                                engine_kwargs=engine_kwargs)
            twice = run_scenario(minimal, policy_factory=policy_factory,
                                 engine_kwargs=engine_kwargs)
            if once.trace == twice.trace and hits(once):
                out.repros.append((minimal, [sig]))
            else:
                out.determinism_failures.append(
                    f"shrunk repro for {sig} is not byte-identical "
                    f"across reruns")
    out.wall_seconds = _wall.perf_counter() - start
    return out
