"""Simulation harness: drive a virtual-clock engine, run scenarios, run
seeded chaos campaigns, and check the invariants WRATH promises.

Three layers:

* :class:`SimHarness` — ergonomic wrapper for tests: builds a
  virtual-clock :class:`~repro.engine.dfk.DataFlowKernel` wired to
  :class:`~repro.sim.cluster.SimExecutor`, and exposes ``run_until`` /
  ``advance`` / ``result`` so "sleep and poll" test code becomes
  "advance virtual time and assert";
* :func:`run_scenario` — execute one :class:`~repro.sim.scenario.
  Scenario` end to end, returning its event trace, engine stats and any
  invariant violations;
* :func:`campaign` — N seeded scenarios with invariant checking and
  same-seed determinism spot-checks; the CI chaos gate.

**Reproducing a failure**: every scenario is fully determined by its
seed, so a failing campaign line like ``seed=1337: unresolved futures``
reproduces as ``run_scenario(Scenario.random(1337))`` — same trace,
byte for byte.
"""
from __future__ import annotations

import json
import math
import re
import time as _wall
from dataclasses import dataclass, field
from typing import Any, Callable

from repro.core.monitoring import MonitoringDatabase
from repro.engine.dfk import DataFlowKernel
from repro.engine.policies import WrathPolicy
from repro.engine.task import ResourceSpec, TaskDef, TaskState
from repro.injection.engines import FN_REPLACEMENT, SPEC_MODIFICATION
from repro.sim.clock import VirtualClock
from repro.sim.cluster import Node, ResourcePool, SimCluster, SimExecutor
from repro.sim.scenario import Scenario

__all__ = ["SimHarness", "ScenarioResult", "CampaignResult", "run_scenario",
           "campaign", "build_trace"]

_TERMINAL = (TaskState.COMPLETED, TaskState.FAILED, TaskState.DEP_FAILED)


# --------------------------------------------------------------------------
# test-facing harness
# --------------------------------------------------------------------------
class SimHarness:
    """A virtual-clock engine session for tests.

    ``durations`` scripts task durations by template name (see
    :class:`~repro.sim.cluster.SimExecutor`); every other kwarg goes to
    the :class:`~repro.engine.dfk.DataFlowKernel`.  Use as a context
    manager — inside the block the DFK is current, so ``@task``
    invocations submit to it::

        with SimHarness(SimCluster.homogeneous(2),
                        durations={"work": 0.3}) as h:
            fut = work(1)
            h.run_until(lambda: fut.done())
            assert fut.result(timeout=0) == 1
    """

    def __init__(self, cluster: Any = None, *,
                 durations: dict[str, float] | Callable[..., Any] | None = None,
                 monitor: MonitoringDatabase | None = None,
                 trace: bool = False,
                 **dfk_kwargs: Any):
        self.clock = VirtualClock()
        if monitor is None:
            monitor = MonitoringDatabase(clock=self.clock,
                                         keep_event_log=trace)
        else:
            # a user-supplied monitor must still live on the virtual
            # timebase (real stamps would break every now-vs-last-beat
            # comparison) and honor trace=
            monitor.clock = self.clock
            monitor._time = self.clock.time
            if trace and monitor.event_log is None:
                monitor.event_log = []
        self.monitor = monitor
        if cluster is None:
            cluster = SimCluster.homogeneous(2)
        self.cluster = cluster
        self.dfk = DataFlowKernel(
            cluster, monitor=self.monitor, clock=self.clock,
            executor_factory=SimExecutor.factory(durations), **dfk_kwargs)

    # -- session ----------------------------------------------------------
    def __enter__(self) -> "SimHarness":
        self.dfk.__enter__()
        return self

    def __exit__(self, *exc: Any) -> None:
        self.dfk.__exit__(*exc)

    # -- virtual-time control ---------------------------------------------
    def run_until(self, predicate: Callable[[], bool] | None = None,
                  timeout: float = 60.0) -> bool:
        """Drive events until ``predicate()`` holds or ``timeout`` virtual
        seconds pass; returns whether the predicate holds."""
        self.dfk.events.run_until(predicate,
                                  deadline=self.clock.now() + timeout)
        return predicate() if predicate is not None else True

    def advance(self, dt: float) -> None:
        """Run everything scheduled in the next ``dt`` virtual seconds and
        land the clock exactly ``dt`` later — the sim replacement for
        ``time.sleep(dt)``."""
        self.dfk.events.run_until(deadline=self.clock.now() + dt)

    def result(self, fut: Any, timeout: float = 60.0) -> Any:
        """Drive the sim until ``fut`` resolves, then return its result
        (raising its exception) — the sim ``fut.result(timeout=...)``."""
        if not self.run_until(fut.done, timeout=timeout):
            raise TimeoutError(
                f"future {fut!r} unresolved after {timeout} virtual seconds")
        return fut.result(timeout=0)

    def wait_all(self, timeout: float = 60.0) -> bool:
        return self.dfk.wait_all(timeout)

    # -- fault injection ---------------------------------------------------
    def _manager(self, node_name: str):
        for ex in self.dfk.executors.values():
            mgr = ex.managers.get(node_name)
            if mgr is not None:
                return ex, mgr
        raise KeyError(f"no sim node named {node_name!r}")

    def fail_node(self, node_name: str) -> None:
        node = self.cluster.find_node(node_name)
        if node is not None:
            node.healthy = False
        ex, _ = self._manager(node_name)
        ex.fail_node(node_name)

    def restore_node(self, node_name: str) -> None:
        ex, _ = self._manager(node_name)
        ex.restore_node(node_name)

    def pause_heartbeats(self, node_name: str) -> None:
        self._manager(node_name)[1].pause_heartbeats()

    def resume_heartbeats(self, node_name: str) -> None:
        self._manager(node_name)[1].resume_heartbeats()

    def kill_worker(self, node_name: str) -> bool:
        return self._manager(node_name)[1].kill_worker()

    def trace(self) -> str:
        return build_trace(self.monitor)


# --------------------------------------------------------------------------
# event traces
# --------------------------------------------------------------------------
_TASK_ID_RE = re.compile(r"task-\d{6}")


def build_trace(monitor: MonitoringDatabase,
                epoch: float = VirtualClock.EPOCH) -> str:
    """Serialize the monitor's ordered event log as a canonical trace.

    Raw task ids come from a process-global counter, so two runs of the
    same scenario in one process would differ spuriously; ids are
    relabelled ``T0, T1, ...`` in order of first appearance (including
    inside reason strings).  Everything else — virtual timestamps, node
    names, retry decisions, failure reasons — is emitted verbatim:
    *identical trace* means identical behaviour.
    """
    if monitor.event_log is None:
        raise ValueError("monitor was not built with keep_event_log=True")
    rename: dict[str, str] = {}

    def norm(value: Any) -> Any:
        if isinstance(value, str):
            return _TASK_ID_RE.sub(
                lambda m: rename.setdefault(m.group(0), f"T{len(rename)}"),
                value)
        return value

    lines = []
    for entry in monitor.event_log:
        d = {k: norm(v) for k, v in entry.items()}
        t = d.pop("time") - epoch
        scope = d.pop("scope")
        event = d.pop("event")
        payload = json.dumps(d, sort_keys=True, default=repr)
        lines.append(f"{t:014.6f} {scope} {event} {payload}")
    return "\n".join(lines)


# --------------------------------------------------------------------------
# scenario execution
# --------------------------------------------------------------------------
@dataclass
class ScenarioResult:
    seed: int
    scenario: Scenario
    trace: str
    stats: dict[str, float]
    violations: list[str]
    #: per-task outcome: ("ok", result) or ("error", exception type name)
    outcomes: dict[str, tuple[str, Any]]
    events_executed: int = 0
    # -- checkpoint/restart bookkeeping (engine_crash scenarios) ----------
    #: number of engine crash/restart cycles that occurred
    crashes: int = 0
    #: TaskStore size (committed results) snapshotted at each crash
    committed_at_crash: list[int] = field(default_factory=list)
    #: tasks the *final* engine incarnation actually executed (dispatched
    #: to a worker at least once) — after a restart this is the incomplete
    #: frontier, everything else resolves from the store
    reexecuted: int = 0

    @property
    def ok(self) -> bool:
        return not self.violations

    def summary(self) -> str:
        status = "ok" if self.ok else f"VIOLATIONS={len(self.violations)}"
        return (f"seed={self.seed}: {status} "
                f"submitted={int(self.stats['submitted'])} "
                f"completed={int(self.stats['completed'])} "
                f"failed={int(self.stats['failed'])} "
                f"dep_failed={int(self.stats['dep_failed'])} "
                f"retries={int(self.stats['retries'])} "
                f"fast_fails={int(self.stats['fast_fails'])}")


def _make_fn(index: int, fail: str | None) -> Callable[..., Any]:
    if fail in FN_REPLACEMENT:
        return FN_REPLACEMENT[fail]

    def ok_fn(*deps: Any) -> int:
        return index
    return ok_fn


def _build_cluster(scenario: Scenario) -> SimCluster:
    nodes = [Node(name=s.name, memory_gb=s.memory_gb, speed=s.speed,
                  workers_per_node=s.workers, packages=frozenset(s.packages),
                  ulimit_files=s.ulimit_files)
             for s in scenario.nodes]
    return SimCluster([ResourcePool("sim", nodes)])


def run_scenario(scenario: Scenario, *,
                 policy_factory: Callable[[], Any] | None = None,
                 default_retries: int = 3,
                 heartbeat_period: float = 0.1,
                 heartbeat_threshold: float = 5.0,
                 task_store: Any = None,
                 engine_kwargs: dict[str, Any] | None = None) -> ScenarioResult:
    """Execute one scenario on a fresh virtual-clock engine.

    ``policy_factory`` builds the resilience stack per run (policies bind
    to one engine, so a *factory*, not an instance); default is WRATH's
    taxonomy-driven hierarchical retry.

    ``engine_kwargs`` are forwarded verbatim to every
    :class:`~repro.engine.dfk.DataFlowKernel` the scenario builds
    (including post-crash incarnations) — e.g.
    ``engine_kwargs={"work_stealing": True}`` runs the whole campaign
    with decentralized work stealing on.

    ``engine_crash`` faults tear the whole engine down and rebuild it
    against the same lineage-aware :class:`~repro.checkpoint.task_store.
    TaskStore` (``task_store=``; a fresh in-memory store is created when
    the scenario crashes and none was given), then replay the workflow
    script: already-committed tasks resolve from the store by
    memoization, only the incomplete frontier re-executes.  Environment
    state survives the crash (dead hardware stays dead, silent
    monitoring agents stay silent, scope cancellations are re-issued);
    engine-private state (denylist, drain sets, in-flight attempts) is
    lost, exactly as a real restart loses it.
    """
    clock = VirtualClock()
    monitor = MonitoringDatabase(clock=clock, keep_event_log=True)
    store = task_store
    if store is None and any(f.kind == "engine_crash" for f in scenario.faults):
        from repro.checkpoint.task_store import TaskStore
        store = TaskStore()

    n_tasks = len(scenario.tasks)
    futures: dict[int, Any] = {}
    cancel_times: dict[str, float] = {}
    fired: set[int] = set()          # indices of faults already applied
    crash = {"pending": False}
    state: dict[str, Any] = {}       # current engine incarnation

    def build_engine() -> None:
        cluster = _build_cluster(scenario)
        policy = (policy_factory() if policy_factory is not None
                  else WrathPolicy())
        dfk = DataFlowKernel(
            cluster, monitor=monitor, clock=clock, policy=policy,
            checkpoint=store,
            executor_factory=SimExecutor.factory(scenario.durations),
            default_retries=default_retries,
            heartbeat_period=heartbeat_period,
            heartbeat_threshold=heartbeat_threshold,
            **(engine_kwargs or {}))
        dfk.start()
        state["dfk"] = dfk
        state["cluster"] = cluster
        state["wfs"] = {name: dfk.workflow(name, propagate=mode)
                        for name, mode in scenario.workflows.items()}

    def submit(i: int) -> None:
        spec = scenario.tasks[i]
        res = {"memory_gb": spec.memory_gb}
        if spec.fail in SPEC_MODIFICATION:
            res.update(SPEC_MODIFICATION[spec.fail])
        packages = tuple(res.pop("packages", ()))
        td = TaskDef(_make_fn(i, spec.fail), spec.name,
                     ResourceSpec(packages=packages, **res),
                     spec.max_retries,
                     workflow=state["wfs"].get(spec.workflow))
        args = tuple(futures[j] for j in spec.depends_on)
        futures[i] = state["dfk"].submit(td, args, {})

    def apply_fault(idx: int, fault: Any) -> None:
        fired.add(idx)
        payload: dict[str, Any] = {"node": fault.node,
                                   "workflow": fault.workflow}
        if fault.nodes:
            payload["nodes"] = list(fault.nodes)
        if fault.kind == "mass_preempt":
            payload["fraction"] = fault.fraction
        if fault.spec is not None:
            payload["node"] = fault.spec.name
        monitor.record_system_event(f"fault_{fault.kind}", **payload)
        if fault.kind == "engine_crash":
            # flagged only: the teardown/rebuild happens *outside* the
            # event loop (run_until checks the predicate between events)
            crash["pending"] = True
            return
        dfk, cluster, wfs = state["dfk"], state["cluster"], state["wfs"]
        ex = dfk.executors["sim"]
        if fault.kind == "node_down":
            node = cluster.find_node(fault.node)
            if node is not None:
                node.healthy = False
            ex.fail_node(fault.node)
        elif fault.kind == "node_up":
            ex.restore_node(fault.node)
        elif fault.kind == "hb_pause":
            mgr = ex.managers.get(fault.node)
            if mgr is not None:
                mgr.pause_heartbeats()
        elif fault.kind == "hb_resume":
            mgr = ex.managers.get(fault.node)
            if mgr is not None:
                mgr.resume_heartbeats()
        elif fault.kind == "worker_kill":
            mgr = ex.managers.get(fault.node)
            if mgr is not None:
                mgr.kill_worker()
        elif fault.kind == "drain":
            dfk.drain_node(fault.node, reason="scripted drain")
        elif fault.kind == "undrain":
            dfk.undrain_node(fault.node)
        elif fault.kind == "cancel_workflow":
            wf = wfs.get(fault.workflow)
            if wf is not None:
                cancel_times[fault.workflow] = clock.time()
                wf.cancel("scripted cancellation")
        elif fault.kind == "zone_down":
            # the whole group at once — one fault event, many nodes
            for name in fault.nodes:
                node = cluster.find_node(name)
                if node is not None:
                    node.healthy = False
                ex.fail_node(name)
        elif fault.kind == "zone_up":
            for name in fault.nodes:
                ex.restore_node(name)
        elif fault.kind == "partition":
            mgr = ex.managers.get(fault.node)
            if mgr is not None:
                mgr.partition()
        elif fault.kind == "partition_heal":
            mgr = ex.managers.get(fault.node)
            if mgr is not None:
                mgr.heal_partition()
        elif fault.kind == "mass_preempt":
            # spot reclaim: kill fraction of alive workers in one tick.
            # Victim order is deterministic — busy workers first (maximum
            # disruption), then (node, worker id) lexicographic
            alive = [(mgr, w) for _, mgr in sorted(ex.managers.items())
                     for w in mgr.node.workers if w.alive]
            alive.sort(key=lambda mw: (not mw[1].busy,
                                       mw[1].node.name, mw[1].worker_id))
            n_kill = math.ceil(fault.fraction * len(alive))
            for mgr, w in alive[:n_kill]:
                mgr.kill_worker(w)
        elif fault.kind == "node_join":
            s = fault.spec
            dfk.join_node(Node(name=s.name, memory_gb=s.memory_gb,
                               speed=s.speed, workers_per_node=s.workers,
                               packages=frozenset(s.packages),
                               ulimit_files=s.ulimit_files),
                          pool="sim")
        elif fault.kind == "node_leave":
            dfk.leave_node(fault.node, reason="scripted node_leave")

    build_engine()
    t0 = clock.now()
    for i, spec in enumerate(scenario.tasks):
        state["dfk"].events.call_at(t0 + spec.at, submit, i,
                                    name="scenario-submit")
    for idx, fault in enumerate(scenario.faults):
        state["dfk"].events.call_at(t0 + fault.at, apply_fault, idx, fault,
                                    name=f"fault:{fault.kind}")

    def all_done() -> bool:
        return (len(futures) == n_tasks
                and all(f.done() for f in futures.values()))

    def restart(generation: int) -> None:
        """Tear the crashed engine down and bring a new one up on the
        same store/monitor/clock, replaying the workflow script."""
        old_dfk, old_cluster = state["dfk"], state["cluster"]
        dead = [n.name for pool in old_cluster.pools.values()
                for n in pool.nodes if not n.healthy]
        hb_paused = [name for name, mgr
                     in old_dfk.executors["sim"].managers.items()
                     if mgr._hb_paused]
        partitioned = [name for name, mgr
                       in old_dfk.executors["sim"].managers.items()
                       if mgr._partitioned]
        # elastic membership survives the crash too: nodes that joined are
        # still physically there, departed nodes are still gone
        base_names = {s.name for s in scenario.nodes}
        old_nodes = [n for pool in old_cluster.pools.values()
                     for n in pool.nodes]
        joined = [n for n in old_nodes if n.name not in base_names]
        departed = base_names - {n.name for n in old_nodes}
        cancelled = {name: wf.cancel_reason
                     for name, wf in state["wfs"].items() if wf.cancelled}
        already_submitted = sorted(futures)
        old_dfk.shutdown()
        monitor.record_system_event("engine_restart", generation=generation)
        build_engine()
        dfk, cluster = state["dfk"], state["cluster"]
        ex = dfk.executors["sim"]
        for n in joined:
            dfk.join_node(Node(name=n.name, memory_gb=n.memory_gb,
                               speed=n.speed,
                               workers_per_node=n.workers_per_node,
                               packages=n.packages,
                               ulimit_files=n.ulimit_files),
                          pool="sim")
        for name in sorted(departed):
            dfk.leave_node(name, reason="departed before restart")
        # environment state survives an engine restart: dead hardware
        # stays dead until a scripted node_up revives it, a silent
        # monitoring agent stays silent until a scripted hb_resume, and a
        # partition stays cut until a scripted partition_heal (anything
        # that finished behind it was lost with the old engine)
        for name in dead:
            node = cluster.find_node(name)
            if node is not None:
                node.healthy = False
            ex.fail_node(name)
        for name in hb_paused:
            mgr = ex.managers.get(name)
            if mgr is not None:
                mgr.pause_heartbeats()
        for name in partitioned:
            mgr = ex.managers.get(name)
            if mgr is not None:
                mgr.partition()
        # scope cancellation is coordinator state the replayed script
        # re-issues; members resubmitted below auto-cancel at submit
        for name, reason in cancelled.items():
            wf = state["wfs"].get(name)
            if wf is not None:
                wf.cancel(reason or "cancellation restored after restart")
        # replay: resubmit everything the script had already submitted
        # (committed lineage resolves from the store without dispatch) ...
        for i in already_submitted:
            submit(i)
        # ... and re-schedule arrivals/faults that had not happened yet
        now = clock.now()
        for i, spec in enumerate(scenario.tasks):
            if i not in futures:
                dfk.events.call_at(max(t0 + spec.at, now), submit, i,
                                   name="scenario-submit")
        for idx, fault in enumerate(scenario.faults):
            if idx not in fired:
                dfk.events.call_at(max(t0 + fault.at, now), apply_fault,
                                   idx, fault, name=f"fault:{fault.kind}")

    executed = 0
    crashes = 0
    committed_at_crash: list[int] = []
    while True:
        executed += state["dfk"].events.run_until(
            lambda: all_done() or crash["pending"],
            deadline=t0 + scenario.horizon)
        if not crash["pending"]:
            break
        crash["pending"] = False
        crashes += 1
        committed_at_crash.append(len(store) if store is not None else 0)
        restart(crashes)

    dfk, wfs = state["dfk"], state["wfs"]
    violations = _check_invariants(scenario, dfk, futures, wfs, cancel_times)
    trace = build_trace(monitor)
    stats = dict(dfk.stats)
    reexecuted = sum(1 for rec in dfk.tasks.values() if rec.attempts)
    outcomes: dict[str, tuple[str, Any]] = {}
    for i, fut in futures.items():
        name = scenario.tasks[i].name
        if not fut.done():
            outcomes[name] = ("unresolved", None)
        elif fut.exception(timeout=0) is not None:
            outcomes[name] = ("error",
                              type(fut.exception(timeout=0)).__name__)
        else:
            outcomes[name] = ("ok", fut.result(timeout=0))
    dfk.shutdown()
    return ScenarioResult(seed=scenario.seed, scenario=scenario, trace=trace,
                          stats=stats, violations=violations,
                          outcomes=outcomes, events_executed=executed,
                          crashes=crashes,
                          committed_at_crash=committed_at_crash,
                          reexecuted=reexecuted)


def _check_invariants(scenario: Scenario, dfk: DataFlowKernel,
                      futures: dict[int, Any], wfs: dict[str, Any],
                      cancel_times: dict[str, float]) -> list[str]:
    """The campaign's correctness contract, checked before shutdown."""
    v: list[str] = []
    # 1. every submission happened and every future resolved by the horizon
    if len(futures) != len(scenario.tasks):
        v.append(f"only {len(futures)}/{len(scenario.tasks)} tasks were "
                 f"submitted within the horizon")
    unresolved = [scenario.tasks[i].name for i, f in futures.items()
                  if not f.done()]
    if unresolved:
        v.append(f"unresolved futures at horizon: {unresolved}")
    # 2. no task lost: every primary record reached a terminal state
    stuck = [rec.task_id for rec in dfk.tasks.values()
             if rec.future is not None and rec.future.done()
             and rec.state not in _TERMINAL]
    if stuck:
        v.append(f"records resolved but not terminal: {stuck}")
    # 3. conservation: submitted == completed + failed + dep_failed
    s = dfk.stats
    if s["submitted"] != s["completed"] + s["failed"] + s["dep_failed"]:
        v.append(
            f"task conservation broken: submitted={s['submitted']} != "
            f"completed={s['completed']} + failed={s['failed']} + "
            f"dep_failed={s['dep_failed']}")
    # 4. cancelled scopes stay cancelled
    for name, wf in wfs.items():
        if not wf.cancelled:
            continue
        cancelled_at = cancel_times.get(name)
        for rec in wf.tasks():
            if rec.state not in _TERMINAL:
                v.append(f"cancelled scope {name!r} member {rec.task_id} "
                         f"not terminal ({rec.state.value})")
            if (cancelled_at is not None
                    and rec.state is TaskState.COMPLETED
                    and rec.start_time > cancelled_at):
                v.append(f"cancelled scope {name!r} member {rec.task_id} "
                         f"started after the scope was cancelled")
    return v


# --------------------------------------------------------------------------
# campaigns
# --------------------------------------------------------------------------
@dataclass
class CampaignResult:
    results: list[ScenarioResult] = field(default_factory=list)
    #: (seed, violation) pairs, including determinism-check mismatches
    violations: list[tuple[int, str]] = field(default_factory=list)
    wall_seconds: float = 0.0

    @property
    def ok(self) -> bool:
        return not self.violations

    def summary(self) -> str:
        n = len(self.results)
        bad_seeds = sorted({s for s, _ in self.violations})
        head = (f"campaign: {n} scenarios, "
                f"{sum(r.events_executed for r in self.results)} events, "
                f"{self.wall_seconds:.2f}s wall")
        if self.ok:
            return head + " — all invariants held"
        return (head + f" — {len(self.violations)} violations in seeds "
                f"{bad_seeds}; reproduce with "
                f"run_scenario(Scenario.random(<seed>))")


def campaign(n: int, *, base_seed: int = 0,
             policy_factory: Callable[[], Any] | None = None,
             determinism_checks: int = 1,
             scenario_kwargs: dict[str, Any] | None = None,
             engine_kwargs: dict[str, Any] | None = None) -> CampaignResult:
    """Run ``n`` seeded chaos scenarios and check every invariant.

    Seeds are ``base_seed .. base_seed + n - 1``.  The first
    ``determinism_checks`` scenarios are executed *twice* and their
    traces compared byte-for-byte — the "same seed ⇒ identical event
    trace" invariant guarding against nondeterminism creeping into the
    engine.  Any violation names its seed; the seed alone reproduces the
    run.
    """
    kw = scenario_kwargs or {}
    out = CampaignResult()
    start = _wall.perf_counter()
    for k in range(n):
        seed = base_seed + k
        scenario = Scenario.random(seed, **kw)
        result = run_scenario(scenario, policy_factory=policy_factory,
                              engine_kwargs=engine_kwargs)
        out.results.append(result)
        for viol in result.violations:
            out.violations.append((seed, viol))
        if k < determinism_checks:
            replay = run_scenario(Scenario.random(seed, **kw),
                                  policy_factory=policy_factory,
                                  engine_kwargs=engine_kwargs)
            if replay.trace != result.trace:
                out.violations.append(
                    (seed, "nondeterminism: same seed produced a "
                           "different event trace"))
    out.wall_seconds = _wall.perf_counter() - start
    return out
