"""Trace coverage: n-grams over the canonical monitor-event stream.

The guided chaos campaign needs a notion of "this scenario reached an
engine state no earlier scenario reached".  Source-line coverage is
meaningless for a deterministic event-loop engine — every scenario runs
the same dispatcher — so coverage is defined over *behaviour*: the
ordered sequence of monitor events a run emits.

Each trace line (``build_trace`` format: ``<t> <scope> <event> <json>``)
is normalized to a token.  Task scopes are collapsed to the literal
``task`` (task ids are relabelled per run and their count is a measure of
scenario *size*, not novelty); system scope stays ``system``.  The
coverage unit is the n-gram of consecutive tokens: 1-grams distinguish
*which* failure machinery fired, higher n distinguishes *orderings* —
retry-after-steal-after-partition is a different 3-gram path than
retry-after-steal alone, which is exactly the kind of interleaving a
correlated-fault search is hunting.
"""
from __future__ import annotations

from typing import Iterable

__all__ = ["trace_tokens", "trace_ngrams", "CoverageMap"]


def trace_tokens(trace: str) -> list[str]:
    """Canonical trace text -> normalized ``scope:event`` token sequence."""
    tokens: list[str] = []
    for line in trace.splitlines():
        parts = line.split(" ", 3)
        if len(parts) < 3:
            continue
        _, scope, event = parts[0], parts[1], parts[2]
        scope_class = "system" if scope == "system" else "task"
        tokens.append(f"{scope_class}:{event}")
    return tokens


def trace_ngrams(trace: str, n: int = 3) -> set[tuple[str, ...]]:
    """All n-grams (orders 1..n) of the normalized token sequence.

    Including the lower orders makes coverage monotone in n and keeps a
    single novel *event kind* visible even when its context n-gram was
    already seen.
    """
    tokens = trace_tokens(trace)
    grams: set[tuple[str, ...]] = set()
    for order in range(1, n + 1):
        for i in range(len(tokens) - order + 1):
            grams.add(tuple(tokens[i:i + order]))
    return grams


class CoverageMap:
    """Accumulated n-gram coverage across a campaign."""

    def __init__(self, n: int = 3):
        self.n = n
        self.seen: set[tuple[str, ...]] = set()

    def novelty(self, trace: str) -> int:
        """How many n-grams of ``trace`` are new, without recording them."""
        return len(trace_ngrams(trace, self.n) - self.seen)

    def add(self, trace: str) -> int:
        """Record a trace; returns the number of newly-covered n-grams."""
        grams = trace_ngrams(trace, self.n)
        new = len(grams - self.seen)
        self.seen |= grams
        return new

    def add_tokens(self, grams: Iterable[tuple[str, ...]]) -> int:
        before = len(self.seen)
        self.seen.update(grams)
        return len(self.seen) - before

    def distinct(self) -> int:
        return len(self.seen)

    def __len__(self) -> int:
        return len(self.seen)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"<CoverageMap n={self.n} distinct={len(self.seen)}>"
