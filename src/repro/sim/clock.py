"""Virtual time: the deterministic clock behind the simulation plane.

A :class:`VirtualClock` is a :class:`repro.engine.events.Clock` whose time
advances only by decree — :meth:`advance_to` — never by the passage of
real time.  The :class:`~repro.engine.events.EventLoop` drives it from
``run_until``: pop the next scheduled event, jump the clock to its
timestamp, execute.  A "60-second" heartbeat-loss scenario therefore
costs exactly the callbacks it runs, and two runs of the same scenario
see the same timestamps to the last bit.

``time()`` (the wall-clock stamp used for heartbeats, TTF and monitor
events) is ``epoch + now()``: a fixed, plausible-looking epoch keeps
virtual wall stamps positive and distinguishable from real ones while
staying deterministic.
"""
from __future__ import annotations

import threading

from repro.engine.events import Clock


class VirtualClock(Clock):
    """Deterministic discrete-event clock (starts at virtual second 0)."""

    virtual = True

    #: fixed virtual epoch for wall-clock stamps (2023-11-14T22:13:20Z)
    EPOCH = 1_700_000_000.0

    def __init__(self, start: float = 0.0, epoch: float = EPOCH):
        self._now = float(start)
        self.epoch = float(epoch)

    # -- Clock protocol ---------------------------------------------------
    def now(self) -> float:
        return self._now

    def time(self) -> float:
        return self.epoch + self._now

    def wait(self, cond: threading.Condition, timeout: float) -> None:
        # only reachable if a *threaded* EventLoop is built on a virtual
        # clock — the loop refuses that combination, so waiting here would
        # mean a bug: fail loudly instead of hanging a test run
        raise RuntimeError("VirtualClock cannot wait; drive the loop with "
                           "EventLoop.run_until() instead")

    def sleep(self, seconds: float) -> None:
        # a virtual sleep is just a jump: no thread ever blocks on it
        self.advance(seconds)

    # -- virtual-time control ---------------------------------------------
    def advance_to(self, t: float) -> None:
        """Jump to virtual timestamp ``t`` (never backwards)."""
        if t > self._now:
            self._now = t

    def advance(self, dt: float) -> None:
        """Jump forward ``dt`` virtual seconds."""
        self.advance_to(self._now + dt)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"<VirtualClock t={self._now:.6f}>"
