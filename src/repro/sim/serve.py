"""Seeded serving-plane scenarios: deterministic chaos for the serve stack.

The serving analog of :mod:`repro.sim.scenario`: a :class:`ServeScenario`
declares one complete serving run — replica pool shape, a timed request
arrival schedule (with per-request SLOs), and a timed replica fault
schedule — and :func:`run_serve_scenario` executes it on the **real**
serving driver (continuous batcher, admission stack, autoscaler, policy
failover) under a :class:`~repro.sim.clock.VirtualClock` with the
simulated decode backend.  Same seed ⇒ byte-identical event trace.

As with task scenarios, **the seed is the scenario**:
:meth:`ServeScenario.random` draws every choice (pool size, arrival
pattern, prompt shapes, deadlines, kill/restore schedule, whether
admission control and autoscaling are enabled) from one
``random.Random(seed)``.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field

from repro.core import MonitoringDatabase
from repro.engine.policies import WrathPolicy
from repro.engine.scheduler import make_scheduler
from repro.serve import (ReplicaAutoscaler, ServeRequest, SLOAdmissionPolicy,
                         WrathServeDriver)
from repro.sim.clock import VirtualClock
from repro.sim.harness import build_trace

__all__ = ["ServeFault", "ServeRequestSpec", "ServeScenario",
           "ServeScenarioResult", "run_serve_scenario", "serve_campaign",
           "SERVE_FAULT_KINDS"]

#: replica fault kinds the serving driver knows how to inject
SERVE_FAULT_KINDS = ("kill", "restore")


@dataclass(frozen=True)
class ServeFault:
    """One timed replica fault (``kill`` / ``restore``)."""

    at: float                      # virtual seconds from scenario start
    kind: str
    replica: str

    def __post_init__(self) -> None:
        if self.kind not in SERVE_FAULT_KINDS:
            raise ValueError(f"unknown serve fault kind {self.kind!r}; "
                             f"expected one of {SERVE_FAULT_KINDS}")


@dataclass(frozen=True)
class ServeRequestSpec:
    """One request arrival: prompt, generation budget, SLO."""

    at: float
    prompt: tuple[int, ...]
    max_new_tokens: int = 8
    deadline_s: float | None = None


@dataclass
class ServeScenario:
    """A complete seeded serving-plane script."""

    seed: int
    n_replicas: int = 3
    max_batch: int = 4
    step_s: float = 0.02           # modeled decode-step cost (speed 1.0)
    requests: list[ServeRequestSpec] = field(default_factory=list)
    faults: list[ServeFault] = field(default_factory=list)
    horizon: float = 60.0
    tick_period: float = 0.25
    admission: bool = True
    autoscale: bool = False
    max_replicas: int = 6
    scheduler: str | None = None
    queue_capacity: int | None = None

    def describe(self) -> str:
        slo = sum(1 for r in self.requests if r.deadline_s is not None)
        return (f"ServeScenario(seed={self.seed}): {self.n_replicas}x"
                f"{self.max_batch} slots, {len(self.requests)} requests "
                f"({slo} with SLO), {len(self.faults)} faults, "
                f"admission={self.admission}, autoscale={self.autoscale}")

    # ------------------------------------------------------------------ #
    @staticmethod
    def random(seed: int, *, max_requests: int = 32,
               fault_rate: float = 0.6, horizon: float = 60.0,
               vocab_size: int = 256,
               outage_rate: float = 0.0) -> "ServeScenario":
        """Sample a serving chaos scenario; every choice flows from the seed.

        At least one replica is never targeted by a *partial* fault, so a
        healthy floor always exists and "every admitted request reaches a
        terminal state" stays assertable.  With ``outage_rate`` > 0 a
        scenario may additionally script a **total replica outage**: every
        replica (floor included) killed in one window, then every one
        restored — the zero-live-slot regime the SLO admission policy must
        reject into rather than divide through.  The block draws nothing
        from the RNG at rate 0.0, so pre-existing seeds keep their traces
        byte for byte.
        """
        rng = random.Random(seed)
        n_replicas = rng.randint(2, 4)
        max_batch = rng.choice([2, 2, 4])
        step_s = rng.choice([0.01, 0.02, 0.02, 0.05])
        n_requests = rng.randint(8, max_requests)
        requests: list[ServeRequestSpec] = []
        t = 0.0
        for _ in range(n_requests):
            t += rng.uniform(0.0, 4 * step_s)
            prompt = tuple(rng.randrange(vocab_size)
                           for _ in range(rng.randint(2, 6)))
            deadline = None
            if rng.random() < 0.5:
                deadline = round(rng.uniform(0.2, 3.0), 6)
            requests.append(ServeRequestSpec(
                at=round(t, 6), prompt=prompt,
                max_new_tokens=rng.randint(3, 10),
                deadline_s=deadline))
        faults: list[ServeFault] = []
        # replica0 is the guaranteed-healthy floor: never targeted
        for i in range(1, n_replicas):
            if rng.random() >= fault_rate:
                continue
            name = f"replica{i}"
            at = round(rng.uniform(0.05, max(t, 0.1)), 6)
            faults.append(ServeFault(at=at, kind="kill", replica=name))
            if rng.random() < 0.5:
                faults.append(ServeFault(
                    at=round(at + rng.uniform(0.2, 2.0), 6),
                    kind="restore", replica=name))
        if outage_rate > 0.0 and rng.random() < outage_rate:
            # total outage window: correlated kill of the whole pool,
            # correlated restore — always healed so terminality holds
            ot = round(rng.uniform(0.05, max(t, 0.1)), 6)
            heal = round(ot + rng.uniform(0.3, 1.5), 6)
            for i in range(n_replicas):
                name = f"replica{i}"
                faults.append(ServeFault(at=ot, kind="kill", replica=name))
                faults.append(ServeFault(at=heal, kind="restore",
                                         replica=name))
        faults.sort(key=lambda f: (f.at, f.kind, f.replica))
        return ServeScenario(
            seed=seed, n_replicas=n_replicas, max_batch=max_batch,
            step_s=step_s, requests=requests, faults=faults,
            horizon=horizon,
            tick_period=rng.choice([0.1, 0.25]),
            admission=rng.random() < 0.7,
            autoscale=rng.random() < 0.4,
            scheduler=rng.choice([None, None, "least_loaded",
                                  "round_robin"]))


@dataclass
class ServeScenarioResult:
    seed: int
    scenario: ServeScenario
    report: object                  # repro.serve.ServeReport
    trace: str
    violations: list[str]

    @property
    def ok(self) -> bool:
        return not self.violations


def _check_invariants(scenario: ServeScenario, requests: list[ServeRequest],
                      report, monitor: MonitoringDatabase) -> list[str]:
    """Serving-plane invariants every scenario must satisfy."""
    v: list[str] = []
    # autoscaler cooldown contract: two *load-following* grows can never
    # land within the patience window (capacity repair is exempt — it
    # answers replica loss, not the gauge trend)
    grows = [e for e in monitor.system_events
             if e["event"] == "autoscale_grow"
             and e.get("reason") == "sustained backlog"]
    min_gap = 2 * scenario.tick_period        # autoscaler runs patience=2
    for a, b in zip(grows, grows[1:]):
        if b["time"] - a["time"] < min_gap - 1e-9:
            v.append(f"back-to-back autoscale grows at {a['time']:.3f}s "
                     f"and {b['time']:.3f}s (inside the "
                     f"{min_gap:.3f}s cooldown window)")
    total = (report.completed + report.failed + report.rejected
             + report.shed)
    if total != len(requests):
        v.append(f"request conservation: {total} terminal != "
                 f"{len(requests)} submitted")
    for r in requests:
        if not r.terminal:
            v.append(f"request {r.rid} left non-terminal ({r.status})")
        if r.status == "rejected" and r.generated:
            v.append(f"rejected request {r.rid} consumed decode steps")
        if r.status == "done" and len(r.generated) != r.max_new_tokens:
            v.append(f"done request {r.rid} has {len(r.generated)} tokens, "
                     f"wanted {r.max_new_tokens}")
    if report.rejected and not scenario.admission \
            and scenario.queue_capacity is None:
        v.append("rejections without admission control or a bounded queue")
    return v


def run_serve_scenario(scenario: ServeScenario) -> ServeScenarioResult:
    """Execute one serving scenario deterministically; returns the report,
    the canonical event trace, and any invariant violations."""
    from repro.serve.batcher import SimDecodeBackend

    clock = VirtualClock()
    monitor = MonitoringDatabase(clock=clock, keep_event_log=True)
    policy: list = [WrathPolicy()]
    if scenario.autoscale:
        policy.append(ReplicaAutoscaler(
            min_replicas=1, max_replicas=scenario.max_replicas,
            patience=2, idle_ticks=4))
    driver = WrathServeDriver(
        None, n_replicas=scenario.n_replicas,
        max_batch=scenario.max_batch,
        clock=clock, monitor=monitor,
        decode=SimDecodeBackend(step_s=scenario.step_s),
        policy=policy,
        admission=SLOAdmissionPolicy(default_step_s=scenario.step_s)
        if scenario.admission else None,
        queue_capacity=scenario.queue_capacity,
        scheduler=(make_scheduler(scenario.scheduler)
                   if scenario.scheduler else None))
    requests = [ServeRequest(rid=i, prompt=list(spec.prompt),
                             max_new_tokens=spec.max_new_tokens,
                             deadline_s=spec.deadline_s)
                for i, spec in enumerate(scenario.requests)]
    report = driver.serve_continuous(
        requests,
        arrivals=[spec.at for spec in scenario.requests],
        faults=[(f.at, f.kind, f.replica) for f in scenario.faults],
        horizon=scenario.horizon,
        tick_period=scenario.tick_period)
    driver.shutdown()
    return ServeScenarioResult(
        seed=scenario.seed, scenario=scenario, report=report,
        trace=build_trace(monitor),
        violations=_check_invariants(scenario, requests, report, monitor))


def serve_campaign(n_scenarios: int, *, base_seed: int = 0,
                   check_determinism: bool = False,
                   scenario_kwargs: dict | None = None,
                   ) -> list[ServeScenarioResult]:
    """Run ``n_scenarios`` seeded serving scenarios; with
    ``check_determinism`` each scenario runs twice and a trace mismatch is
    recorded as a violation.  ``scenario_kwargs`` forwards to
    :meth:`ServeScenario.random` (e.g. ``outage_rate=0.3`` to mix in
    total-outage windows)."""
    results = []
    kw = scenario_kwargs or {}
    for i in range(n_scenarios):
        scenario = ServeScenario.random(base_seed + i, **kw)
        res = run_serve_scenario(scenario)
        if check_determinism:
            again = run_serve_scenario(
                ServeScenario.random(base_seed + i, **kw))
            if again.trace != res.trace:
                res.violations.append("trace not deterministic across runs")
        results.append(res)
    return results
