"""Flash attention Pallas TPU kernel (blockwise online-softmax).

TPU-native adaptation of the flash-attention idea (DESIGN.md §6): the
(Sq × Sk) score matrix never leaves VMEM.  Grid = (batch·heads, q_blocks,
kv_blocks); the kv dimension is the innermost sequential ("arbitrary")
axis, with running max / normalizer / accumulator kept in VMEM scratch
across kv steps.  Block shapes are MXU-aligned: q/kv tiles are multiples
of 128 rows and the head dim rides the 128-lane axis; softmax statistics
are stored lane-replicated (qb, 128) for layout friendliness.

Supports causal and sliding-window masking.  Numerics: scores and the
accumulator are fp32 regardless of input dtype (matching the pure-jnp
reference to ~1e-2 in bf16, ~1e-5 in fp32).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            qb: int, kb: int, hd: int, causal: bool, window: int,
            nk: int, scale: float, kv_valid: int):
    i = pl.program_id(1)          # q block
    j = pl.program_id(2)          # kv block (sequential)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)                  # (qb, hd)
    k = k_ref[0].astype(jnp.float32)                  # (kb, hd)
    v = v_ref[0].astype(jnp.float32)                  # (kb, hd)
    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale    # (qb, kb)

    q_pos = i * qb + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 0)
    k_pos = j * kb + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 1)
    mask = jnp.ones((qb, kb), jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= k_pos > q_pos - window
    if kv_valid:
        # kv padded up to a block multiple: positions past the true length
        # contribute nothing (padded *q* rows need no mask — their output
        # is sliced off, and the online-softmax rescale keeps them finite)
        mask &= k_pos < kv_valid
    scores = jnp.where(mask, scores, NEG_INF)

    m_prev = m_ref[:, 0][:, None]                      # (qb, 1)
    m_new = jnp.maximum(m_prev, scores.max(axis=1, keepdims=True))
    p = jnp.exp(scores - m_new)                        # (qb, kb)
    alpha = jnp.exp(m_prev - m_new)                    # (qb, 1)
    l_new = alpha * l_ref[:, 0][:, None] + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(j == nk - 1)
    def _finalize():
        l = l_ref[:, 0][:, None]
        o_ref[0] = (acc_ref[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_block", "kv_block", "interpret"))
def flash_attention_bh(q: jax.Array, k: jax.Array, v: jax.Array, *,
                       causal: bool = True, window: int = 0,
                       q_block: int = 128, kv_block: int = 128,
                       interpret: bool = False) -> jax.Array:
    """q, k, v: (BH, S, D) with equal head counts (GQA expanded by caller).

    Sequence lengths need not divide the block sizes: q/kv are zero-padded
    up to the next block multiple (the kernel masks padded kv positions;
    padded q rows are sliced off the output), so autotuned blocks work for
    arbitrary lengths.
    """
    bh, s, hd = q.shape
    sk = k.shape[1]
    qb = min(q_block, s)
    kb = min(kv_block, sk)
    s_pad = -(-s // qb) * qb
    sk_pad = -(-sk // kb) * kb
    if s_pad != s:
        q = jnp.pad(q, ((0, 0), (0, s_pad - s), (0, 0)))
    if sk_pad != sk:
        k = jnp.pad(k, ((0, 0), (0, sk_pad - sk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, sk_pad - sk), (0, 0)))
    nq, nk = s_pad // qb, sk_pad // kb
    scale = 1.0 / math.sqrt(hd)

    kernel = functools.partial(
        _kernel, qb=qb, kb=kb, hd=hd, causal=causal, window=window,
        nk=nk, scale=scale, kv_valid=sk if sk_pad != sk else 0)
    out = pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, qb, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, kb, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, kb, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, qb, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s_pad, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((qb, hd), jnp.float32),
            pltpu.VMEM((qb, 128), jnp.float32),
            pltpu.VMEM((qb, 128), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out[:, :s] if s_pad != s else out
