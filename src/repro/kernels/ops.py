"""Public jit'd wrappers around the Pallas kernels.

``flash_attention`` takes model-layout tensors (B, S, H, D) with GQA
(kv heads ≤ q heads) and handles head expansion + folding; ``ssd_scan``
matches the signature of the pure-JAX ``repro.models.ssm.ssd_scan``.

On this CPU container the kernels run with ``interpret=True`` (Pallas
executes the kernel body in Python); on TPU pass ``interpret=False``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_bh
from repro.kernels.ssd_scan import ssd_scan_kernel


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    q_block: int = 128, kv_block: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: (B, S, H, D); k, v: (B, S, KV, D) -> (B, S, H, D)."""
    b, s, h, d = q.shape
    kvh = k.shape[2]
    if h != kvh:
        rep = h // kvh
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, -1, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, -1, d)
    of = flash_attention_bh(qf, kf, vf, causal=causal, window=window,
                            q_block=q_block, kv_block=kv_block,
                            interpret=interpret)
    return of.reshape(b, h, s, d).transpose(0, 2, 1, 3)


def ssd_scan(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
             c: jax.Array, *, chunk: int = 128,
             interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """Grouped (G=1) SSD scan; see ssd_scan_kernel for shapes."""
    if b.ndim == 4:                         # (B, L, G, N) with G == 1
        b = b[:, :, 0]
        c = c[:, :, 0]
    return ssd_scan_kernel(x, dt, a, b, c, chunk=chunk, interpret=interpret)
