"""Public jit'd wrappers around the Pallas kernels.

``flash_attention`` takes model-layout tensors (B, S, H, D) with GQA
(kv heads ≤ q heads) and handles head expansion + folding; ``ssd_scan``
matches the signature of the pure-JAX ``repro.models.ssm.ssd_scan``.

Block sizes are optional: when the caller omits them, the persistent
autotune cache (``repro.kernels.autotune``) is consulted for this device
signature and input shape — a hit uses the measured winner, a miss falls
back to the 128-block defaults (or sweeps on the spot under
``REPRO_AUTOTUNE=1``).  Sequence lengths that do not divide the blocks
are handled by the kernels' pad-and-mask path.

On this CPU container the kernels run with ``interpret=True`` (Pallas
executes the kernel body in Python); on TPU pass ``interpret=False``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.autotune import tuned_flash_blocks, tuned_ssd_chunk
from repro.kernels.flash_attention import flash_attention_bh
from repro.kernels.ssd_scan import ssd_scan_kernel


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    q_block: int | None = None, kv_block: int | None = None,
                    interpret: bool = False) -> jax.Array:
    """q: (B, S, H, D); k, v: (B, S, KV, D) -> (B, S, H, D)."""
    b, s, h, d = q.shape
    kvh = k.shape[2]
    if h != kvh:
        rep = h // kvh
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, -1, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, -1, d)
    if q_block is None or kv_block is None:
        tuned = tuned_flash_blocks(qf, kf, causal=causal, window=window,
                                   interpret=interpret)
        q_block = q_block or tuned["q_block"]
        kv_block = kv_block or tuned["kv_block"]
    of = flash_attention_bh(qf, kf, vf, causal=causal, window=window,
                            q_block=q_block, kv_block=kv_block,
                            interpret=interpret)
    return of.reshape(b, h, s, d).transpose(0, 2, 1, 3)


def ssd_scan(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
             c: jax.Array, *, chunk: int | None = None,
             interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """Grouped (G=1) SSD scan; see ssd_scan_kernel for shapes."""
    if b.ndim == 4:                         # (B, L, G, N) with G == 1
        b = b[:, :, 0]
        c = c[:, :, 0]
    if chunk is None:
        chunk = tuned_ssd_chunk(x, b, interpret=interpret)
    return ssd_scan_kernel(x, dt, a, b, c, chunk=chunk, interpret=interpret)
