"""Mamba-2 SSD chunked-scan Pallas TPU kernel.

TPU adaptation of the SSD algorithm (DESIGN.md §6): grid = (batch, heads,
chunks) with the chunk axis sequential; the running state (P × N) lives in
VMEM scratch across chunk steps.  Per chunk (Q = chunk length, MXU-aligned
128 by default):

    da       = dt ⊙ A                     (Q,)
    L        = exp(segsum(da))            (Q, Q) lower-triangular decay
    y_diag   = ((C Bᵀ) ⊙ L) (x ⊙ dt)      intra-chunk, two MXU matmuls
    y_off    = exp(cumsum(da)) ⊙ (C · state)        carried-state term
    state    = exp(sum(da)) · state + (B ⊙ decay)ᵀ (x ⊙ dt)

All accumulation in fp32.  G=1 (single B/C group), the configuration used
by mamba2-780m.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_out_ref,
            state_ref, *, q: int, p: int, n: int, nc: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)           # (Q,)
    a = a_ref[0].astype(jnp.float32)                   # ()
    b = b_ref[0].astype(jnp.float32)                   # (Q, N)
    c = c_ref[0].astype(jnp.float32)                   # (Q, N)

    da = dt * a                                        # (Q,)
    da_cs = jnp.cumsum(da)                             # (Q,)
    # segsum: L[i, j] = exp(sum(da[j+1..i])) for i >= j
    diff = da_cs[:, None] - da_cs[None, :] + jnp.diag(da) * 0.0
    row = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    lmask = row >= col
    l_decay = jnp.where(lmask, jnp.exp(diff), 0.0)     # (Q, Q)

    xdt = x * dt[:, None]                              # (Q, P)
    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    y = jax.lax.dot_general(cb * l_decay, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (Q, P)

    # carried-state contribution: exp(cumsum) ⊙ (C @ stateᵀ)
    state = state_ref[...]                             # (P, N)
    y += jnp.exp(da_cs)[:, None] * jax.lax.dot_general(
        c, state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)            # (Q, P)

    # state update
    total = da_cs[-1]
    decay_in = jnp.exp(total - da_cs)                  # (Q,)
    contrib = jax.lax.dot_general(
        xdt, b * decay_in[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)            # (P, N)
    state_ref[...] = state * jnp.exp(total) + contrib

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _final():
        state_out_ref[0, 0] = state_ref[...].astype(state_out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_kernel(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
                    c: jax.Array, *, chunk: int = 128,
                    interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """x: (B, L, H, P); dt: (B, L, H); a: (H,); b, c: (B, L, N).

    Returns (y (B, L, H, P), final_state (B, H, P, N)).

    L need not divide the chunk size: inputs are zero-padded up to the
    next chunk multiple.  Padded steps have dt = 0, so da = 0 — they decay
    the carried state by exp(0) = 1 and contribute x·dt = 0, i.e. they are
    exact identities on the recurrence; padded y rows are sliced off.
    """
    bb, l, h, p = x.shape
    n = b.shape[-1]
    q = min(chunk, l)
    l_pad = -(-l // q) * q
    if l_pad != l:
        x = jnp.pad(x, ((0, 0), (0, l_pad - l), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, l_pad - l), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, l_pad - l), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, l_pad - l), (0, 0)))
    nc = l_pad // q

    kernel = functools.partial(_kernel, q=q, p=p, n=n, nc=nc)
    y, state = pl.pallas_call(
        kernel,
        grid=(bb, h, nc),
        in_specs=[
            pl.BlockSpec((1, q, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, q, 1), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, q, n), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, q, n), lambda bi, hi, ci: (bi, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, q, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bb, l_pad, h, p), x.dtype),
            jax.ShapeDtypeStruct((bb, h, p, n), x.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, a, b, c)
    return (y[:, :l] if l_pad != l else y), state
