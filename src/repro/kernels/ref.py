"""Pure-jnp oracles for the Pallas kernels."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0) -> jax.Array:
    """q, k, v: (BH, S, D) — dense softmax attention in fp32."""
    bh, s, d = q.shape
    sk = k.shape[1]
    scores = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(d)
    q_pos = jnp.arange(s)[:, None]
    k_pos = jnp.arange(sk)[None, :]
    mask = jnp.ones((s, sk), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= k_pos > q_pos - window
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def ssd_ref(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
            c: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Naive per-step SSD recurrence (fp32).

    x: (B, L, H, P); dt: (B, L, H); a: (H,); b, c: (B, L, N)  [G=1].
    Returns (y (B, L, H, P), final_state (B, H, P, N)).
    """
    bb, l, h, p = x.shape
    n = b.shape[-1]

    def step(state, inp):
        xt, dtt, bt, ct = inp                          # (B,H,P),(B,H),(B,N),(B,N)
        decay = jnp.exp(dtt * a[None, :])              # (B,H)
        upd = jnp.einsum("bhp,bn,bh->bhpn", xt, bt, dtt)
        state = state * decay[..., None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", state, ct)
        return state, y

    s0 = jnp.zeros((bb, h, p, n), jnp.float32)
    xs = (x.astype(jnp.float32).transpose(1, 0, 2, 3),
          dt.astype(jnp.float32).transpose(1, 0, 2),
          b.astype(jnp.float32).transpose(1, 0, 2),
          c.astype(jnp.float32).transpose(1, 0, 2))
    final, ys = jax.lax.scan(step, s0, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), final.astype(x.dtype)
