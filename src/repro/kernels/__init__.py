"""Pallas TPU kernels for the substrate's compute hot spots.

The WRATH paper itself has no kernel-level contribution (it is a
control-plane resilience system); these kernels cover the two dominant
compute hot spots of the model substrate per the hardware-adaptation
directive: blockwise flash attention (8/10 archs) and the Mamba-2 SSD
chunked scan (ssm/hybrid archs).  Validated in interpret mode against the
pure-jnp oracles in ``ref.py``.

Block sizes are autotuned per input shape and persisted per device
signature (``repro.kernels.autotune``); callers that omit explicit
blocks get the cached winner transparently.
"""
from repro.kernels.autotune import (
    AutotuneCache,
    autotune_flash_attention,
    autotune_ssd_scan,
    device_signature,
)
from repro.kernels.ops import flash_attention, ssd_scan

__all__ = ["flash_attention", "ssd_scan", "AutotuneCache",
           "autotune_flash_attention", "autotune_ssd_scan",
           "device_signature"]
