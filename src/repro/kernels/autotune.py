"""Block-size autotuning for the Pallas kernels, with a persistent cache.

The kernels ship MXU-friendly 128-block defaults, but the best block
shape depends on the input shape, dtype and the device generation —
and the right answer does not change between runs on the same hardware.
This module closes that loop the same way the proactive sentinel's
feasibility-verdict cache does for placement decisions (PR 2): measure
once, key the verdict by a *device signature*, and consult the cache
transparently on every subsequent call.

* :func:`device_signature` — ``platform:device_kind:core_count`` (the
  sentinel's cluster-signature idiom, applied to the hardware layer).  A
  cache written on one device kind is **ignored** on another: winners are
  measurements, not portable facts.
* :class:`AutotuneCache` — one JSON file per device signature under
  ``$REPRO_AUTOTUNE_CACHE`` (default ``~/.cache/repro_autotune``).
  Writes are atomic (tmp + ``os.replace``); a corrupt or foreign-device
  file is ignored at open and overwritten on the next flush.
* :func:`autotune_flash_attention` / :func:`autotune_ssd_scan` — sweep
  candidate block shapes on the *real* kernel + arrays, best-of-``repeats``
  wall time, persist the winner.
* :func:`tuned_flash_blocks` / :func:`tuned_ssd_chunk` — the transparent
  consultation path: ``repro.kernels.flash_attention(...)`` with blocks
  omitted resolves them here (cache hit → tuned blocks, miss → the 128
  defaults; set ``REPRO_AUTOTUNE=1`` to tune on miss instead of
  defaulting).

The pad-and-mask kernel wrappers accept any sequence length, so the
sweep is free to propose blocks that do not divide the input — padding
waste is simply part of what the timing measures.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import threading
import time
from pathlib import Path
from typing import Any, Callable, Iterable, Sequence

__all__ = [
    "AutotuneCache", "TuneResult", "device_signature", "default_cache",
    "autotune_flash_attention", "autotune_ssd_scan",
    "tuned_flash_blocks", "tuned_ssd_chunk",
    "flash_block_candidates", "ssd_chunk_candidates",
]

_ENV_CACHE_DIR = "REPRO_AUTOTUNE_CACHE"
_ENV_AUTOTUNE = "REPRO_AUTOTUNE"
_DEFAULT_DIR = "~/.cache/repro_autotune"

#: the hard-coded defaults the autotuner has to beat
DEFAULT_FLASH_BLOCKS = {"q_block": 128, "kv_block": 128}
DEFAULT_SSD_CHUNK = 128


def device_signature() -> str:
    """``platform:device_kind:core_count`` for the default jax backend."""
    import jax

    dev = jax.devices()[0]
    kind = getattr(dev, "device_kind", "") or "unknown"
    return f"{dev.platform}:{kind}:{jax.device_count()}"


# --------------------------------------------------------------------------
# persistent cache
# --------------------------------------------------------------------------
@dataclasses.dataclass
class TuneResult:
    """One sweep's verdict: the winning blocks and the evidence."""
    blocks: dict[str, int]
    us: float                      # best-of-N for the winner
    default_us: float              # same measurement for the 128 defaults
    sweep: list[dict[str, Any]]    # every candidate: {blocks, us}

    @property
    def speedup(self) -> float:
        return self.default_us / self.us if self.us else 0.0


class AutotuneCache:
    """On-disk map ``(kernel, shape-key) -> winning blocks``, scoped to one
    device signature.

    The file layout is one JSON per signature (filename = short sha of the
    signature) holding ``{"device_signature": ..., "entries": {...}}``.
    ``load`` ignores files whose recorded signature differs from the
    current one — e.g. a cache directory copied over from a TPU host is
    never consulted on a CPU container — and ignores unparseable files
    (a crash mid-write before the atomic rename cannot produce one, but a
    truncated copy can).
    """

    def __init__(self, directory: str | os.PathLike | None = None, *,
                 signature: str | None = None):
        if directory is None:
            directory = os.environ.get(_ENV_CACHE_DIR, _DEFAULT_DIR)
        self.directory = Path(directory).expanduser()
        self.signature = signature or device_signature()
        digest = hashlib.sha256(self.signature.encode()).hexdigest()[:16]
        self.path = self.directory / f"autotune-{digest}.json"
        self._lock = threading.Lock()
        self._entries: dict[str, dict[str, Any]] = self._load()

    def _load(self) -> dict[str, dict[str, Any]]:
        try:
            data = json.loads(self.path.read_text())
        except (OSError, ValueError):
            return {}
        if not isinstance(data, dict):
            return {}
        if data.get("device_signature") != self.signature:
            # foreign-device cache at our path (hash collision or a copied
            # directory): measurements from other hardware are not verdicts
            return {}
        entries = data.get("entries")
        if not isinstance(entries, dict):
            return {}
        # drop individually corrupt entries instead of trusting them
        good = {}
        for key, ent in entries.items():
            if (isinstance(ent, dict) and isinstance(ent.get("blocks"), dict)
                    and all(isinstance(v, int)
                            for v in ent["blocks"].values())):
                good[key] = ent
        return good

    def __len__(self) -> int:
        return len(self._entries)

    def lookup(self, kernel: str, key: str) -> dict[str, int] | None:
        """Winning blocks for ``key``, or None on miss."""
        ent = self._entries.get(f"{kernel}|{key}")
        return dict(ent["blocks"]) if ent else None

    def store(self, kernel: str, key: str, result: TuneResult) -> None:
        with self._lock:
            self._entries[f"{kernel}|{key}"] = {
                "blocks": dict(result.blocks),
                "us": round(result.us, 2),
                "default_us": round(result.default_us, 2),
                "speedup": round(result.speedup, 3),
                "tuned_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
            }
            self._flush_locked()

    def _flush_locked(self) -> None:
        self.directory.mkdir(parents=True, exist_ok=True)
        payload = json.dumps(
            {"device_signature": self.signature, "entries": self._entries},
            indent=1, sort_keys=True)
        tmp = self.path.with_name(self.path.name + f".tmp-{os.getpid()}")
        tmp.write_text(payload)
        os.replace(tmp, self.path)


_default_cache: AutotuneCache | None = None
_default_cache_lock = threading.Lock()


def default_cache() -> AutotuneCache:
    """Process-wide cache instance (re-created if the env dir changes —
    tests repoint ``REPRO_AUTOTUNE_CACHE`` at tmp directories)."""
    global _default_cache
    want = Path(os.environ.get(_ENV_CACHE_DIR, _DEFAULT_DIR)).expanduser()
    with _default_cache_lock:
        if _default_cache is None or _default_cache.directory != want:
            _default_cache = AutotuneCache(want)
        return _default_cache


# --------------------------------------------------------------------------
# shape keys and candidate grids
# --------------------------------------------------------------------------
def _dtype_name(x: Any) -> str:
    return str(getattr(x, "dtype", x))


def flash_key(bh: int, s: int, sk: int, hd: int, dtype: Any, *,
              causal: bool, window: int) -> str:
    return f"bh{bh}_s{s}_sk{sk}_d{hd}_{_dtype_name(dtype)}_c{int(causal)}_w{window}"


def ssd_key(bb: int, l: int, h: int, p: int, n: int, dtype: Any) -> str:
    return f"b{bb}_l{l}_h{h}_p{p}_n{n}_{_dtype_name(dtype)}"


def _pow2_upto(n: int, lo: int = 32, hi: int = 512) -> list[int]:
    out = [c for c in (32, 64, 128, 256, 512) if lo <= c <= min(n, hi)]
    if n <= hi and n not in out:
        out.append(n)            # the exact length: zero padding waste
    return sorted(out) or [n]


def flash_block_candidates(s: int, sk: int) -> list[tuple[int, int]]:
    """(q_block, kv_block) grid: powers of two plus the exact lengths,
    capped so a score tile stays comfortably inside VMEM."""
    pairs = [(qb, kb)
             for qb in _pow2_upto(s) for kb in _pow2_upto(sk)
             if qb * kb <= 256 * 256]
    return pairs


def ssd_chunk_candidates(l: int) -> list[int]:
    return _pow2_upto(l)


# --------------------------------------------------------------------------
# measurement
# --------------------------------------------------------------------------
def _time_us(fn: Callable[[], Any], repeats: int) -> float:
    """Best-of-``repeats`` wall time in µs (first call outside the timing
    loop warms the jit cache for this block config)."""
    import jax

    jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _sweep(run: Callable[[dict[str, int]], Any],
           candidates: Iterable[dict[str, int]],
           default_blocks: dict[str, int], repeats: int) -> TuneResult:
    sweep: list[dict[str, Any]] = []
    best_blocks, best_us, default_us = dict(default_blocks), float("inf"), 0.0
    for blocks in candidates:
        us = _time_us(lambda: run(blocks), repeats)
        sweep.append({"blocks": dict(blocks), "us": round(us, 2)})
        if blocks == default_blocks:
            default_us = us
        if us < best_us:
            best_blocks, best_us = dict(blocks), us
    if not default_us:                      # defaults not in the grid
        default_us = _time_us(lambda: run(default_blocks), repeats)
    return TuneResult(blocks=best_blocks, us=best_us,
                      default_us=default_us, sweep=sweep)


def autotune_flash_attention(q: Any, k: Any, v: Any, *, causal: bool = True,
                             window: int = 0, interpret: bool = False,
                             cache: AutotuneCache | None = None,
                             candidates: Sequence[tuple[int, int]] | None = None,
                             repeats: int = 3) -> TuneResult:
    """Sweep (q_block, kv_block) on (BH, S, D) arrays; persist the winner."""
    from repro.kernels.flash_attention import flash_attention_bh

    bh, s, hd = q.shape
    sk = k.shape[1]
    pairs = candidates or flash_block_candidates(s, sk)

    def run(blocks: dict[str, int]):
        return flash_attention_bh(q, k, v, causal=causal, window=window,
                                  q_block=blocks["q_block"],
                                  kv_block=blocks["kv_block"],
                                  interpret=interpret)

    result = _sweep(run, [{"q_block": qb, "kv_block": kb} for qb, kb in pairs],
                    DEFAULT_FLASH_BLOCKS, repeats)
    cache = cache or default_cache()
    cache.store("flash_attention",
                flash_key(bh, s, sk, hd, q.dtype, causal=causal, window=window),
                result)
    return result


def autotune_ssd_scan(x: Any, dt: Any, a: Any, b: Any, c: Any, *,
                      interpret: bool = False,
                      cache: AutotuneCache | None = None,
                      candidates: Sequence[int] | None = None,
                      repeats: int = 3) -> TuneResult:
    """Sweep the SSD chunk length on model-layout arrays; persist the winner."""
    from repro.kernels.ssd_scan import ssd_scan_kernel

    bb, l, h, p = x.shape
    n = b.shape[-1]
    chunks = candidates or ssd_chunk_candidates(l)

    def run(blocks: dict[str, int]):
        return ssd_scan_kernel(x, dt, a, b, c, chunk=blocks["chunk"],
                               interpret=interpret)

    result = _sweep(run, [{"chunk": ch} for ch in chunks],
                    {"chunk": DEFAULT_SSD_CHUNK}, repeats)
    cache = cache or default_cache()
    cache.store("ssd_scan", ssd_key(bb, l, h, p, n, x.dtype), result)
    return result


# --------------------------------------------------------------------------
# transparent consultation (the ops.py entry points call these when the
# caller omits explicit blocks)
# --------------------------------------------------------------------------
def _tune_on_miss() -> bool:
    return os.environ.get(_ENV_AUTOTUNE, "") == "1"


def tuned_flash_blocks(q: Any, k: Any, *, causal: bool, window: int,
                       interpret: bool = False) -> dict[str, int]:
    """Blocks for a (BH, S, D) flash call: cache hit → winner; miss → the
    128 defaults (or a fresh sweep when ``REPRO_AUTOTUNE=1``)."""
    bh, s, hd = q.shape
    sk = k.shape[1]
    cache = default_cache()
    key = flash_key(bh, s, sk, hd, q.dtype, causal=causal, window=window)
    hit = cache.lookup("flash_attention", key)
    if hit is not None:
        return hit
    if _tune_on_miss():
        import jax.numpy as jnp

        v = jnp.zeros_like(k)
        return autotune_flash_attention(
            q, k, v, causal=causal, window=window, interpret=interpret,
            cache=cache).blocks
    return dict(DEFAULT_FLASH_BLOCKS)


def tuned_ssd_chunk(x: Any, b: Any, *, interpret: bool = False) -> int:
    """Chunk length for a model-layout SSD call (same contract as
    :func:`tuned_flash_blocks`)."""
    bb, l, h, p = x.shape
    n = b.shape[-1]
    cache = default_cache()
    key = ssd_key(bb, l, h, p, n, x.dtype)
    hit = cache.lookup("ssd_scan", key)
    if hit is not None:
        return hit["chunk"]
    if _tune_on_miss():
        import jax.numpy as jnp

        dt = jnp.full((bb, l, h), 0.5, x.dtype)
        a = jnp.full((h,), -0.5, x.dtype)
        c = jnp.zeros_like(b)
        return autotune_ssd_scan(x, dt, a, b, c, interpret=interpret,
                                 cache=cache).blocks["chunk"]
    return DEFAULT_SSD_CHUNK
