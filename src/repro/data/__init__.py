from repro.data.pipeline import SyntheticTokens, batch_for

__all__ = ["SyntheticTokens", "batch_for"]
