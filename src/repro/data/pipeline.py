"""Deterministic synthetic token pipeline.

``batch_at(step)`` is a pure function of (seed, step): after a WRATH
checkpoint/restart the data order resumes exactly — restart-deterministic
data is a fault-tolerance feature, not a convenience (DESIGN.md §2).

The token stream is a learnable Markov-ish process: next-token depends on
the current token through a fixed random permutation + noise, so small
models actually reduce loss (used by the resilient-training example to
verify recovery does not corrupt optimization).
"""
from __future__ import annotations

import numpy as np

from repro.models.config import ModelConfig


class SyntheticTokens:
    def __init__(self, vocab_size: int, batch: int, seq_len: int, *,
                 seed: int = 0, noise: float = 0.1):
        self.vocab = vocab_size
        self.batch = batch
        self.seq = seq_len
        self.seed = seed
        self.noise = noise
        rng = np.random.default_rng(seed)
        self.perm = rng.permutation(vocab_size)

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed << 20) ^ step)
        toks = np.empty((self.batch, self.seq + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, size=self.batch)
        noise_mask = rng.random((self.batch, self.seq)) < self.noise
        noise_tok = rng.integers(0, self.vocab, size=(self.batch, self.seq))
        for t in range(self.seq):
            nxt = self.perm[toks[:, t]]
            toks[:, t + 1] = np.where(noise_mask[:, t], noise_tok[:, t], nxt)
        return {"inputs": toks[:, :-1], "targets": toks[:, 1:]}


def batch_for(cfg: ModelConfig, batch: int, seq_len: int, step: int, *,
              seed: int = 0) -> dict[str, np.ndarray]:
    """Arch-aware batch (token models get tokens; embed models get frames)."""
    out: dict[str, np.ndarray] = {}
    rng = np.random.default_rng((seed << 20) ^ step)
    if cfg.encoder_layers:
        out["enc_embeds"] = rng.standard_normal(
            (batch, seq_len, cfg.d_model)).astype(np.float32) * 0.02
    if cfg.input_kind == "embeds" and not cfg.encoder_layers:
        out["embeds"] = rng.standard_normal(
            (batch, seq_len, cfg.d_model)).astype(np.float32) * 0.02
        out["targets"] = rng.integers(
            0, cfg.vocab_size, size=(batch, seq_len)).astype(np.int32)
        return out
    pipe = SyntheticTokens(cfg.vocab_size, batch, seq_len, seed=seed)
    out.update(pipe.batch_at(step))
    return out
