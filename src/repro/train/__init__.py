from repro.train.supervisor import (
    TrainEvent,
    TrainReport,
    WrathTrainSupervisor,
)

__all__ = ["WrathTrainSupervisor", "TrainEvent", "TrainReport"]
