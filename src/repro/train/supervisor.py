"""WRATH-supervised training loop (the paper's technique on the training
plane — DESIGN.md §2).

Training is executed as a task hierarchy: each step fans out per-host
*gradient-shard tasks* over a set of virtual hosts (an
``repro.engine.cluster.Cluster`` pool, so heterogeneous memory/health/speed
and the WRATH machinery come for free).  Failures raised while computing a
shard flow through the SAME composable :class:`~repro.engine.policies.
PolicyStack` as the task plane (``policy=`` kwarg, WRATH by default; like
the serving plane, the supervisor drives the *decision* subset of the
protocol — ``on_submit``/``on_failure``/``review_decision`` — while
engine-execution policies such as ``replicate`` are task-plane only):

* host loss (``HardwareShutdownError``)  → denylist + hierarchical retry
  of the lost shard on another host; subsequent steps re-mesh elastically
  (the global batch is re-split over the surviving hosts);
* resource starvation (shard too big for the host) → feasibility-aware
  placement onto a big-memory host (retry ladder rung 1/4);
* NaN/Inf loss (``NumericalDivergenceError``, application layer) →
  restore the last committed checkpoint and continue with a perturbed
  data order (retriable-in-place, like the paper's Random Seed Errors);
* stragglers → speculative re-execution of the slow shard on the fastest
  healthy host (history-informed placement, §V-B rung 3).

All recovery decisions are recorded; ``TrainReport`` summarizes recovery
counts, checkpoint restores, and the loss trace (tests assert the loss
still goes down through failures).
"""
from __future__ import annotations

import dataclasses
import time
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core import MonitoringDatabase
from repro.core.failures import (
    FailureReport,
    HardwareShutdownError,
    NumericalDivergenceError,
)
from repro.data import batch_for
from repro.engine.cluster import Cluster, Node, ResourcePool
from repro.engine.policies import PolicyStack, WrathPolicy, normalize_policies
from repro.engine.retry_api import Action, SchedulingContext
from repro.engine.scheduler import Scheduler
from repro.engine.task import ResourceSpec, TaskDef, new_task_record
from repro.models import loss_fn, materialize, param_defs
from repro.models.config import ModelConfig
from repro.optim import OptConfig, adamw_apply, init_opt_state


@dataclasses.dataclass
class TrainEvent:
    """Injected failure for a given step (training-plane fail engine)."""

    step: int
    kind: str    # host_down | host_up | nan | straggler | host_join | host_leave
    host: str | None = None
    factor: float = 5.0        # straggler slowdown
    memory_gb: float = 192.0   # joining host's capacity (host_join)


@dataclasses.dataclass
class TrainReport:
    steps_completed: int
    losses: list[float]
    recoveries: list[dict]
    restores: int
    denylisted: list[str]
    speculations: int
    final_hosts: int

    @property
    def recovered_all(self) -> bool:
        return all(r["action"] != "fail" for r in self.recoveries)


class WrathTrainSupervisor:
    def __init__(
        self,
        cfg: ModelConfig,
        opt_cfg: OptConfig,
        *,
        n_hosts: int = 4,
        big_host: bool = True,
        host_memory_gb: float = 16.0,
        global_batch: int = 8,
        seq_len: int = 64,
        ckpt_dir: str = "/tmp/wrath_ckpt",
        ckpt_every: int = 10,
        shard_memory_gb: float = 1.0,
        data_seed: int = 0,
        straggler_factor: float = 3.0,
        scheduler: Scheduler | None = None,
        policy: object = None,
        profile_shard_sizing: bool = True,
    ):
        self.cfg = cfg
        self.opt_cfg = opt_cfg
        self.global_batch = global_batch
        self.seq_len = seq_len
        self.data_seed = data_seed
        self.shard_memory_gb = shard_memory_gb
        self.straggler_factor = straggler_factor
        self.profile_shard_sizing = profile_shard_sizing

        nodes = [Node(f"host{i:02d}", memory_gb=host_memory_gb,
                      workers_per_node=1) for i in range(n_hosts)]
        if big_host:
            nodes.append(Node("bighost", memory_gb=host_memory_gb * 32,
                              workers_per_node=1))
        self.cluster = Cluster([ResourcePool("pod0", nodes)])
        self.monitor = MonitoringDatabase()
        # composable resilience stack (task-hierarchy API): shard-failure
        # decisions flow through the same middleware protocol as the task
        # plane — first decisive decision wins.  policy=None -> WRATH
        # default; an explicit [] means Parsl-style baseline retry only
        self.policies = PolicyStack(
            normalize_policies(policy) if policy is not None
            else (WrathPolicy(),),
            on_error=self._policy_error)
        # optional placement policy: when set, shard->host assignment and
        # speculation targets go through the scheduler interface (None
        # keeps the legacy fixed-order assignment + EMA-fastest targets)
        self.scheduler = scheduler.bind(cluster=self.cluster,
                                        monitor=self.monitor) \
            if scheduler is not None else None
        self.denylist: set[str] = set()
        self.ckpt = CheckpointManager(ckpt_dir, keep=2, async_save=False)
        self.ckpt_every = ckpt_every

        self._grad_fn = jax.jit(
            jax.value_and_grad(
                lambda p, b: loss_fn(p, b, cfg, remat=False)[0]))
        self._host_times: dict[str, float] = {}
        self._slow_counts: dict[str, int] = {}

    # ------------------------------------------------------------------ #
    def _policy_error(self, hook: str, err: BaseException) -> None:
        """Swallowed policy-hook exceptions stay visible as system events."""
        self.monitor.record_system_event(
            "policy_error", event=hook, error=type(err).__name__,
            message=str(err))

    def _ctx(self) -> SchedulingContext:
        return SchedulingContext(cluster=self.cluster, monitor=self.monitor,
                                 denylist=self.denylist, default_pool="pod0",
                                 scheduler=self.scheduler)

    def healthy_hosts(self) -> list[Node]:
        return [n for n in self.cluster.pools["pod0"].nodes
                if n.healthy and n.name not in self.denylist
                and n.name != "bighost"]

    def _order_hosts(self, hosts: list[Node]) -> list[Node]:
        """Shard->host assignment order for one step.

        With a scheduler bound, hosts are drained through repeated
        ``select`` calls — ``np.array_split`` hands earlier hosts the
        larger shards, so e.g. a history-aware scheduler steers the bigger
        sub-batches onto historically fast hosts.  Without one, pool order
        is kept (legacy behaviour).
        """
        if self.scheduler is None or len(hosts) <= 1:
            return hosts
        probe = new_task_record(
            TaskDef(lambda: None, "grad_shard",
                    ResourceSpec(memory_gb=self.shard_memory_gb), 0),
            (), {}, default_retries=0)
        pool = self.cluster.pools["pod0"]
        remaining, ordered = list(hosts), []
        while remaining:
            pick = self.scheduler.select(probe, remaining, pool=pool)
            pick = pick if pick is not None else remaining[0]
            ordered.append(pick)
            remaining.remove(pick)
        return ordered

    def _shard_sizes(self, hosts: list[Node]) -> list[int]:
        """Per-host shard sizes for one step.

        With ``profile_shard_sizing`` the monitoring database's streaming
        duration profiles size each host's sub-batch proportionally to its
        observed throughput (1 / mean shard duration): fast hosts get more
        samples, chronic stragglers get fewer — but every host keeps at
        least one sample so its profile stays fresh and the chronic-
        straggler machinery still observes it.  Hosts without enough
        history (< 3 shards) get the mean observed rate.  Falls back to the
        uniform ``np.array_split`` sizes while no history exists.
        """
        n = len(hosts)
        uniform = [len(a) for a in
                   np.array_split(np.arange(self.global_batch), n)]
        if (not self.profile_shard_sizing or n <= 1
                or self.global_batch < n):
            return uniform
        rates: list[float | None] = []
        for h in hosts:
            stats = self.monitor.duration_stats("grad_shard", node=h.name)
            rates.append(1.0 / max(stats.mean, 1e-6)
                         if stats is not None and stats.n >= 3 else None)
        known = [r for r in rates if r is not None]
        if not known:
            return uniform
        fill = sum(known) / len(known)
        weights = [r if r is not None else fill for r in rates]
        # floor of 1 sample per host, remainder by largest-remainder quota
        spare = self.global_batch - n
        total = sum(weights)
        quotas = [spare * w / total for w in weights]
        sizes = [1 + int(q) for q in quotas]
        leftover = self.global_batch - sum(sizes)
        order = sorted(range(n), key=lambda i: quotas[i] - int(quotas[i]),
                       reverse=True)
        for i in order[:leftover]:
            sizes[i] += 1
        return sizes

    # ------------------------------------------------------------------ #
    def _shard_task(self, step: int, host: Node, params, batch,
                    injected_nan: bool):
        """Compute one host's gradient shard (real JAX compute), raising
        the failures a real host would raise."""
        if not host.healthy:
            raise HardwareShutdownError(f"host {host.name} is down",
                                        node=host.name)
        if self.shard_memory_gb > host.memory_gb:
            raise MemoryError(
                f"cannot allocate {self.shard_memory_gb}GB on {host.name} "
                f"(capacity {host.memory_gb}GB)")
        if host.speed < 1.0:
            time.sleep(min(0.05 / host.speed, 0.5))  # simulated straggle
        loss, grads = self._grad_fn(params, batch)
        if injected_nan:
            loss = loss * jnp.nan
            grads = jax.tree.map(lambda g: g * jnp.nan, grads)
        if not bool(jnp.isfinite(loss)):
            raise NumericalDivergenceError(
                f"loss is NaN/Inf at step {step}", node=host.name)
        return float(loss), grads

    def _profile(self, host: Node) -> dict[str, float]:
        return {"node_memory_gb": host.memory_gb,
                "node_mem_in_use_gb": host.mem_in_use_gb,
                "node_healthy": float(host.healthy)}

    # ------------------------------------------------------------------ #
    def run(self, steps: int, *, events: list[TrainEvent] | None = None,
            start_params=None) -> TrainReport:
        events = events or []
        by_step: dict[int, list[TrainEvent]] = {}
        for e in events:
            by_step.setdefault(e.step, []).append(e)

        key = jax.random.PRNGKey(self.data_seed)
        params = start_params if start_params is not None \
            else materialize(param_defs(self.cfg), key)
        opt_state = init_opt_state(params, self.opt_cfg)
        step0 = 0
        restored = self.ckpt.restore_latest({"params": params, "opt": opt_state})
        if restored is not None:
            tree, meta = restored
            params, opt_state = tree["params"], tree["opt"]
            step0 = int(meta["step"]) + 1

        losses: list[float] = []
        recoveries: list[dict] = []
        restores = 0
        speculations = 0
        data_jitter = 0
        step = step0
        while step < steps:
            # -- injected environment events (one-shot: a rewound run must
            # not re-trigger the same injected fault) ----------------------
            step_events = by_step.pop(step, [])
            for ev in step_events:
                node = self.cluster.find_node(ev.host) if ev.host else None
                if ev.kind == "host_down" and node:
                    node.shutdown_hardware()
                elif ev.kind == "host_up" and node:
                    node.restore_hardware()
                    self.denylist.discard(node.name)
                elif ev.kind == "straggler" and node:
                    node.speed = 1.0 / ev.factor
                elif ev.kind == "host_join" and ev.host and node is None:
                    # elastic scale-out: the next step's shard plan is
                    # recomputed from the live host list, so the joiner
                    # picks up a sub-batch immediately — no restart
                    self.cluster.pools["pod0"].add_node(
                        Node(name=ev.host, memory_gb=ev.memory_gb))
                    self.monitor.record_system_event("host_join",
                                                     node=ev.host)
                elif ev.kind == "host_leave" and node:
                    # elastic scale-in: remove from membership entirely
                    # (unlike host_down the host is *gone*, not unhealthy)
                    # and reshard the remaining global batch live
                    self.cluster.pools["pod0"].remove_node(ev.host)
                    self.denylist.discard(ev.host)
                    self.monitor.record_system_event("host_leave",
                                                     node=ev.host)

            inject_nan = any(e.kind == "nan" for e in step_events)

            hosts = self._order_hosts(
                self.healthy_hosts() or [self.cluster.find_node("bighost")])
            batch = batch_for(self.cfg, self.global_batch, self.seq_len,
                              step + data_jitter, seed=self.data_seed)
            sizes = self._shard_sizes(hosts)
            edges = np.cumsum([0] + sizes)
            shards = [np.arange(edges[i], edges[i + 1])
                      for i in range(len(hosts))]

            grads_acc = None
            loss_acc = 0.0
            nshards = 0
            restart_step = False
            for host, idx in zip(hosts, shards):
                if len(idx) == 0:
                    continue
                sub = {k: v[idx] for k, v in batch.items()}
                attempt_host: Node | None = host
                rec = new_task_record(
                    TaskDef(lambda: None, "grad_shard",
                            ResourceSpec(memory_gb=self.shard_memory_gb), 2),
                    (), {}, default_retries=2)
                # full middleware protocol: on_submit lets policies set up
                # per-record state (e.g. deferred replay's budget extension)
                self.policies.on_submit(rec, self._ctx())
                while attempt_host is not None:
                    t0 = time.perf_counter()
                    try:
                        loss, grads = self._shard_task(
                            step, attempt_host, params, sub,
                            inject_nan and nshards == 0)
                        dt = time.perf_counter() - t0
                        self.monitor.record_task_placement(
                            "grad_shard", attempt_host.name, "pod0", ok=True,
                            duration=dt, memory_gb=self.shard_memory_gb)
                        # straggler detection: EMA of *per-sample* shard
                        # times — profile-weighted sizing hands fast hosts
                        # bigger shards, so raw durations no longer compare
                        per = dt / max(len(idx), 1)
                        ema = self._host_times.get(attempt_host.name, per)
                        self._host_times[attempt_host.name] = 0.7 * ema + 0.3 * per
                        median = float(np.median(list(self._host_times.values())))
                        if per > self.straggler_factor * max(median, 1e-4) \
                                and len(hosts) > 1:
                            # rung-3 style: speculatively redo on the
                            # historically fastest host (or wherever the
                            # bound scheduler points)
                            others = [h for h in hosts
                                      if h.name != attempt_host.name]
                            fastest = None
                            if self.scheduler is not None:
                                fastest = self.scheduler.select(
                                    rec, others,
                                    pool=self.cluster.pools["pod0"])
                            if fastest is None:
                                fastest = min(
                                    others,
                                    key=lambda h: self._host_times.get(h.name, 1e9))
                            loss, grads = self._shard_task(
                                step, fastest, params, sub, False)
                            speculations += 1
                            n_slow = self._slow_counts.get(attempt_host.name, 0) + 1
                            self._slow_counts[attempt_host.name] = n_slow
                            if n_slow >= 3:
                                # chronic straggler: denylist the host (it
                                # resumes via the heartbeat-resume rule once
                                # its speed recovers)
                                self.denylist.add(attempt_host.name)
                                self.monitor.record_system_event(
                                    "denylist_add", node=attempt_host.name,
                                    cause="chronic_straggler")
                        break
                    except Exception as err:  # noqa: BLE001
                        rec.record_attempt(
                            node=attempt_host.name, pool="pod0", worker="-",
                            ok=False, error=type(err).__name__,
                            duration=time.perf_counter() - t0)
                        report = FailureReport.from_exception(
                            err, task_id=rec.task_id, node=attempt_host.name,
                            pool="pod0",
                            resource_profile=self._profile(attempt_host),
                            requirements=rec.resources.asdict(),
                            retry_count=rec.retry_count)
                        self.monitor.record_task_placement(
                            "grad_shard", attempt_host.name, "pod0", ok=False)
                        decision = self.policies.decide(rec, report, self._ctx())
                        recoveries.append({
                            "step": step, "error": type(err).__name__,
                            "host": attempt_host.name,
                            "action": decision.action.value,
                            "rung": decision.rung, "reason": decision.reason})
                        if isinstance(err, NumericalDivergenceError):
                            # application-layer divergence: restore last
                            # checkpoint, perturb the data order, re-run
                            restart_step = True
                            break
                        if decision.action in (Action.RETRY,
                                               Action.RESTART_AND_RETRY):
                            rec.retry_count += 1
                            if decision.target_node:
                                attempt_host = self.cluster.find_node(
                                    decision.target_node)
                            else:
                                # un-pinned retry (e.g. replay(n)): move to
                                # another healthy host when one exists
                                failed = attempt_host.name
                                others = [h for h in self.healthy_hosts()
                                          if h.name != failed]
                                attempt_host = (others[0] if others else
                                                (self.healthy_hosts() or [None])[0])
                        else:
                            attempt_host = None
                if restart_step:
                    break
                if attempt_host is None:
                    raise RuntimeError(
                        f"shard for step {step} unrecoverable; aborting run")
                loss_acc += loss * len(idx)
                grads = jax.tree.map(
                    lambda g: g.astype(jnp.float32) * (len(idx) / self.global_batch),
                    grads)
                grads_acc = grads if grads_acc is None else jax.tree.map(
                    jnp.add, grads_acc, grads)
                nshards += 1

            if restart_step:
                restored = self.ckpt.restore_latest(
                    {"params": params, "opt": opt_state})
                restores += 1
                data_jitter += 1          # perturb data order (reseed)
                if restored is not None:
                    tree, meta = restored
                    params, opt_state = tree["params"], tree["opt"]
                    step = int(meta["step"]) + 1
                continue

            params, opt_state, _ = adamw_apply(params, grads_acc, opt_state,
                                               self.opt_cfg)
            losses.append(loss_acc / self.global_batch)
            if step % self.ckpt_every == 0:
                self.ckpt.save(step, {"params": params, "opt": opt_state})
            step += 1

        self.ckpt.save(steps - 1, {"params": params, "opt": opt_state})
        return TrainReport(
            steps_completed=len(losses), losses=losses, recoveries=recoveries,
            restores=restores, denylisted=sorted(self.denylist),
            speculations=speculations, final_hosts=len(self.healthy_hosts()))
