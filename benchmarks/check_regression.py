"""Perf regression gate: compare a fresh BENCH JSON to a baseline.

    python -m benchmarks.check_regression \
        BENCH_engine_throughput.json bench-out/BENCH_engine_throughput.json
    python -m benchmarks.check_regression --metric achieved_gflops \
        BENCH_kernels.json bench-out/BENCH_kernels.json

Every gated metric in the baseline must be within ``--tolerance``
(default 20%) below the committed value in the fresh run;
higher-is-better, so only downward movement can fail.  ``--metric``
selects the gated suffix (default ``tasks_per_sec``, the engine
throughput gate) and may be repeated to gate several suffixes in one
invocation — the kernel suite gates ``achieved_gflops`` per kernel and
per train step.  Rows whose name contains ``_before_`` are the frozen
pre-optimization reference — the untuned measurement the suite reports
for context, not the thing being protected — and are skipped.  Exit
status is the gate: 0 = no regression, 1 = at least one metric
regressed, 2 = a baseline metric is missing from the fresh run (a
renamed or dropped row must update the committed baseline in the same
change).

CI runners are slower and noisier than the machine that produced the
committed baseline; ``--tolerance`` (or ``BENCH_TOLERANCE``) is the
knob that absorbs that, and the default is deliberately loose — the
gate exists to catch the 2× dispatch-path regressions, not 5% jitter.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from pathlib import Path


def compare(baseline: dict, fresh: dict, *, suffix: str,
            tolerance: float, lower: bool = False) -> tuple[list[str], list[str]]:
    """Returns (regressions, missing) message lists.

    ``lower=True`` flips the direction for lower-is-better metrics
    (latency percentiles): the fresh value may not exceed the baseline by
    more than ``tolerance``.
    """
    regressions: list[str] = []
    missing: list[str] = []
    for key, base_val in sorted(baseline.get("metrics", {}).items()):
        if not key.endswith(f".{suffix}") or "_before_" in key:
            continue
        new_val = fresh.get("metrics", {}).get(key)
        if new_val is None:
            missing.append(f"{key}: in baseline but absent from fresh run")
            continue
        if lower:
            ceiling = base_val * (1.0 + tolerance)
            if new_val > ceiling:
                regressions.append(
                    f"{key}: {new_val:.4g} > {ceiling:.4g} "
                    f"(baseline {base_val:.4g}, tolerance {tolerance:.0%})")
        else:
            floor = base_val * (1.0 - tolerance)
            if new_val < floor:
                regressions.append(
                    f"{key}: {new_val:.4g} < {floor:.4g} "
                    f"(baseline {base_val:.4g}, tolerance {tolerance:.0%})")
    return regressions, missing


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.check_regression",
        description="fail on tasks/sec regression vs a committed baseline")
    ap.add_argument("baseline", type=Path,
                    help="committed BENCH_<suite>.json")
    ap.add_argument("fresh", type=Path,
                    help="BENCH_<suite>.json from the current run")
    ap.add_argument("--metric", action="append", default=None,
                    help="metric suffix to gate on (repeatable; default "
                         "tasks_per_sec)")
    ap.add_argument("--lower-metric", action="append", default=None,
                    help="lower-is-better metric suffix to gate on "
                         "(repeatable; e.g. p99_ms — fails when the fresh "
                         "value exceeds baseline by more than tolerance)")
    ap.add_argument("--tolerance",
                    type=float,
                    default=float(os.environ.get("BENCH_TOLERANCE", "0.20")),
                    help="allowed fractional drop (default 0.20 or "
                         "$BENCH_TOLERANCE)")
    args = ap.parse_args(argv)

    baseline = json.loads(args.baseline.read_text())
    fresh = json.loads(args.fresh.read_text())
    if fresh.get("error"):
        print(f"REGRESSION GATE: fresh run errored: {fresh['error']}")
        return 1
    metrics = args.metric or ([] if args.lower_metric else ["tasks_per_sec"])
    lower_metrics = args.lower_metric or []
    regressions: list[str] = []
    missing: list[str] = []
    for suffix in metrics:
        reg, mis = compare(baseline, fresh, suffix=suffix,
                           tolerance=args.tolerance)
        regressions += reg
        missing += mis
    for suffix in lower_metrics:
        reg, mis = compare(baseline, fresh, suffix=suffix,
                           tolerance=args.tolerance, lower=True)
        regressions += reg
        missing += mis
    for msg in regressions:
        print(f"REGRESSION: {msg}")
    for msg in missing:
        print(f"MISSING: {msg}")
    if regressions:
        return 1
    if missing:
        return 2
    gated = ",".join(metrics + [f"{m}(lower)" for m in lower_metrics])
    print(f"regression gate ok: every *.{{{gated}}} within "
          f"{args.tolerance:.0%} of {args.baseline}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
