"""Coverage-guided chaos search vs uniform sampling at equal budget.

The acceptance benchmark for the guided campaign: three arms, one
scenario budget, one coverage metric (distinct monitor-event n-grams,
orders 1..3, :mod:`repro.sim.coverage`):

* ``chaos_uniform`` — the status-quo campaign: independent
  ``Scenario.random`` draws, no correlated fault kinds;
* ``chaos_uniform_correlated`` — ablation: the same independent draws
  with the correlated kinds enabled (``correlated_rate=0.35``), isolating
  how much of the win is vocabulary vs search;
* ``chaos_guided`` — the full search: seeded exploration + novelty-bandit
  mutation over the same correlated generator.

Everything is seeded (``BASE_SEED``/``BUDGET`` fixed), so the numbers are
machine-independent and the superiority claim is a deterministic
regression check, not a statistical one: ``guided_gt_uniform`` and
``guided_gt_correlated`` must both stay 1.
"""
from __future__ import annotations

import time

from benchmarks.common import csv_row
from repro.sim import guided_campaign, uniform_campaign_coverage

BUDGET = 60
BASE_SEED = 0
MAX_TASKS = 16
CORRELATED_RATE = 0.35


def run():
    rows = []
    t0 = time.perf_counter()
    plain = uniform_campaign_coverage(
        BUDGET, base_seed=BASE_SEED,
        scenario_kwargs={"max_tasks": MAX_TASKS})
    rows.append(csv_row(
        "chaos_uniform", (time.perf_counter() - t0) * 1e6 / BUDGET,
        f"distinct_ngrams={plain.distinct} budget={BUDGET}"))

    t0 = time.perf_counter()
    corr = uniform_campaign_coverage(
        BUDGET, base_seed=BASE_SEED,
        scenario_kwargs={"max_tasks": MAX_TASKS,
                         "correlated_rate": CORRELATED_RATE})
    rows.append(csv_row(
        "chaos_uniform_correlated",
        (time.perf_counter() - t0) * 1e6 / BUDGET,
        f"distinct_ngrams={corr.distinct} budget={BUDGET}"))

    t0 = time.perf_counter()
    guided = guided_campaign(
        BUDGET, base_seed=BASE_SEED, determinism_checks=1,
        scenario_kwargs={"max_tasks": MAX_TASKS,
                         "correlated_rate": CORRELATED_RATE})
    assert guided.ok, guided.summary()
    rows.append(csv_row(
        "chaos_guided", (time.perf_counter() - t0) * 1e6 / BUDGET,
        f"distinct_ngrams={guided.distinct()} budget={BUDGET} "
        f"seeded={guided.from_seeds} mutated={guided.mutated}"))

    rows.append(csv_row(
        "chaos_search_win", 0.0,
        f"guided_gt_uniform={int(guided.distinct() > plain.distinct)} "
        f"guided_gt_correlated={int(guided.distinct() > corr.distinct)} "
        f"guided_minus_uniform={guided.distinct() - plain.distinct} "
        f"guided_minus_correlated={guided.distinct() - corr.distinct}"))
    return rows
