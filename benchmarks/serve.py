"""Serving-plane benchmark: static vs continuous batching, SLO admission,
chaos p99, autoscaling — all on the simulated decode backend under a
virtual clock, so every number is **deterministic**: the committed
``BENCH_serve.json`` baseline matches a CI re-run bit for bit and the
regression gate can be tight.

Rows (metrics in the derived field):

* ``serve_static``      — the synchronous static batcher baseline.
* ``serve_continuous``  — same workload, same replica count, continuous
  batching; ``speedup_vs_static`` is the headline (slot refill at step
  boundaries + all replicas decoding concurrently).
* ``serve_chaos``       — a replica killed mid-traffic; the metric that
  matters is ``p99_ms`` staying bounded while every request completes.
* ``serve_admission``   — overload with per-request deadlines; rejected
  requests must consume **zero** decode steps (fast-fail at the door).
* ``serve_autoscale``   — bursty arrivals against a 1-replica floor; the
  autoscaler grows into the burst and shrinks back after it drains.
"""
from __future__ import annotations

import random

from repro.core import MonitoringDatabase
from repro.serve import (ReplicaAutoscaler, ServeRequest, SLOAdmissionPolicy,
                         WrathServeDriver)
from repro.serve.batcher import SimDecodeBackend
from repro.sim.clock import VirtualClock

STEP_S = 0.02          # modeled decode-step cost at replica speed 1.0
REPLICAS = 3
MAX_BATCH = 4


def _workload(n: int, *, deadline_s: float | None = None,
              seed: int = 0) -> list[ServeRequest]:
    """Mixed-length workload: short and long requests interleaved, so the
    static batcher pays real head-of-line blocking."""
    rng = random.Random(seed)
    return [ServeRequest(
        rid=i,
        prompt=[rng.randrange(256) for _ in range(rng.randint(2, 6))],
        max_new_tokens=rng.randint(2, 12),
        deadline_s=deadline_s) for i in range(n)]


def _driver(n_replicas: int = REPLICAS, **kw) -> WrathServeDriver:
    clock = VirtualClock()
    return WrathServeDriver(None, n_replicas=n_replicas, max_batch=MAX_BATCH,
                            clock=clock,
                            monitor=MonitoringDatabase(clock=clock),
                            decode=SimDecodeBackend(step_s=STEP_S), **kw)


def run():
    # -- static baseline -------------------------------------------------
    driver = _driver()
    reqs = _workload(60)
    rep = driver.serve(reqs)
    static_rps = rep.requests_per_s
    yield (f"serve_static,{rep.wall_s * 1e6 / len(reqs):.0f},"
           f"requests_per_sec={static_rps:.3f} "
           f"tokens_per_sec={rep.tokens_per_s:.1f} "
           f"decode_steps={rep.decode_steps}")

    # -- continuous batching, same workload and replica count ------------
    driver = _driver()
    reqs = _workload(60)
    rep = driver.serve_continuous(reqs, horizon=600.0)
    driver.shutdown()
    yield (f"serve_continuous,{rep.wall_s * 1e6 / len(reqs):.0f},"
           f"requests_per_sec={rep.requests_per_s:.3f} "
           f"p50_ms={rep.p50_s * 1e3:.1f} p99_ms={rep.p99_s * 1e3:.1f} "
           f"speedup_vs_static={rep.requests_per_s / max(static_rps, 1e-9):.2f} "
           f"decode_steps={rep.decode_steps}")

    # -- chaos: replica killed mid-traffic -------------------------------
    driver = _driver()
    reqs = _workload(60)
    arrivals = [0.01 * i for i in range(len(reqs))]
    rep = driver.serve_continuous(reqs, arrivals=arrivals,
                                  faults=[(0.3, "kill", "replica1")],
                                  horizon=600.0)
    driver.shutdown()
    yield (f"serve_chaos,{rep.wall_s * 1e6 / len(reqs):.0f},"
           f"requests_per_sec={rep.requests_per_s:.3f} "
           f"p99_ms={rep.p99_s * 1e3:.1f} "
           f"completed_frac={rep.completed / len(reqs):.3f} "
           f"recoveries={len(rep.recoveries)}")

    # -- SLO admission under overload ------------------------------------
    driver = _driver(admission=SLOAdmissionPolicy(default_step_s=STEP_S))
    reqs = _workload(150, deadline_s=1.0)
    arrivals = [0.005 * i for i in range(len(reqs))]   # 200 req/s offered
    rep = driver.serve_continuous(reqs, arrivals=arrivals, horizon=600.0)
    driver.shutdown()
    rejected_steps = sum(len(r.generated) for r in reqs
                         if r.status == "rejected")
    yield (f"serve_admission,{rep.wall_s * 1e6 / len(reqs):.0f},"
           f"requests_per_sec={rep.requests_per_s:.3f} "
           f"shed_rate={rep.shed_rate:.3f} rejected={rep.rejected} "
           f"rejected_decode_steps={rejected_steps} "
           f"p99_ms={rep.p99_s * 1e3:.1f}")

    # -- autoscaling through a burst -------------------------------------
    driver = _driver(
        n_replicas=1,
        policy=[ReplicaAutoscaler(min_replicas=1, max_replicas=5,
                                  patience=2, idle_ticks=3)])
    reqs = _workload(80)
    rep = driver.serve_continuous(reqs, arrivals=[0.0] * len(reqs),
                                  horizon=600.0, tick_period=0.1,
                                  drain_s=1.0)
    driver.shutdown()
    yield (f"serve_autoscale,{rep.wall_s * 1e6 / len(reqs):.0f},"
           f"requests_per_sec={rep.requests_per_s:.3f} "
           f"autoscaled_up={rep.autoscaled_up} "
           f"autoscaled_down={rep.autoscaled_down} "
           f"replicas_final={rep.replicas_final}")
