"""Generate the EXPERIMENTS.md roofline tables from dry-run JSONs.

Baseline JSONs (benchmarks/results/dryrun_baseline) were measured before
the all-reduce bytes were weighted 2× (physical RS+AG decomposition); this
script re-derives their collective term with the same convention so the
baseline↔optimized comparison is apples-to-apples.
"""
from __future__ import annotations

import json
from pathlib import Path

HERE = Path(__file__).resolve().parent
CHIP_LINK = 50e9
PEAK = 197e12


def corrected(cell: dict, *, ar_was_1x: bool) -> dict:
    r = dict(cell["roofline"])
    cb = dict(r.get("coll_breakdown", {}))
    total = sum(v for k, v in cb.items() if k != "total")
    if ar_was_1x and "all-reduce" in cb:
        total += cb["all-reduce"]          # count AR twice
    chips = r["chips"]
    r["collective_s"] = total / (chips * CHIP_LINK)
    t = max(r["compute_s"], r["memory_s"], r["collective_s"])
    mf = float(r["model_flops"])
    r["roofline_fraction"] = mf / (chips * PEAK * t) if t else 0.0
    terms = {"compute": r["compute_s"], "memory": r["memory_s"],
             "collective": r["collective_s"]}
    r["dominant"] = max(terms, key=terms.get)
    return r


def load(directory: Path, mesh: str, *, ar_was_1x: bool) -> list[dict]:
    out = []
    for p in sorted(directory.glob(f"*__{mesh}.json")):
        d = json.loads(p.read_text())
        if d["status"] != "ok":
            continue
        out.append(corrected(d, ar_was_1x=ar_was_1x))
    return out


def table(cells: list[dict], *, kernel_col: bool = False) -> str:
    hdr = ("| arch | shape | chips | compute_s | memory_s | collective_s | "
           "dominant | useful | roofline_frac |")
    sep = "|---|---|---|---|---|---|---|---|---|"
    if kernel_col:
        hdr += " frac_w/kernel | HBM GB/dev |"
        sep += "---|---|"
    rows = [hdr, sep]
    for r in cells:
        line = (f"| {r['arch']} | {r['shape']} | {r['chips']} | "
                f"{r['compute_s']:.4f} | {r['memory_s']:.4f} | "
                f"{r['collective_s']:.4f} | {r['dominant']} | "
                f"{r['useful_ratio']:.3f} | {r['roofline_fraction']:.4f} |")
        if kernel_col:
            line += (f" {r.get('roofline_fraction_kernel', float(r['roofline_fraction'])):.4f} "
                     f"| {r['per_device_hbm_gb']:.2f} |")
        rows.append(line)
    return "\n".join(rows)


def main() -> None:
    base = load(HERE / "results" / "dryrun_baseline", "single", ar_was_1x=True)
    opt_s = load(HERE / "results" / "dryrun", "single", ar_was_1x=False)
    opt_m = load(HERE / "results" / "dryrun", "multi", ar_was_1x=False)
    print("## Optimized — single-pod (16×16 = 256 chips)\n")
    print(table(opt_s, kernel_col=True))
    print("\n## Optimized — multi-pod (2×16×16 = 512 chips)\n")
    print(table(opt_m, kernel_col=True))
    print("\n## Baseline (pre-hillclimb, AR re-weighted 2× for comparability)"
          " — single-pod\n")
    print(table(base))


if __name__ == "__main__":
    main()
