"""Roofline table: three terms per (arch × shape × mesh) from the dry-run
artifacts (benchmarks/results/dryrun/*.json).  Run the dry-run first:

    python -m repro.launch.dryrun --all --mesh both
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

from benchmarks.common import csv_row

RESULTS = Path(__file__).resolve().parent / "results" / "dryrun"


def load_cells(mesh: str | None = None, *, verbose: bool = True) -> list[dict]:
    """Dry-run cells with ``status == "ok"``.

    Every skipped artifact is logged with its status (no silent caps):
    a failed or skipped compile cell silently vanishing from the table
    would read as full coverage when it is not.
    """
    cells = []
    for p in sorted(RESULTS.glob("*.json")):
        d = json.loads(p.read_text())
        status = d.get("status")
        if status != "ok":
            if verbose:
                why = d.get("skip_reason") or d.get("error", "").partition("\n")[0]
                print(f"roofline: skipping {p.name}: status={status}"
                      + (f" ({why[:100]})" if why else ""), file=sys.stderr)
            continue
        if mesh and d["roofline"]["mesh"] != mesh:
            continue
        cells.append(d)
    return cells


def run(mesh: str = "single") -> list[str]:
    rows: list[str] = []
    cells = load_cells(mesh)
    if not cells:
        return [csv_row("roofline_missing", 0.0,
                        "run `python -m repro.launch.dryrun --all` first")]
    for d in cells:
        r = d["roofline"]
        t_bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
        rows.append(csv_row(
            f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}",
            t_bound * 1e6,
            f"dominant={r['dominant']};compute_s={r['compute_s']};"
            f"memory_s={r['memory_s']};collective_s={r['collective_s']};"
            f"useful_ratio={r['useful_ratio']};"
            f"roofline_fraction={r['roofline_fraction']};"
            f"hbm_gb={r['per_device_hbm_gb']}"))
    return rows


def markdown_table(mesh: str = "single") -> str:
    """EXPERIMENTS.md §Roofline content."""
    cells = load_cells(mesh)
    out = ["| arch | shape | chips | compute_s | memory_s | collective_s | "
           "dominant | model_flops | useful | roofline_frac | HBM GB/dev |",
           "|---|---|---|---|---|---|---|---|---|---|---|"]
    for d in cells:
        r = d["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['chips']} | "
            f"{r['compute_s']:.4f} | {r['memory_s']:.4f} | "
            f"{r['collective_s']:.4f} | {r['dominant']} | {r['model_flops']} | "
            f"{r['useful_ratio']:.3f} | {r['roofline_fraction']:.4f} | "
            f"{r['per_device_hbm_gb']:.2f} |")
    return "\n".join(out)
