"""Fig 8: Cholesky task success rate vs memory-failure rate (0.1–0.3).

16 small-memory nodes + 1 large-memory node, like the paper.  WRATH holds
task SR high via hierarchical retry; baseline degrades as rate rises.
"""
from __future__ import annotations

from benchmarks.common import csv_row, mean_sem, run_once
from repro.engine import Cluster
from repro.injection import FailureInjector


def run(repeats: int = 3,
        rates: tuple[float, ...] = (0.1, 0.2, 0.3)) -> list[str]:
    rows: list[str] = []
    for rate in rates:
        for mode in ("wrath", "baseline"):
            srs = []
            for r in range(repeats):
                inj = FailureInjector("memory", rate=rate, seed=r,
                                      app_tag=f"f8:{rate}:{r}")
                res = run_once(
                    "cholesky", mode=mode, injector=inj,
                    cluster_fn=lambda: Cluster.paper_testbed(
                        small_nodes=16, big_nodes=1),
                    default_pool="small-mem", retries=2, scale="small")
                srs.append(res.task_success_rate)
            m, sem = mean_sem(srs)
            rows.append(csv_row(f"fig8_tasksr_{mode}_rate{rate}", 0.0,
                                f"task_success_rate={m:.3f}±{sem:.3f}"))
    return rows
