"""Kernel performance plane: roofline-tracked timings for the Pallas
kernels and a full train step.

For each kernel shape the suite runs the autotune sweep
(``repro.kernels.autotune``), then reports a *before* row (the hard-coded
128-block defaults — excluded from the regression gate, it is the frozen
reference point) and a *tuned* row (the cache-persisted winner).  Every
row divides achieved FLOP/s and bandwidth by the roofline terms from
``repro.roofline`` (v5e peak FLOP/s and HBM bandwidth, the same constants
the dry-run analysis uses), so ``BENCH_kernels.json`` tracks
"fraction of the hardware roofline" per push, not just microseconds.

The FLOP yardstick is *useful work* (chunk/block-independent — flash:
4·BH·Sq·Sk·D, halved for causal; SSD: 4·B·H·L·P·N), the kernel analog of
the roofline plane's 6·N·D model FLOPs: block choices change the time,
never the numerator.

On this CPU container the kernels run in interpret mode (the Pallas body
executes in Python), so absolute roofline fractions are tiny; on a TPU
host the same suite measures the compiled kernels against the real roof.

    PYTHONPATH=src python -m benchmarks.run kernels
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row
from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16

#: flash shapes: (label, batch, seq, heads, head_dim, causal).  s320 is
#: deliberately not a multiple of 128 — the default blocks pad 320 -> 384
#: (a 3x3 grid), while the tuner can pick blocks that divide 320.
FLASH_SHAPES = [
    ("s256_d64", 1, 256, 4, 64, True),
    ("s320_d64", 1, 320, 4, 64, True),
]

#: ssd shapes: (label, batch, seq, heads, head_channels, state).  l160 is
#: the non-multiple-of-128 case for the chunked scan.
SSD_SHAPES = [
    ("l256_p16", 2, 256, 2, 16, 32),
    ("l160_p16", 2, 160, 2, 16, 32),
]

TRAIN_ARCH = "granite-3-2b"
TRAIN_BATCH, TRAIN_SEQ = 4, 64
REPEATS = 3


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _flash_flops(bh: int, s: int, sk: int, d: int, causal: bool) -> float:
    return 4.0 * bh * s * sk * d * (0.5 if causal else 1.0)


def _flash_bytes(bh: int, s: int, sk: int, d: int, itemsize: int) -> float:
    return float(bh * (2 * s * d + 2 * sk * d) * itemsize)   # q, out, k, v


def _ssd_flops(b: int, l: int, h: int, p: int, n: int) -> float:
    return 4.0 * b * h * l * p * n     # state update + output contraction


def _ssd_bytes(b: int, l: int, h: int, p: int, n: int, itemsize: int) -> float:
    x_y = 2 * b * l * h * p
    dt = b * l * h
    bc = 2 * b * l * n
    state = b * h * p * n
    return float((x_y + dt + bc + state) * itemsize)


def _derived(us: float, flops: float, nbytes: float, extra: str = "") -> str:
    """Achieved rates + their roofline fractions (single chip)."""
    s = us / 1e6
    gflops = flops / s / 1e9
    gbps = nbytes / s / 1e9
    out = (f"achieved_gflops={gflops:.4g};achieved_gbps={gbps:.4g};"
           f"compute_frac={gflops * 1e9 / PEAK_FLOPS_BF16:.3g};"
           f"hbm_frac={gbps * 1e9 / HBM_BW:.3g}")
    return f"{out};{extra}" if extra else out


def _bench_flash() -> list[str]:
    from repro.kernels.autotune import autotune_flash_attention

    rows = []
    key = jax.random.PRNGKey(11)
    for label, b, s, h, d, causal in FLASH_SHAPES:
        bh = b * h
        q = jax.random.normal(key, (bh, s, d), jnp.float32)
        k = jax.random.normal(jax.random.fold_in(key, 1), (bh, s, d), jnp.float32)
        v = jax.random.normal(jax.random.fold_in(key, 2), (bh, s, d), jnp.float32)
        res = autotune_flash_attention(q, k, v, causal=causal,
                                       interpret=_interpret(), repeats=REPEATS)
        flops = _flash_flops(bh, s, s, d, causal)
        nbytes = _flash_bytes(bh, s, s, d, q.dtype.itemsize)
        rows.append(csv_row(
            f"kernels_flash_{label}_before_tuning", res.default_us,
            _derived(res.default_us, flops, nbytes, "qb=128;kb=128")))
        blk = res.blocks
        rows.append(csv_row(
            f"kernels_flash_{label}_tuned", res.us,
            _derived(res.us, flops, nbytes,
                     f"qb={blk['q_block']};kb={blk['kv_block']};"
                     f"speedup={res.speedup:.3f}")))
    return rows


def _bench_ssd() -> list[str]:
    from repro.kernels.autotune import autotune_ssd_scan

    rows = []
    key = jax.random.PRNGKey(13)
    for label, b, l, h, p, n in SSD_SHAPES:
        x = jax.random.normal(key, (b, l, h, p), jnp.float32)
        dt = jax.nn.softplus(
            jax.random.normal(jax.random.fold_in(key, 1), (b, l, h)))
        a = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (h,)) * 0.3)
        bm = jax.random.normal(jax.random.fold_in(key, 3), (b, l, n))
        cm = jax.random.normal(jax.random.fold_in(key, 4), (b, l, n))
        res = autotune_ssd_scan(x, dt, a, bm, cm, interpret=_interpret(),
                                repeats=REPEATS)
        flops = _ssd_flops(b, l, h, p, n)
        nbytes = _ssd_bytes(b, l, h, p, n, x.dtype.itemsize)
        rows.append(csv_row(
            f"kernels_ssd_{label}_before_tuning", res.default_us,
            _derived(res.default_us, flops, nbytes, "chunk=128")))
        rows.append(csv_row(
            f"kernels_ssd_{label}_tuned", res.us,
            _derived(res.us, flops, nbytes,
                     f"chunk={res.blocks['chunk']};"
                     f"speedup={res.speedup:.3f}")))
    return rows


def _bench_train_step() -> list[str]:
    """One real grad step (smoke config, jit-compiled): useful 6·N·D FLOPs
    and HLO-reported FLOPs/bytes over measured step time, as fractions of
    the same roofline terms the dry-run analysis reports."""
    from repro.configs import get_smoke_config
    from repro.data import batch_for
    from repro.models import loss_fn, materialize, param_defs
    from repro.roofline.analysis import hlo_cost, model_flops

    cfg = get_smoke_config(TRAIN_ARCH)
    defs = param_defs(cfg)
    params = materialize(defs, jax.random.PRNGKey(0))
    batch = batch_for(cfg, TRAIN_BATCH, TRAIN_SEQ, 0)
    grad_fn = jax.jit(
        jax.value_and_grad(lambda p, b: loss_fn(p, b, cfg, remat=False)[0]))
    lowered = grad_fn.lower(params, batch)
    compiled = lowered.compile()
    hlo = hlo_cost(compiled)

    out = compiled(params, batch)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        jax.block_until_ready(compiled(params, batch))
        best = min(best, time.perf_counter() - t0)
    us = best * 1e6

    tokens = TRAIN_BATCH * TRAIN_SEQ
    useful = model_flops(cfg, defs, kind="train", tokens=tokens)
    s = best
    return [csv_row(
        f"kernels_train_step_{cfg.name}", us,
        f"achieved_gflops={hlo['flops'] / s / 1e9:.4g};"
        f"useful_gflops={useful / s / 1e9:.4g};"
        f"achieved_gbps={hlo['bytes'] / s / 1e9:.4g};"
        f"compute_frac={hlo['flops'] / s / PEAK_FLOPS_BF16:.3g};"
        f"hbm_frac={hlo['bytes'] / s / HBM_BW:.3g};"
        f"roofline_frac={useful / s / PEAK_FLOPS_BF16:.3g};"
        f"tokens_per_s={tokens / s:.1f}")]


def run() -> list[str]:
    return _bench_flash() + _bench_ssd() + _bench_train_step()
