"""Fig 4: normalized time-to-failure, apps × non-resolvable failure types.

WRATH identifies destined-to-fail tasks and fails fast; baseline burns
retries first.  Reported value = TTF(WRATH) / TTF(baseline) (< 1 is
better; paper: 0.5–0.8).
"""
from __future__ import annotations

from benchmarks.common import csv_row, mean_sem, run_once
from repro.engine import Cluster
from repro.injection import FailureInjector

APPS = ("mapreduce", "cholesky", "docking", "moldesign", "fedlearn")
FAILURES = ("zero_division", "exception", "worker_killed", "dependency")


def run(repeats: int = 3, rate: float = 0.3) -> list[str]:
    rows: list[str] = []
    for app in APPS:
        for failure in FAILURES:
            ratios, wrath_ttfs = [], []
            for r in range(repeats):
                tag = f"{app}:{failure}:{r}"
                inj_w = FailureInjector(failure, rate=rate, seed=r, app_tag=tag,
                                        only_parents=failure == "dependency")
                rw = run_once(app, mode="wrath", injector=inj_w,
                              cluster_fn=lambda: Cluster.homogeneous(4),
                              default_pool=None)
                inj_b = FailureInjector(failure, rate=rate, seed=r, app_tag=tag,
                                        only_parents=failure == "dependency")
                rb = run_once(app, mode="baseline", injector=inj_b,
                              cluster_fn=lambda: Cluster.homogeneous(4),
                              default_pool=None)
                if rw.time_to_failure and rb.time_to_failure:
                    ratios.append(rw.time_to_failure / rb.time_to_failure)
                    wrath_ttfs.append(rw.time_to_failure)
            if ratios:
                m, sem = mean_sem(ratios)
                ttf_m, _ = mean_sem(wrath_ttfs)
                rows.append(csv_row(
                    f"fig4_ttf_{app}_{failure}", ttf_m * 1e6,
                    f"normalized_ttf={m:.3f}±{sem:.3f}"))
            else:
                rows.append(csv_row(f"fig4_ttf_{app}_{failure}", 0.0,
                                    "no_failures_triggered"))
    return rows
