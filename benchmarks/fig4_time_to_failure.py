"""Fig 4: normalized time-to-failure, apps × non-resolvable failure types.

WRATH identifies destined-to-fail tasks and fails fast; baseline burns
retries first.  Reported value = TTF(WRATH) / TTF(baseline) (< 1 is
better; paper: 0.5–0.8).

**Proactive mode** (``run_proactive`` / the trailing rows of ``run``)
compares the proactive plane against *reactive WRATH* itself on the
failure types where reacting is not enough:

* ``worker_killed`` — deterministic framework failures on a single-pool
  cluster: the reactive fail-fast heuristic needs recurrence across >= 2
  pools, so it burns the full retry budget; the sentinel's failure-streak
  rule cuts the last retry;
* ``memory`` — requirements that fit no node in the cluster: reactive
  WRATH needs the first OOM to manifest before rung analysis fails the
  task; the sentinel's predictive fast-fail kills it at dispatch.

The metric is the mean per-task time-to-failure (first dispatch ->
terminal, so dependency wait and JIT warm-up of unrelated parents are not
billed) of the destined tasks, normalized proactive/reactive (< 1 =
proactive wins).
"""
from __future__ import annotations

from benchmarks.common import csv_row, mean_sem, run_once
from repro.engine import Cluster
from repro.injection import FailureInjector

APPS = ("mapreduce", "cholesky", "docking", "moldesign", "fedlearn")
FAILURES = ("zero_division", "exception", "worker_killed", "dependency")
# failure types where the proactive plane beats reactive WRATH
PROACTIVE_FAILURES = ("worker_killed", "memory")


def run(repeats: int = 3, rate: float = 0.3) -> list[str]:
    rows: list[str] = []
    for app in APPS:
        for failure in FAILURES:
            ratios, wrath_ttfs = [], []
            for r in range(repeats):
                tag = f"{app}:{failure}:{r}"
                inj_w = FailureInjector(failure, rate=rate, seed=r, app_tag=tag,
                                        only_parents=failure == "dependency")
                rw = run_once(app, mode="wrath", injector=inj_w,
                              cluster_fn=lambda: Cluster.homogeneous(4),
                              default_pool=None)
                inj_b = FailureInjector(failure, rate=rate, seed=r, app_tag=tag,
                                        only_parents=failure == "dependency")
                rb = run_once(app, mode="baseline", injector=inj_b,
                              cluster_fn=lambda: Cluster.homogeneous(4),
                              default_pool=None)
                if rw.time_to_failure and rb.time_to_failure:
                    ratios.append(rw.time_to_failure / rb.time_to_failure)
                    wrath_ttfs.append(rw.time_to_failure)
            if ratios:
                m, sem = mean_sem(ratios)
                ttf_m, _ = mean_sem(wrath_ttfs)
                rows.append(csv_row(
                    f"fig4_ttf_{app}_{failure}", ttf_m * 1e6,
                    f"normalized_ttf={m:.3f}±{sem:.3f}"))
            else:
                rows.append(csv_row(f"fig4_ttf_{app}_{failure}", 0.0,
                                    "no_failures_triggered"))
    rows.extend(run_proactive(repeats=repeats, rate=rate))
    return rows


def _warmup() -> None:
    """Throwaway runs: JIT compilation and thread/loop spin-up costs must
    not be billed to whichever measured mode happens to run first."""
    for app in APPS:
        run_once(app, mode="wrath", injector=None,
                 cluster_fn=lambda: Cluster.homogeneous(4), default_pool=None)
    inj = FailureInjector("worker_killed", rate=0.3, seed=99, app_tag="warmup")
    run_once("mapreduce", mode="proactive", injector=inj,
             cluster_fn=lambda: Cluster.homogeneous(4), default_pool=None)


def run_proactive(repeats: int = 3, rate: float = 0.3) -> list[str]:
    """Proactive plane vs reactive WRATH: per-task normalized TTF."""
    rows: list[str] = []
    all_ratios: list[float] = []
    _warmup()
    for app in APPS:
        for failure in PROACTIVE_FAILURES:
            ratios, pro_ttfs = [], []
            for r in range(repeats):
                tag = f"{app}:pro:{failure}:{r}"
                inj_p = FailureInjector(failure, rate=rate, seed=r, app_tag=tag)
                rp = run_once(app, mode="proactive", injector=inj_p,
                              cluster_fn=lambda: Cluster.homogeneous(4),
                              default_pool=None)
                inj_w = FailureInjector(failure, rate=rate, seed=r, app_tag=tag)
                rw = run_once(app, mode="wrath", injector=inj_w,
                              cluster_fn=lambda: Cluster.homogeneous(4),
                              default_pool=None)
                tp = rp.extra.get("ttf_per_task_mean")
                tw = rw.extra.get("ttf_per_task_mean")
                if tp and tw:
                    ratios.append(tp / tw)
                    pro_ttfs.append(tp)
            if ratios:
                m, sem = mean_sem(ratios)
                all_ratios.extend(ratios)
                ttf_m, _ = mean_sem(pro_ttfs)
                rows.append(csv_row(
                    f"fig4_proactive_{app}_{failure}", ttf_m * 1e6,
                    f"normalized_ttf={m:.3f}±{sem:.3f}"))
            else:
                rows.append(csv_row(f"fig4_proactive_{app}_{failure}", 0.0,
                                    "no_failures_triggered"))
    if all_ratios:
        m, sem = mean_sem(all_ratios)
        rows.append(csv_row("fig4_proactive_aggregate", 0.0,
                            f"normalized_ttf={m:.3f}±{sem:.3f}"))
    return rows
