"""Fig 5: WRATH overhead ratio on successful runs (paper: < 2%).

Failure rate 0.1 of resolvable (memory) failures on the heterogeneous
testbed; overhead = time spent in WRATH analysis/decisions / makespan.
The ``proactive`` rows run the same workload with the sentinel attached
(its dispatch checks, retry reviews and periodic sweeps are all counted
into the overhead) — the acceptance bar is staying within 2x of the
reactive overhead ratio.
"""
from __future__ import annotations

from benchmarks.common import csv_row, mean_sem, run_once
from repro.engine import Cluster
from repro.injection import FailureInjector

APPS = ("mapreduce", "cholesky", "docking", "moldesign", "fedlearn")


def run(repeats: int = 5, rate: float = 0.1) -> list[str]:
    rows: list[str] = []
    pooled: dict[str, list[float]] = {"wrath": [], "proactive": []}
    for app in APPS:
        # throwaway warm-up: JIT compiles and thread spin-up must not
        # inflate the first measured mode's makespan (which would deflate
        # its overhead ratio and skew the reactive/proactive comparison)
        run_once(app, mode="proactive",
                 injector=FailureInjector("memory", rate=rate, seed=9,
                                          app_tag=f"f5:warmup:{app}"),
                 cluster_fn=lambda: Cluster.paper_testbed(small_nodes=3,
                                                          big_nodes=1),
                 default_pool="small-mem", retries=3)
        for mode in ("wrath", "proactive"):
            overheads, makespans = [], []
            for r in range(repeats):
                inj = FailureInjector("memory", rate=rate, seed=r,
                                      app_tag=f"f5:{app}:{r}")
                res = run_once(
                    app, mode=mode, injector=inj,
                    cluster_fn=lambda: Cluster.paper_testbed(small_nodes=3,
                                                             big_nodes=1),
                    default_pool="small-mem", retries=3)
                if res.success:
                    overheads.append(res.overhead_ratio)
                    makespans.append(res.makespan)
            pooled[mode].extend(overheads)
            tag = "" if mode == "wrath" else "_proactive"
            if overheads:
                m, sem = mean_sem(overheads)
                mk, _ = mean_sem(makespans)
                rows.append(csv_row(f"fig5_overhead{tag}_{app}", mk * 1e6,
                                    f"overhead_ratio={m:.5f}±{sem:.5f}"))
            else:
                rows.append(csv_row(f"fig5_overhead{tag}_{app}", 0.0,
                                    "no_successful_runs"))
    if pooled["wrath"] and pooled["proactive"]:
        # pooled across apps: per-app ratios of sub-1% numbers on ~20ms
        # makespans are noise-bound (a single GC/compile stall inside one
        # timed handler window dwarfs the signal), so the acceptance bar
        # (proactive within 2x of reactive) reads off pooled *medians*
        import statistics
        mw = statistics.median(pooled["wrath"])
        mp = statistics.median(pooled["proactive"])
        rows.append(csv_row(
            "fig5_overhead_proactive_vs_wrath", 0.0,
            f"pooled_median_ratio={mp / max(mw, 1e-9):.3f};"
            f"wrath={mw:.5f};proactive={mp:.5f}"))
    return rows
