"""Fig 5: WRATH overhead ratio on successful runs (paper: < 2%).

Failure rate 0.1 of resolvable (memory) failures on the heterogeneous
testbed; overhead = time spent in WRATH analysis/decisions / makespan.
"""
from __future__ import annotations

from benchmarks.common import csv_row, mean_sem, run_once
from repro.engine import Cluster
from repro.injection import FailureInjector

APPS = ("mapreduce", "cholesky", "docking", "moldesign", "fedlearn")


def run(repeats: int = 3, rate: float = 0.1) -> list[str]:
    rows: list[str] = []
    for app in APPS:
        overheads, makespans = [], []
        for r in range(repeats):
            inj = FailureInjector("memory", rate=rate, seed=r,
                                  app_tag=f"f5:{app}:{r}")
            res = run_once(
                app, mode="wrath", injector=inj,
                cluster_fn=lambda: Cluster.paper_testbed(small_nodes=3,
                                                         big_nodes=1),
                default_pool="small-mem", retries=3)
            if res.success:
                overheads.append(res.overhead_ratio)
                makespans.append(res.makespan)
        if overheads:
            m, sem = mean_sem(overheads)
            mk, _ = mean_sem(makespans)
            rows.append(csv_row(f"fig5_overhead_{app}", mk * 1e6,
                                f"overhead_ratio={m:.5f}±{sem:.5f}"))
        else:
            rows.append(csv_row(f"fig5_overhead_{app}", 0.0, "no_successful_runs"))
    return rows
