"""Shared benchmark utilities: repeated app runs + CSV emission."""
from __future__ import annotations

import statistics
from typing import Any, Callable

from repro.apps import run_app
from repro.core import MonitoringDatabase
from repro.engine.policies import ProactivePolicy, WrathPolicy


def repeated(fn: Callable[[int], Any], repeats: int) -> list[Any]:
    return [fn(i) for i in range(repeats)]


def mean_sem(xs: list[float]) -> tuple[float, float]:
    if len(xs) <= 1:
        return (xs[0] if xs else 0.0), 0.0
    return statistics.mean(xs), statistics.stdev(xs) / len(xs) ** 0.5


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"


def run_once(app: str, *, mode: str, injector, cluster_fn, default_pool,
             scale: str = "tiny", retries: int = 2, timeout: float = 120.0):
    """One app run in ``mode``: "baseline" (Parsl default retry), "wrath"
    (reactive resilience module) or "proactive" (wrath + sentinel) —
    expressed as the equivalent policy stacks of the task-hierarchy API."""
    policy = {
        "baseline": [],
        "wrath": [WrathPolicy()],
        "proactive": [WrathPolicy(), ProactivePolicy()],
    }[mode]
    return run_app(app, cluster_fn(), policy=policy,
                   monitor=MonitoringDatabase(), injector=injector,
                   scale=scale, default_pool=default_pool,
                   default_retries=retries, wait_timeout=timeout)
