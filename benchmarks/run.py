"""Benchmark harness: one module per paper table/figure + roofline +
training-plane recovery.  Prints ``name,us_per_call,derived`` CSV.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run fig4 fig8  # a subset
"""
from __future__ import annotations

import sys
import time

from benchmarks import (
    fig4_time_to_failure,
    fig5_overhead,
    fig6_scalability,
    fig7_overhead_scaling,
    fig8_failure_rate,
    roofline,
    table4_success_rates,
    train_recovery,
)

SUITES = {
    "fig4": fig4_time_to_failure.run,
    "fig4_proactive": fig4_time_to_failure.run_proactive,
    "fig5": fig5_overhead.run,
    "table4": table4_success_rates.run,
    "fig6": fig6_scalability.run,
    "fig6_sched": fig6_scalability.run_schedulers,
    "fig7": fig7_overhead_scaling.run,
    "fig8": fig8_failure_rate.run,
    "roofline": roofline.run,
    "train_recovery": train_recovery.run,
}


def main() -> None:
    picks = [a for a in sys.argv[1:] if a in SUITES] or list(SUITES)
    print("name,us_per_call,derived")
    for name in picks:
        t0 = time.time()
        try:
            for row in SUITES[name]():
                print(row, flush=True)
        except Exception as e:  # noqa: BLE001 - one suite must not kill the run
            print(f"{name}_ERROR,0.0,{type(e).__name__}:{e}", flush=True)
        print(f"{name}_wall,{(time.time() - t0) * 1e6:.0f},suite_seconds="
              f"{time.time() - t0:.1f}", flush=True)


if __name__ == "__main__":
    main()
