"""Benchmark harness: one module per paper table/figure + roofline +
training-plane recovery.  Prints ``name,us_per_call,derived`` CSV and
writes one machine-readable ``BENCH_<suite>.json`` per suite (rows +
parsed metrics: makespans, task/app success rates, normalized TTF, ...)
so CI can archive the perf trajectory PR over PR.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run fig4 fig8  # a subset
    BENCH_OUT=artifacts/ ...                           # JSON output dir
"""
from __future__ import annotations

import json
import os
import re
import sys
import time
from pathlib import Path

from benchmarks import (
    chaos_search,
    engine_throughput,
    fig4_time_to_failure,
    fig5_overhead,
    fig6_scalability,
    fig7_overhead_scaling,
    fig8_failure_rate,
    kernels,
    roofline,
    serve,
    table4_success_rates,
    train_recovery,
)

SUITES = {
    "engine_throughput": engine_throughput.run,
    "chaos_search": chaos_search.run,
    "kernels": kernels.run,
    "fig4": fig4_time_to_failure.run,
    "fig4_proactive": fig4_time_to_failure.run_proactive,
    "fig5": fig5_overhead.run,
    "table4": table4_success_rates.run,
    "fig6": fig6_scalability.run,
    "fig6_sched": fig6_scalability.run_schedulers,
    "fig7": fig7_overhead_scaling.run,
    "fig8": fig8_failure_rate.run,
    "roofline": roofline.run,
    "serve": serve.run,
    "train_recovery": train_recovery.run,
}

# derived fields look like "normalized_ttf=0.430±0.012" or "makespan=1.2";
# capture the key and the leading float (the ±sem tail stays in the row)
_METRIC_RE = re.compile(r"([A-Za-z_][\w.]*)=(-?\d+(?:\.\d+)?(?:e-?\d+)?)")


def _parse_row(row: str) -> dict:
    name, _, rest = row.partition(",")
    us, _, derived = rest.partition(",")
    try:
        us_val = float(us)
    except ValueError:
        us_val = None
    return {
        "name": name,
        "us_per_call": us_val,
        "derived": derived,
        "metrics": {k: float(v) for k, v in _METRIC_RE.findall(derived)},
    }


def write_suite_json(out_dir: str | Path, suite: str, rows: list[str], *,
                     wall_seconds: float, error: str | None = None) -> Path:
    """Persist one suite's results as ``BENCH_<suite>.json``.

    Per-row metrics are parsed out of the derived field; a top-level
    ``metrics`` map aggregates them as ``<row>.<key>`` so downstream
    tooling can diff runs without re-parsing CSV.
    """
    parsed = [_parse_row(r) for r in rows]
    payload = {
        "suite": suite,
        "wall_seconds": round(wall_seconds, 3),
        "error": error,
        "rows": parsed,
        "metrics": {f"{p['name']}.{k}": v
                    for p in parsed for k, v in p["metrics"].items()},
    }
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    path = out / f"BENCH_{suite}.json"
    path.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
    return path


def main() -> None:
    picks = [a for a in sys.argv[1:] if a in SUITES] or list(SUITES)
    out_dir = os.environ.get("BENCH_OUT", ".")
    print("name,us_per_call,derived")
    for name in picks:
        rows: list[str] = []
        error: str | None = None
        t0 = time.time()
        try:
            for row in SUITES[name]():
                print(row, flush=True)
                rows.append(row)
        except Exception as e:  # noqa: BLE001 - one suite must not kill the run
            error = f"{type(e).__name__}:{e}"
            print(f"{name}_ERROR,0.0,{error}", flush=True)
        wall = time.time() - t0
        print(f"{name}_wall,{wall * 1e6:.0f},suite_seconds={wall:.1f}",
              flush=True)
        write_suite_json(out_dir, name, rows, wall_seconds=wall, error=error)


if __name__ == "__main__":
    main()
