"""Table IV: task & retry success rates, MapReduce, import/memory failures.

Paper: WRATH retry SR 0.53/0.75 and task SR 0.43/0.47 vs baseline 0.22/0.24
retry SR and 0.00 task SR (tasks can only succeed on the right executor).
"""
from __future__ import annotations

from benchmarks.common import csv_row, mean_sem, run_once
from repro.engine import Cluster
from repro.injection import FailureInjector


def _cluster(failure: str) -> Cluster:
    if failure == "import":
        return Cluster.paper_testbed(small_nodes=3, big_nodes=1,
                                     with_pkg_pool=True, package="wrathpkg")
    return Cluster.paper_testbed(small_nodes=3, big_nodes=1)


def _pool(failure: str) -> str:
    return "no-pkg" if failure == "import" else "small-mem"


def run(repeats: int = 4, rate: float = 0.4) -> list[str]:
    rows: list[str] = []
    for failure in ("import", "memory"):
        for mode in ("wrath", "baseline"):
            task_srs, retry_srs = [], []
            for r in range(repeats):
                inj = FailureInjector(failure, rate=rate, seed=r,
                                      app_tag=f"t4:{failure}:{r}")
                res = run_once("mapreduce", mode=mode, injector=inj,
                               cluster_fn=lambda f=failure: _cluster(f),
                               default_pool=_pool(failure), scale="small")
                task_srs.append(res.task_success_rate)
                retry_srs.append(res.retry_success_rate)
            t, ts = mean_sem(task_srs)
            rr, rs = mean_sem(retry_srs)
            rows.append(csv_row(
                f"table4_{mode}_{failure}", 0.0,
                f"retry_sr={rr:.3f}±{rs:.3f};task_sr={t:.3f}±{ts:.3f}"))
    return rows
