"""Engine throughput: submit→resolve tasks/sec on the simulation executor.

The dispatch-path benchmark behind the batched-dispatch + work-stealing
engine work: N zero-duration tasks are submitted to a virtual-clock
engine on an 8-node sim cluster and driven to resolution; every second
of wall time is engine overhead (placement, bookkeeping, future
resolution), none of it is task work.  Reported per scale:

* ``tasks_per_sec`` — N / wall for the whole submit→resolve cycle
  (best of ``repeats`` runs: the engine's capability, robust to
  allocator/machine noise);
* ``p99_submit_us`` — 99th-percentile latency of one ``dfk.submit``
  call in the best run, the head-of-line cost the batched dispatch
  queue is designed to bound;
* ``speedup`` — vs the committed pre-optimization baseline row
  (``engine_tp_before_*``), measured on the same machine at the commit
  that introduced this suite.

``engine_steal_*`` rows measure what stealing buys: the same task mix
on a skewed cluster (two full-speed nodes, two 4× stragglers) placed
round-robin, with and without work stealing.  Makespan is *virtual*
seconds — fully deterministic, so the row doubles as a regression check
that stealing keeps rescuing the backlog (and ``steals=0`` when off).
"""
from __future__ import annotations

import gc
import time

from benchmarks.common import csv_row
from repro.engine.dfk import DataFlowKernel
from repro.engine.task import ResourceSpec, TaskDef
from repro.sim.clock import VirtualClock
from repro.sim.cluster import Node, ResourcePool, SimCluster, SimExecutor

# Pre-optimization throughput (commit bc20def^ engine: per-task dispatch
# events, per-future condition objects, no batched bookkeeping), measured
# by this same harness on the machine that produced the committed
# BENCH_engine_throughput.json.  Kept as emitted rows so the before/after
# pair travels together in one artifact.
BASELINE = {
    1_000: (13_090.0, 72.3),
    10_000: (14_723.0, 70.3),
    100_000: (9_328.0, 115.0),
}


def _noop(i: int) -> int:
    return i


def _one_run(n: int) -> tuple[float, float]:
    """One submit→resolve cycle; returns (tasks_per_sec, p99_submit_us)."""
    # drop the previous run's garbage first: live-heap pressure (not GC
    # pauses) is the dominant cross-run interference at the 100k scale
    gc.collect()
    clock = VirtualClock()
    cluster = SimCluster.homogeneous(8, workers_per_node=4)
    td = TaskDef(_noop, "noop", ResourceSpec(memory_gb=0.0), 0)
    lat = []
    with DataFlowKernel(cluster, clock=clock,
                        executor_factory=SimExecutor.factory(None)) as dfk:
        t0 = time.perf_counter()
        for i in range(n):
            s = time.perf_counter()
            dfk.submit(td, (i,), {})
            lat.append(time.perf_counter() - s)
        ok = dfk.wait_all(timeout=3600.0)
        wall = time.perf_counter() - t0
        if not ok or dfk.stats["completed"] != n:
            raise RuntimeError(f"throughput run incomplete: {dfk.stats}")
    lat.sort()
    p99 = lat[min(int(0.99 * n), n - 1)] * 1e6
    return n / wall, p99


def _skewed_steal_run(*, work_stealing: bool, n_tasks: int = 64,
                      duration_s: float = 2.0) -> tuple[float, int]:
    """Round-robin on a skewed sim cluster; returns (virtual makespan, steals)."""
    clock = VirtualClock()
    nodes = [Node(name="fast0", speed=1.0, workers_per_node=1),
             Node(name="fast1", speed=1.0, workers_per_node=1),
             Node(name="slug0", speed=0.25, workers_per_node=1),
             Node(name="slug1", speed=0.25, workers_per_node=1)]
    cluster = SimCluster([ResourcePool("skew", nodes)])
    td = TaskDef(_noop, "unit", ResourceSpec(memory_gb=0.0), 0)
    with DataFlowKernel(cluster, clock=clock,
                        executor_factory=SimExecutor.factory(
                            {"unit": duration_s}),
                        work_stealing=work_stealing) as dfk:
        t0 = clock.now()
        for i in range(n_tasks):
            dfk.submit(td, (i,), {})
        if not dfk.wait_all(timeout=100_000.0):
            raise RuntimeError("steal run did not finish")
        makespan = clock.now() - t0
        steals = int(dfk.stats.get("steals", 0))
    return makespan, steals


def run(scales: tuple[int, ...] = (1_000, 10_000, 100_000),
        repeats: int = 3) -> list[str]:
    rows: list[str] = []
    for n in scales:
        best_tps, best_p99 = 0.0, 0.0
        for _ in range(repeats):
            tps, p99 = _one_run(n)
            if tps > best_tps:
                best_tps, best_p99 = tps, p99
        base_tps, base_p99 = BASELINE.get(n, (0.0, 0.0))
        if base_tps:
            rows.append(csv_row(
                f"engine_tp_before_{n}", 0.0,
                f"tasks_per_sec={base_tps:.0f} p99_submit_us={base_p99:.1f}"))
        speedup = best_tps / base_tps if base_tps else 0.0
        rows.append(csv_row(
            f"engine_tp_{n}", 1e6 / best_tps,
            f"tasks_per_sec={best_tps:.0f} p99_submit_us={best_p99:.1f} "
            f"speedup={speedup:.2f}"))
    for stealing in (False, True):
        makespan, steals = _skewed_steal_run(work_stealing=stealing)
        rows.append(csv_row(
            f"engine_steal_{'on' if stealing else 'off'}", 0.0,
            f"makespan_virtual_s={makespan:.2f} steals={steals}"))
    return rows
