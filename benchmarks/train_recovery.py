"""Training-plane resilience benchmark (beyond-paper, DESIGN.md §2).

Measures: (a) supervision overhead of WRATH on a failure-free run,
(b) recovery cost (extra wall time + replayed steps) under injected
host-loss / NaN / straggler events, (c) that the loss trajectory still
converges.
"""
from __future__ import annotations

import shutil
import time

from benchmarks.common import csv_row
from repro.configs import get_smoke_config
from repro.optim import OptConfig
from repro.train import TrainEvent, WrathTrainSupervisor


def _mk(tag: str, steps: int = 30):
    shutil.rmtree(f"/tmp/wrath_bench_{tag}", ignore_errors=True)
    cfg = get_smoke_config("granite_3_2b")
    return WrathTrainSupervisor(
        cfg, OptConfig(lr=5e-3, warmup_steps=5, total_steps=steps),
        n_hosts=3, global_batch=6, seq_len=32,
        ckpt_dir=f"/tmp/wrath_bench_{tag}", ckpt_every=5)


def run(steps: int = 30) -> list[str]:
    rows: list[str] = []
    # (a) failure-free
    sup = _mk("clean", steps)
    t0 = time.time()
    rep = sup.run(steps)
    clean_s = time.time() - t0
    rows.append(csv_row("train_clean", clean_s / max(rep.steps_completed, 1) * 1e6,
                        f"loss={rep.losses[0]:.3f}->{rep.losses[-1]:.3f}"))
    # (b) faulted
    sup = _mk("fault", steps)
    events = [TrainEvent(step=8, kind="host_down", host="host01"),
              TrainEvent(step=15, kind="nan"),
              TrainEvent(step=22, kind="straggler", host="host02", factor=30)]
    t0 = time.time()
    rep = sup.run(steps, events=events)
    fault_s = time.time() - t0
    rows.append(csv_row(
        "train_faulted", fault_s / max(rep.steps_completed, 1) * 1e6,
        f"loss={rep.losses[0]:.3f}->{rep.losses[-1]:.3f};restores={rep.restores};"
        f"speculations={rep.speculations};recoveries={len(rep.recoveries)};"
        f"slowdown={fault_s / max(clean_s, 1e-9):.2f}x"))
    return rows
