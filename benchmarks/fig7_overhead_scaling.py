"""Fig 7: WRATH overhead ratio vs cluster size (paper: flat, < 2%)."""
from __future__ import annotations

from benchmarks.common import csv_row, mean_sem, run_once
from repro.engine import Cluster
from repro.injection import FailureInjector


def run(repeats: int = 3, rate: float = 0.1,
        sizes: tuple[int, ...] = (2, 4, 8, 16)) -> list[str]:
    rows: list[str] = []
    for n_nodes in sizes:
        overheads = []
        for r in range(repeats):
            inj = FailureInjector("memory", rate=rate, seed=r,
                                  app_tag=f"f7:{n_nodes}:{r}")
            res = run_once(
                "mapreduce", mode="wrath", injector=inj,
                cluster_fn=lambda n=n_nodes: Cluster.paper_testbed(
                    small_nodes=n, big_nodes=1),
                default_pool="small-mem", retries=3, scale="small")
            if res.success:
                overheads.append(res.overhead_ratio)
        m, sem = mean_sem(overheads) if overheads else (0.0, 0.0)
        rows.append(csv_row(f"fig7_overhead_nodes{n_nodes}", 0.0,
                            f"overhead_ratio={m:.5f}±{sem:.5f}"))
    return rows
