"""Fig 6: application success rate vs number of inadequate nodes — plus a
scheduler-comparison mode.

The number of nodes lacking memory (or the package) grows; one adequate
node remains.  Paper: WRATH keeps app success > 90% at every size;
baseline fails continuously.

``run_schedulers`` (also ``python -m benchmarks.run fig6_sched``) compares
the pluggable placement policies on a *skewed-speed* cluster (three
full-speed nodes + one 8x straggler): round-robin keeps feeding the slug
1/4 of the work, while the least-loaded and history-aware schedulers
observe the backlog (resp. the slow history) and steer around it, cutting
makespan.
"""
from __future__ import annotations

import time

from benchmarks.common import csv_row, mean_sem, run_once
from repro.core import MonitoringDatabase
from repro.engine import Cluster, DataFlowKernel, Node, ResourcePool, make_scheduler, task
from repro.engine.cluster import simwork
from repro.injection import FailureInjector


def _cluster(failure: str, bad_nodes: int) -> Cluster:
    if failure == "import":
        return Cluster.paper_testbed(small_nodes=bad_nodes, big_nodes=1,
                                     with_pkg_pool=True, package="wrathpkg")
    return Cluster.paper_testbed(small_nodes=bad_nodes, big_nodes=1)


def run(repeats: int = 4, rate: float = 0.3,
        sizes: tuple[int, ...] = (2, 4, 8)) -> list[str]:
    rows: list[str] = []
    for failure in ("import", "memory"):
        pool = "no-pkg" if failure == "import" else "small-mem"
        for n_bad in sizes:
            for mode in ("wrath", "baseline"):
                successes = []
                for r in range(repeats):
                    inj = FailureInjector(failure, rate=rate, seed=r,
                                          app_tag=f"f6:{failure}:{n_bad}:{r}")
                    res = run_once("mapreduce", mode=mode, injector=inj,
                                   cluster_fn=lambda f=failure, n=n_bad: _cluster(f, n),
                                   default_pool=pool, retries=3)
                    successes.append(1.0 if res.success else 0.0)
                m, sem = mean_sem(successes)
                rows.append(csv_row(
                    f"fig6_appsr_{failure}_{mode}_nodes{n_bad}", 0.0,
                    f"app_success_rate={m:.3f}±{sem:.3f}"))
    return rows


def _skewed_cluster(slug_speed: float) -> Cluster:
    nodes = [Node(f"fast{i}", speed=1.0, workers_per_node=1) for i in range(3)]
    nodes.append(Node("slug", speed=slug_speed, workers_per_node=1))
    return Cluster([ResourcePool("skew", nodes)])


def run_schedulers(repeats: int = 3, n_tasks: int = 24,
                   work_s: float = 0.05, slug_speed: float = 0.125,
                   backpressure: int = 8) -> list[str]:
    """Scheduler-comparison mode: makespan per placement policy on the
    skewed-speed cluster, submitted as one batched ``DataFlowKernel.map``
    sweep under backpressure."""
    rows: list[str] = []
    for name in ("round_robin", "least_loaded", "history"):
        makespans = []
        for _ in range(repeats):
            mon = MonitoringDatabase()
            with DataFlowKernel(_skewed_cluster(slug_speed), monitor=mon,
                                scheduler=make_scheduler(name),
                                map_backpressure=backpressure) as dfk:
                @task(est_duration_s=work_s)
                def unit(i):
                    simwork(work_s)
                    return i

                t0 = time.time()
                futs = dfk.map(unit, range(n_tasks))
                for f in futs:
                    f.result(timeout=120)
                makespans.append(time.time() - t0)
        m, sem = mean_sem(makespans)
        rows.append(csv_row(f"fig6_sched_{name}", 0.0,
                            f"makespan_s={m:.3f}±{sem:.3f}"))
    return rows
