"""Fig 6: application success rate vs number of inadequate nodes.

The number of nodes lacking memory (or the package) grows; one adequate
node remains.  Paper: WRATH keeps app success > 90% at every size;
baseline fails continuously.
"""
from __future__ import annotations

from benchmarks.common import csv_row, mean_sem, run_once
from repro.engine import Cluster
from repro.injection import FailureInjector


def _cluster(failure: str, bad_nodes: int) -> Cluster:
    if failure == "import":
        return Cluster.paper_testbed(small_nodes=bad_nodes, big_nodes=1,
                                     with_pkg_pool=True, package="wrathpkg")
    return Cluster.paper_testbed(small_nodes=bad_nodes, big_nodes=1)


def run(repeats: int = 4, rate: float = 0.3,
        sizes: tuple[int, ...] = (2, 4, 8)) -> list[str]:
    rows: list[str] = []
    for failure in ("import", "memory"):
        pool = "no-pkg" if failure == "import" else "small-mem"
        for n_bad in sizes:
            for mode in ("wrath", "baseline"):
                successes = []
                for r in range(repeats):
                    inj = FailureInjector(failure, rate=rate, seed=r,
                                          app_tag=f"f6:{failure}:{n_bad}:{r}")
                    res = run_once("mapreduce", mode=mode, injector=inj,
                                   cluster_fn=lambda f=failure, n=n_bad: _cluster(f, n),
                                   default_pool=pool, retries=3)
                    successes.append(1.0 if res.success else 0.0)
                m, sem = mean_sem(successes)
                rows.append(csv_row(
                    f"fig6_appsr_{failure}_{mode}_nodes{n_bad}", 0.0,
                    f"app_success_rate={m:.3f}±{sem:.3f}"))
    return rows
