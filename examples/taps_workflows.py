"""Run the five TaPS-analog applications under failure injection.

Reproduces the paper's experimental setup in miniature: pick an app, a
failure type and a rate; compare resilience-policy stacks — WRATH
(``[WrathPolicy()]``) against Parsl-style baseline retry (the empty
stack).  Each app run executes inside a :class:`~repro.api.Workflow`
scope named after the app (see ``repro.apps.base.run_app``).

    PYTHONPATH=src python examples/taps_workflows.py --failure memory --rate 0.3
    PYTHONPATH=src python examples/taps_workflows.py --app cholesky \
        --failure zero_division --rate 0.2
"""
import argparse

from repro.api import Cluster, MonitoringDatabase, WrathPolicy
from repro.apps import APPS, run_app
from repro.injection import FAILURE_TYPES, FailureInjector, NoInjector


def cluster_for(failure: str) -> tuple[Cluster, str | None]:
    if failure == "import":
        return (Cluster.paper_testbed(small_nodes=3, big_nodes=1,
                                      with_pkg_pool=True, package="wrathpkg"),
                "no-pkg")
    if failure in ("memory", "ulimit"):
        cl = Cluster.paper_testbed(small_nodes=3, big_nodes=1)
        if failure == "ulimit":
            for n in cl.pools["big-mem"].nodes:
                n.ulimit_files = 2_000_000
        return cl, "small-mem"
    return Cluster.homogeneous(4), None


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--app", default="all", choices=["all", *sorted(APPS)])
    ap.add_argument("--failure", default="memory",
                    choices=["none", *FAILURE_TYPES])
    ap.add_argument("--rate", type=float, default=0.3)
    ap.add_argument("--scale", default="small")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    apps = sorted(APPS) if args.app == "all" else [args.app]
    hdr = (f"{'app':12s} {'mode':9s} {'ok':3s} {'makespan':>9s} {'ttf':>8s} "
           f"{'task_sr':>8s} {'retry_sr':>9s} {'overhead':>9s}")
    print(hdr)
    print("-" * len(hdr))
    for app in apps:
        for mode in ("wrath", "baseline"):
            cl, pool = cluster_for(args.failure)
            inj = (NoInjector() if args.failure == "none" else
                   FailureInjector(args.failure, rate=args.rate,
                                   seed=args.seed, app_tag=f"{app}:{mode}"))
            r = run_app(app, cl,
                        policy=[WrathPolicy()] if mode == "wrath" else [],
                        monitor=MonitoringDatabase(), injector=inj,
                        scale=args.scale, default_pool=pool,
                        default_retries=2, wait_timeout=120)
            ttf = f"{r.time_to_failure:.3f}" if r.time_to_failure else "-"
            print(f"{app:12s} {mode:9s} {'Y' if r.success else 'N':3s} "
                  f"{r.makespan:9.3f} {ttf:>8s} {r.task_success_rate:8.3f} "
                  f"{r.retry_success_rate:9.3f} {r.overhead_ratio:9.5f}")


if __name__ == "__main__":
    main()
