"""End-to-end WRATH-supervised training with injected failures.

Trains a reduced-config model (any of the 10 assigned architectures) with
the WRATH training supervisor while the run is hit by a host loss, a NaN
loss, and a chronic straggler.  The run checkpoint-restarts, elastically
re-meshes, denylists the straggler — and the loss still goes down.

    PYTHONPATH=src python examples/resilient_training.py \
        --arch granite-3-2b --steps 120 --d-model 256 --layers 4

Scale --d-model/--layers up toward ~100M params if you have minutes to
spare; the recovery behaviour is identical at every scale.
"""
import argparse
import shutil

from repro.api import WrathPolicy, replay
from repro.configs import get_smoke_config
from repro.optim import OptConfig
from repro.train import TrainEvent, WrathTrainSupervisor


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--hosts", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt", default="/tmp/wrath_resilient_training")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    heads = max(4, cfg.n_heads)
    cfg = cfg.scaled(d_model=args.d_model, n_layers=args.layers)

    shutil.rmtree(args.ckpt, ignore_errors=True)
    sup = WrathTrainSupervisor(
        cfg, OptConfig(lr=3e-3, warmup_steps=10, total_steps=args.steps),
        n_hosts=args.hosts, global_batch=args.batch, seq_len=args.seq,
        ckpt_dir=args.ckpt, ckpt_every=10,
        # composable stack: two HPX-style replays first, then WRATH's
        # taxonomy-driven placement takes over (first decisive wins)
        policy=[replay(2, on_exhausted="defer"), WrathPolicy()])

    third = args.steps // 3
    events = [
        TrainEvent(step=third, kind="host_down", host="host01"),
        TrainEvent(step=third + 10, kind="nan"),
        TrainEvent(step=2 * third, kind="straggler", host="host02", factor=40),
    ]
    print(f"training {cfg.name} (reduced: d={cfg.d_model}, L={cfg.n_layers}) "
          f"for {args.steps} steps on {args.hosts} virtual hosts; injecting "
          f"host-loss @ {third}, NaN @ {third+10}, straggler @ {2*third}")
    rep = sup.run(args.steps, events=events)

    print(f"\nsteps completed: {rep.steps_completed}")
    print(f"loss: {rep.losses[0]:.3f} -> {rep.losses[-1]:.3f}")
    print(f"checkpoint restores: {rep.restores}, speculations: "
          f"{rep.speculations}, denylisted: {rep.denylisted}, "
          f"surviving hosts: {rep.final_hosts}")
    print("\nrecovery log:")
    for r in rep.recoveries:
        print(f"  step {r['step']:4d} {r['error']:28s} on {r['host']:8s} "
              f"-> {r['action']} (rung {r['rung']})")
    assert rep.losses[-1] < rep.losses[0], "loss did not improve"
    print("\nresilient training complete — loss improved through failures.")


if __name__ == "__main__":
    main()
