"""Quickstart: the WRATH-enabled TBPP engine in ~60 lines.

Builds the paper's §VII-C heterogeneous testbed (192 GB nodes + one 6 TB
node), runs a small task DAG, and injects a memory-hungry task that OOMs
on the default pool.  Watch WRATH categorize the failure (runtime layer →
resource starvation → capacity mismatch) and hierarchically retry onto
the big-memory pool (rung 4), while the same failure kills the run under
Parsl-style baseline retry.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.apps.base import run_app  # noqa: F401  (import check)
from repro.core import MonitoringDatabase, wrath_retry_handler
from repro.engine import Cluster, DataFlowKernel, task


@task(memory_gb=1)
def tokenize(doc: str) -> list[str]:
    return doc.split()


@task(memory_gb=200)          # needs more than the 192 GB default nodes
def embed_corpus(tokens: list[str]) -> dict[str, float]:
    return {t: float(len(t)) for t in tokens}


@task(memory_gb=1)
def top_word(emb: dict[str, float]) -> str:
    return max(emb, key=emb.get)


def main() -> None:
    cluster = Cluster.paper_testbed(small_nodes=3, big_nodes=1)
    monitor = MonitoringDatabase()
    handler = wrath_retry_handler()

    with DataFlowKernel(cluster, monitor=monitor, retry_handler=handler,
                        default_pool="small-mem", default_retries=2) as dfk:
        toks = tokenize("wrath makes task based parallel programming resilient")
        emb = embed_corpus(toks)     # OOMs on small-mem, recovers on big-mem
        best = top_word(emb)
        print("longest word:", best.result(timeout=30))
        print("\nWRATH decisions:")
        for d in handler.decisions:
            print(f"  [{d['layer']}/{d['failure_type']}] -> {d['action']} "
                  f"(rung {d['rung']}): {d['reason'][:80]}")
        print("\nstats:", {k: round(v, 4) for k, v in dfk.stats.items() if v})

    # same workload, Parsl-style baseline: retries in place and fails
    from repro.core import DependencyError

    with DataFlowKernel(Cluster.paper_testbed(small_nodes=3, big_nodes=1),
                        monitor=MonitoringDatabase(),
                        default_pool="small-mem", default_retries=2) as dfk:
        try:
            top_word(embed_corpus(tokenize("same workload"))).result(timeout=30)
        except (MemoryError, DependencyError) as e:
            print(f"\nbaseline failed as expected after "
                  f"{dfk.stats['retries']:.0f} wasted retries: "
                  f"{type(e).__name__}: {e}")


if __name__ == "__main__":
    main()
