"""Quickstart: the task-hierarchy API in ~70 lines.

Builds the paper's §VII-C heterogeneous testbed (192 GB nodes + one 6 TB
node), then runs a small DAG inside a :class:`Workflow` scope with a
composable resilience-policy stack.  A memory-hungry task OOMs on the
default pool; watch WRATH categorize the failure (runtime layer →
resource starvation → capacity mismatch) and hierarchically retry onto
the big-memory pool (rung 4) — while the same workload under an empty
stack (Parsl-style baseline retry) burns its budget in place and dies.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.api import (
    Cluster,
    DataFlowKernel,
    DependencyError,
    MonitoringDatabase,
    WrathPolicy,
    replay,
    task,
)


@task(memory_gb=1)
def tokenize(doc: str) -> list[str]:
    return doc.split()


@task(memory_gb=200)          # needs more than the 192 GB default nodes
def embed_corpus(tokens: list[str]) -> dict[str, float]:
    return {t: float(len(t)) for t in tokens}


@task(memory_gb=1)
def top_word(emb: dict[str, float]) -> str:
    return max(emb, key=emb.get)


def main() -> None:
    cluster = Cluster.paper_testbed(small_nodes=3, big_nodes=1)
    wrath = WrathPolicy()

    with DataFlowKernel(cluster, monitor=MonitoringDatabase(),
                        policy=[wrath], default_pool="small-mem") as dfk:
        # a named scope: per-scope retry default, scope-wide wait()/stats()
        with dfk.workflow("quickstart", retries=2) as wf:
            toks = tokenize("wrath makes task based parallel programming resilient")
            emb = embed_corpus(toks)     # OOMs on small-mem, recovers on big-mem
            best = top_word(emb)
        print("longest word:", best.result(timeout=30))
        wf.wait(timeout=30)
        print("\nWRATH decisions:")
        for d in wrath.decisions:
            print(f"  [{d['layer']}/{d['failure_type']}] -> {d['action']} "
                  f"(rung {d['rung']}): {d['reason'][:80]}")
        print("\nscope stats:", wf.stats())
        print("engine stats:", {k: round(v, 4) for k, v in dfk.stats.items() if v})

    # same workload on an explicit baseline stack: replay(3) retries in
    # place — HPX-style task replay, no resource analysis — and fails
    with DataFlowKernel(Cluster.paper_testbed(small_nodes=3, big_nodes=1),
                        monitor=MonitoringDatabase(),
                        default_pool="small-mem") as dfk:
        try:
            doomed = embed_corpus.options(policy=replay(3))(tokenize("same workload"))
            top_word(doomed).result(timeout=30)
        except (MemoryError, DependencyError) as e:
            print(f"\nbaseline replay(3) failed as expected after "
                  f"{dfk.stats['retries']:.0f} wasted retries: "
                  f"{type(e).__name__}: {e}")


if __name__ == "__main__":
    main()
