"""Batched serving with WRATH replica failover.

Serves batched requests against a reduced model on three virtual replicas,
kills a replica mid-decode, and shows WRATH denylisting it and recovering
the in-flight batch (decode-state snapshot restore) on a healthy replica.

    PYTHONPATH=src python examples/serving.py --arch olmoe-1b-7b
"""
import argparse

import numpy as np

from repro.configs import get_smoke_config
from repro.serve import Request, WrathServeDriver


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--replicas", type=int, default=3)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    driver = WrathServeDriver(cfg, n_replicas=args.replicas, max_batch=4)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, size=6).tolist(),
                    max_new_tokens=args.new_tokens)
            for i in range(args.requests)]

    print(f"serving {len(reqs)} requests on {args.replicas} replicas of "
          f"{cfg.name} (reduced); killing replica0 mid-decode...")
    rep = driver.serve(reqs, kill_replica_at=("replica0", 5))

    print(f"\ncompleted: {rep.completed}/{len(reqs)}  failed: {rep.failed}")
    print(f"tokens generated: {rep.tokens_generated} "
          f"({rep.tokens_per_s:.1f} tok/s)")
    print(f"denylisted replicas: {rep.denylisted}")
    for r in rep.recoveries:
        print(f"  recovery: {r['replica']} died at decode step {r['step']} "
              f"-> {r['action']} (rung {r['rung']})")
    sample = reqs[0]
    print(f"\nrequest 0: prompt={sample.prompt} generated={sample.generated}")
    assert rep.completed == len(reqs), "not all requests completed"
    print("all requests completed despite replica loss.")


if __name__ == "__main__":
    main()
