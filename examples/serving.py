"""Production serving plane: continuous batching with WRATH failover.

Drives the full request plane — clock-stamped queue, SLO-aware admission,
continuous batcher, replica failover — against a reduced model on three
virtual replicas, killing one mid-traffic and showing every in-flight
request recovered on the survivors.  A second pass runs the same workload
through the static batcher to show the continuous plane's throughput win.

    PYTHONPATH=src python examples/serving.py --arch olmoe-1b-7b
"""
import argparse

import numpy as np

from repro.configs import get_smoke_config
from repro.serve import (Request, SLOAdmissionPolicy, WrathServeDriver)


def _requests(cfg, n, new_tokens, deadline_s=None):
    rng = np.random.default_rng(0)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, size=6).tolist(),
                    max_new_tokens=new_tokens,
                    deadline_s=deadline_s)
            for i in range(n)]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--replicas", type=int, default=3)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)

    # -- static baseline -------------------------------------------------
    static = WrathServeDriver(cfg, n_replicas=args.replicas, max_batch=4)
    reqs = _requests(cfg, args.requests, args.new_tokens)
    base = static.serve(reqs)
    print(f"static batcher: {base.completed}/{len(reqs)} requests, "
          f"{base.tokens_generated} tokens ({base.tokens_per_s:.1f} tok/s)")

    # -- continuous plane, replica killed mid-traffic --------------------
    driver = WrathServeDriver(cfg, n_replicas=args.replicas, max_batch=4,
                              admission=SLOAdmissionPolicy())
    reqs = _requests(cfg, args.requests, args.new_tokens, deadline_s=30.0)
    print(f"\ncontinuous plane: submitting {len(reqs)} requests on "
          f"{args.replicas} replicas of {cfg.name} (reduced); killing "
          f"replica0 mid-traffic...")
    rep = driver.serve_continuous(reqs, faults=[(0.05, "kill", "replica0")],
                                  horizon=120.0)
    driver.shutdown()

    print(f"\ncompleted: {rep.completed}/{len(reqs)}  failed: {rep.failed}  "
          f"rejected: {rep.rejected}  shed: {rep.shed}")
    print(f"tokens generated: {rep.tokens_generated} "
          f"({rep.requests_per_s:.1f} req/s, p50 {rep.p50_s*1e3:.0f}ms, "
          f"p99 {rep.p99_s*1e3:.0f}ms)")
    print(f"denylisted replicas: {rep.denylisted}")
    for r in rep.recoveries:
        print(f"  recovery: request {r['rid']} lost with {r['replica']} "
              f"-> {r['action']} (rung {r['rung']})")
    sample = reqs[0]
    print(f"\nrequest 0: prompt={sample.prompt} generated={sample.generated}")
    assert rep.completed == len(reqs), "not all requests completed"
    print("all requests completed despite replica loss.")


if __name__ == "__main__":
    main()
