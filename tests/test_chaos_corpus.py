"""Regression corpus: shrunk chaos repros replayed forever.

Every ``tests/chaos_corpus/*.json`` entry is a scenario the guided chaos
search (or a hand shrink) once minimized, promoted with the exact set of
invariant-violation *signatures* it must reproduce (``expect: []`` pins
a scenario that must stay clean).  Each entry runs twice — the traces
must match byte for byte — and its violation signatures must equal the
promoted expectation exactly: a fixed bug stays fixed, a pinned repro
stays reproducing, and any drift in either direction fails loudly.
"""
from pathlib import Path

import pytest

from repro.sim import load_corpus, run_scenario, violation_signature

CORPUS_DIR = Path(__file__).parent / "chaos_corpus"

ENTRIES = load_corpus(CORPUS_DIR)


def test_corpus_is_not_empty():
    assert ENTRIES, f"no corpus entries under {CORPUS_DIR}"
    # at least one pinned violation repro and one clean pin
    assert any(expect for _, _, expect, _ in ENTRIES)
    assert any(not expect for _, _, expect, _ in ENTRIES)


@pytest.mark.parametrize(
    "path,scenario,expect,note",
    ENTRIES,
    ids=[p.stem for p, _, _, _ in ENTRIES])
def test_corpus_entry_replays_exactly(path, scenario, expect, note):
    first = run_scenario(scenario)
    second = run_scenario(scenario)
    assert first.trace == second.trace, \
        f"{path.name}: corpus scenario is not deterministic"
    got = sorted({violation_signature(v) for v in first.violations})
    assert got == sorted(expect), (
        f"{path.name}: expected violation classes {sorted(expect)}, "
        f"got {got} ({note or 'no note'}); violations={first.violations}")
