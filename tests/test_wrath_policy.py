"""Resilience module behaviour: categorization, policy actions, retry ladder."""
import time

import pytest
from helpers import wait_until

from repro.core import MonitoringDatabase, wrath_retry_handler
from repro.core.categorization import FailureCategorizationEngine
from repro.core.failures import (
    DependencyError,
    FailureReport,
    HardwareShutdownError,
    RandomSeedError,
)
from repro.engine import Cluster, DataFlowKernel, task
from repro.engine.task import ResourceSpec, TaskDef, new_task_record


def _record(name="t", memory_gb=1.0, packages=(), retries=2):
    td = TaskDef(lambda: None, name, ResourceSpec(memory_gb=memory_gb,
                                                  packages=tuple(packages)), retries)
    return new_task_record(td, (), {}, default_retries=retries)


# -------------------------------------------------------- categorization --
def test_categorize_memory_capacity_mismatch():
    eng = FailureCategorizationEngine()
    rec = _record(memory_gb=200)
    rep = FailureReport.from_exception(
        MemoryError("cannot allocate"), task_id=rec.task_id, node="n0", pool="p",
        resource_profile={"node_memory_gb": 192.0, "node_mem_in_use_gb": 0.0},
        requirements=rec.resources.asdict())
    cat = eng.categorize(rec, rep)
    assert cat.resolvable
    assert cat.resource_related
    assert cat.required_memory_gb == 200
    assert "capacity" in cat.explanation


def test_categorize_transient_contention():
    eng = FailureCategorizationEngine()
    rec = _record(memory_gb=6)
    rep = FailureReport.from_exception(
        MemoryError("cannot allocate"), task_id=rec.task_id, node="n0", pool="p",
        resource_profile={"node_memory_gb": 8.0, "node_mem_in_use_gb": 6.0},
        requirements=rec.resources.asdict())
    cat = eng.categorize(rec, rep)
    assert cat.resolvable
    assert "contention" in cat.explanation


def test_categorize_env_mismatch_extracts_packages():
    eng = FailureCategorizationEngine()
    rec = _record(packages=("scipy",))
    rep = FailureReport.from_exception(
        ImportError("No module named 'scipy'"), task_id=rec.task_id, node="n0",
        pool="p", requirements=rec.resources.asdict())
    cat = eng.categorize(rec, rep)
    assert cat.resolvable
    assert "scipy" in cat.required_packages


def test_categorize_user_error_not_resolvable():
    eng = FailureCategorizationEngine()
    rec = _record()
    rep = FailureReport.from_exception(ZeroDivisionError("div"),
                                       task_id=rec.task_id)
    cat = eng.categorize(rec, rep)
    assert not cat.resolvable


def test_categorize_dependency_nonretriable_root_fails_fast():
    eng = FailureCategorizationEngine()
    rec = _record()
    err = DependencyError("parent failed", root_cause=ValueError("bad"))
    rep = FailureReport.from_exception(err, task_id=rec.task_id)
    cat = eng.categorize(rec, rep)
    assert not cat.resolvable


def test_categorize_hardware_denylists():
    eng = FailureCategorizationEngine()
    rec = _record()
    rep = FailureReport.from_exception(
        HardwareShutdownError("node down"), task_id=rec.task_id, node="n3")
    cat = eng.categorize(rec, rep)
    assert cat.resolvable
    assert cat.denylist_node


def test_fail_fast_heuristic_multi_node_multi_pool():
    eng = FailureCategorizationEngine(fail_fast_distinct_nodes=2)
    rec = _record(memory_gb=500)
    rec.attempts = [
        {"attempt": 0, "node": "a0", "pool": "p1", "worker": "w", "ok": False,
         "error": "MemoryError", "duration": 0.1, "time": 0},
        {"attempt": 1, "node": "b0", "pool": "p2", "worker": "w", "ok": False,
         "error": "MemoryError", "duration": 0.1, "time": 0},
    ]
    rep = FailureReport.from_exception(
        MemoryError("x"), task_id=rec.task_id, node="c0", pool="p3",
        resource_profile={"node_memory_gb": 192.0},
        requirements=rec.resources.asdict())
    cat = eng.categorize(rec, rep)
    assert not cat.resolvable  # recurred across pools -> fail fast


def test_random_seed_error_never_fails_fast():
    eng = FailureCategorizationEngine(fail_fast_distinct_nodes=2)
    rec = _record()
    rec.attempts = [
        {"attempt": i, "node": f"n{i}", "pool": "p", "worker": "w", "ok": False,
         "error": "RandomSeedError", "duration": 0.1, "time": 0}
        for i in range(2)]
    rep = FailureReport.from_exception(RandomSeedError("unlucky"),
                                       task_id=rec.task_id, node="n9", pool="p")
    cat = eng.categorize(rec, rep)
    assert cat.resolvable


# ------------------------------------------------------------- end to end --
def test_memory_failure_hierarchical_retry_to_big_pool():
    """§VII-C memory scenario: 200 GB task, 192 GB pool + 6 TB pool."""
    handler = wrath_retry_handler()
    mon = MonitoringDatabase()
    cluster = Cluster.paper_testbed(small_nodes=3, big_nodes=1)
    with DataFlowKernel(cluster, monitor=mon, retry_handler=handler,
                        default_pool="small-mem", default_retries=2) as dfk:
        @task(memory_gb=200)
        def hungry(x):
            return x + 1

        assert hungry(1).result(timeout=15) == 2
        assert dfk.stats["retry_success"] == 1
    # the decisive retry must have moved pools (rung 4)
    rungs = [d["rung"] for d in handler.decisions]
    assert 4 in rungs


def test_import_failure_hierarchical_retry_to_pkg_pool():
    handler = wrath_retry_handler()
    mon = MonitoringDatabase()
    cluster = Cluster.paper_testbed(small_nodes=3, big_nodes=1,
                                    with_pkg_pool=True, package="scipy")
    with DataFlowKernel(cluster, monitor=mon, retry_handler=handler,
                        default_pool="no-pkg", default_retries=2) as dfk:
        @task(packages=("scipy",))
        def needs(x):
            return x * 2

        assert needs(5).result(timeout=15) == 10
    assert any(d["failure_type"] == "env_mismatch" for d in handler.decisions)


def test_user_error_immediate_termination_no_retries():
    handler = wrath_retry_handler()
    with DataFlowKernel(Cluster.homogeneous(2), monitor=MonitoringDatabase(),
                        retry_handler=handler, default_retries=5) as dfk:
        @task
        def boom():
            raise ValueError("user bug")

        with pytest.raises(ValueError):
            boom().result(timeout=10)
        assert dfk.stats["retries"] == 0
    assert handler.decisions[-1]["action"] == "fail"


def test_dependency_children_fail_fast():
    handler = wrath_retry_handler()
    with DataFlowKernel(Cluster.homogeneous(2), monitor=MonitoringDatabase(),
                        retry_handler=handler, default_retries=5) as dfk:
        @task
        def parent():
            raise KeyError("parent bug")

        @task
        def child(x):
            return x

        c = child(parent())
        with pytest.raises(DependencyError):
            c.result(timeout=10)
        assert dfk.stats["retries"] == 0
        assert dfk.stats["dep_failed"] == 1


def test_random_seed_error_retries_in_place():
    handler = wrath_retry_handler()
    attempts = {"n": 0}
    with DataFlowKernel(Cluster.homogeneous(2), monitor=MonitoringDatabase(),
                        retry_handler=handler, default_retries=3) as dfk:
        @task
        def flaky():
            attempts["n"] += 1
            if attempts["n"] < 3:
                raise RandomSeedError("bad seed")
            return "ok"

        assert flaky().result(timeout=10) == "ok"
        assert dfk.stats["retries"] == 2
    assert all(d["action"] == "retry" for d in handler.decisions)


def test_denylist_added_on_shutdown_and_removed_on_resume():
    handler = wrath_retry_handler(heartbeat_resume_window=10.0)
    mon = MonitoringDatabase()
    cluster = Cluster.homogeneous(3, workers_per_node=1)
    with DataFlowKernel(cluster, monitor=mon, retry_handler=handler,
                        default_retries=3, heartbeat_period=0.03,
                        heartbeat_threshold=3) as dfk:
        @task
        def slow(x):
            time.sleep(0.25)
            return x

        futs = [slow(i) for i in range(3)]
        victim = cluster.all_nodes()[0]
        assert wait_until(lambda: all(f.record.start_time > 0 for f in futs),
                          timeout=5)
        victim.shutdown_hardware()
        for f in futs:
            f.result(timeout=30)
        assert victim.name in dfk.denylist
        # resurrect: wait for a heartbeat *after* the restore, then the
        # next decision refreshes the denylist
        t_restore = time.time()
        victim.restore_hardware()
        assert wait_until(
            lambda: mon.last_heartbeats().get(victim.name, 0) > t_restore,
            timeout=5)
        handler._refresh_denylist(dfk.context())
        assert victim.name not in dfk.denylist


def test_decision_log_records_rungs_and_layers():
    handler = wrath_retry_handler()
    cluster = Cluster.paper_testbed(small_nodes=2, big_nodes=1)
    with DataFlowKernel(cluster, monitor=MonitoringDatabase(),
                        retry_handler=handler, default_pool="small-mem",
                        default_retries=2) as dfk:
        @task(memory_gb=200)
        def hungry():
            return 1

        hungry().result(timeout=15)
    d = handler.decisions[0]
    assert d["layer"] == "runtime"
    assert d["failure_type"] == "resource_starvation"
    assert d["action"] in ("retry", "restart_retry")
