"""The static-analysis plane: fixtures detect, the repo stays clean.

Two halves.  Fixture tests pin each checker's exact rule codes and line
numbers against known-bad snippets (and prove the known-good parity
files produce nothing).  Repo tests are the contract itself: the full
suite over ``src/repro`` has zero non-baselined findings, every baseline
waiver is live, and the event registry matches the code — the same
gates CI runs via ``python -m repro.analysis --strict`` and
``--check-registry``.
"""
from __future__ import annotations

import json
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import find_modules, run_checks
from repro.analysis.clock_check import check_clock
from repro.analysis.event_check import check_events, extract_registry, registry_drift
from repro.analysis.findings import Baseline, Finding, split_baselined
from repro.analysis.hook_check import check_hooks
from repro.analysis.lock_check import check_locks

REPO = Path(__file__).resolve().parent.parent
SRC = REPO / "src" / "repro"
FIXTURES = Path(__file__).resolve().parent / "analysis_fixtures"
BASELINE = SRC / "analysis" / "analysis_baseline.json"


def _check(checker, fixture: str) -> list[Finding]:
    return checker(find_modules([FIXTURES / fixture]))


def _codes(findings: list[Finding]) -> list[tuple[str, int]]:
    return sorted((f.rule, f.line) for f in findings)


# --------------------------------------------------------------------- #
# fixture detection: exact rule codes at exact lines
# --------------------------------------------------------------------- #

def test_clock_fixture_detects_every_rule():
    assert _codes(_check(check_clock, "clock_bad.py")) == [
        ("CLK001", 13),   # _t.time()
        ("CLK002", 17),   # _t.sleep(0.5)
        ("CLK003", 21),   # datetime.now()
        ("CLK004", 25),   # random.random()
        ("CLK005", 30),   # default_factory=_t.time
    ]


def test_clock_parity_fixture_is_clean():
    assert _check(check_clock, "clock_good.py") == []


def test_lock_fixture_detects_every_rule():
    assert _codes(_check(check_locks, "lock_bad.py")) == [
        ("LCK001", 18),   # fut.set_result under _lock
        ("LCK001", 32),   # on_failure reachable via _notify
        ("LCK002", 22),   # fut.result under _lock
        ("LCK002", 23),   # time.sleep under _lock
        ("LCK003", 27),   # _queue_mutex under _lock
        ("LCK003", 45),   # a -> b
        ("LCK003", 50),   # b -> a
        ("LCK004", 45),   # the a/b ordering cycle
    ]


def test_lock_fixture_transitive_path_is_named():
    findings = _check(check_locks, "lock_bad.py")
    indirect = [f for f in findings if f.line == 32]
    assert len(indirect) == 1
    assert "via Engine._notify" in indirect[0].message


def test_lock_parity_fixture_is_clean():
    # condition-over-lock aliasing and Condition.wait are both exempt
    assert _check(check_locks, "lock_good.py") == []


def test_event_fixture_detects_every_rule():
    assert _codes(_check(check_events, "events_bad.py")) == [
        ("EVT001", 9),    # "submited" typo
        ("EVT001", 10),   # unregistered system event
        ("EVT001", 11),   # gauge typo
        ("EVT002", 12),   # unregistered f-string family
        ("EVT002", 14),   # dynamic name
    ]


def test_event_parity_fixture_is_clean():
    # literals, a registered prefix family, and an if-else of literals
    assert _check(check_events, "events_good.py") == []


def test_hook_fixture_detects_every_rule():
    assert _codes(_check(check_hooks, "hooks_bad.py")) == [
        ("HOK001", 19),   # p.on_failure with no degrade path
        ("HOK002", 15),   # raising hook override
    ]


def test_hook_parity_fixture_is_clean():
    # stack receiver and try/except both count as degrade paths
    assert _check(check_hooks, "hooks_good.py") == []


# --------------------------------------------------------------------- #
# the repo contract: strict-clean, live baseline, registry in sync
# --------------------------------------------------------------------- #

@pytest.fixture(scope="module")
def repo_findings():
    return run_checks(find_modules([SRC]))


def test_repo_is_strict_clean(repo_findings):
    baseline = Baseline.load(BASELINE)
    active, waived = split_baselined(repo_findings, baseline)
    assert active == [], "non-baselined findings:\n" + "\n".join(
        f.render() for f in active)
    assert baseline.unused() == [], "stale baseline waivers"
    assert waived, "the baseline should be waiving the intentional violations"


def test_baseline_entries_all_have_justifications():
    data = json.loads(BASELINE.read_text())
    assert data["waivers"], "baseline exists and is non-trivial"
    for e in data["waivers"]:
        assert e["justification"].strip(), e


def test_event_registry_matches_code():
    assert registry_drift(find_modules([SRC])) == []


def test_event_registry_covers_known_core_events():
    extracted = extract_registry(find_modules([SRC]))
    # spot-check load-bearing names the chaos coverage keys off
    assert {"finished", "error", "submitted"} <= extracted["task"]
    assert {"denylist_add", "heartbeat_lost", "node_drain"} <= extracted["system"]
    assert "serve.queue_depth" in extracted["gauge"]


def test_stale_waiver_detected():
    baseline = Baseline([{"rule": "CLK001", "file": "nope.py",
                          "symbol": "ghost", "justification": "x"}])
    active, waived = split_baselined([], baseline)
    assert active == [] and waived == []
    assert len(baseline.unused()) == 1


def test_baseline_match_ignores_line_churn():
    baseline = Baseline([{"rule": "CLK001", "file": "a.py",
                          "symbol": "f", "justification": "x"}])
    f1 = Finding(rule="CLK001", file="a.py", line=10, col=0, symbol="f",
                 message="m")
    f2 = Finding(rule="CLK001", file="a.py", line=99, col=4, symbol="f",
                 message="m")
    assert baseline.match(f1) and baseline.match(f2)


def test_finding_render_is_ruff_style():
    f = Finding(rule="CLK001", file="engine/dfk.py", line=12, col=4,
                symbol="DataFlowKernel.submit", message="raw time.time() call",
                hint="use clock.time()")
    out = f.render()
    assert out.startswith("engine/dfk.py:12:4 CLK001 [DataFlowKernel.submit]")
    assert "fix: use clock.time()" in out


# --------------------------------------------------------------------- #
# the CLI: what CI actually runs
# --------------------------------------------------------------------- #

def _run_cli(*args: str) -> subprocess.CompletedProcess:
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True, text=True, cwd=REPO,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"})


def test_cli_strict_passes_on_repo():
    proc = _run_cli("--strict")
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_cli_check_registry_passes_on_repo():
    proc = _run_cli("--check-registry")
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_cli_strict_fails_on_bad_fixture():
    proc = _run_cli("--strict", "--no-baseline",
                    str(FIXTURES / "clock_bad.py"))
    assert proc.returncode == 1
    assert "CLK001" in proc.stdout
