"""Continuous serving plane under the deterministic simulation clock:
slot reuse, SLO admission, autoscaling, chaos-trace determinism."""
import pytest

from repro.core import MonitoringDatabase
from repro.engine.policies import replay
from repro.serve import (ReplicaAutoscaler, RequestQueue, ServeRequest,
                         SLOAdmissionPolicy, WrathServeDriver)
from repro.sim import (ServeFault, ServeRequestSpec, ServeScenario,
                       VirtualClock, run_serve_scenario, serve_campaign)

STEP_S = 0.02


def _driver(**kw):
    clock = kw.pop("clock", None) or VirtualClock()
    monitor = kw.pop("monitor", None) or MonitoringDatabase(
        clock=clock, keep_event_log=True)
    kw.setdefault("decode", "sim")
    return WrathServeDriver(None, clock=clock, monitor=monitor, **kw)


def _req(rid, prompt_len=3, new=6, deadline_s=None):
    return ServeRequest(rid=rid, prompt=list(range(1, prompt_len + 1)),
                        max_new_tokens=new, deadline_s=deadline_s)


# ---------------------------------------------------- continuous batching --
def test_slot_vacated_and_reused_before_batch_mates_finish():
    """A finished request's slot is refilled at the step boundary — the
    queued request completes while the long slot-mate is still decoding."""
    driver = _driver(n_replicas=1, max_batch=2)
    long = _req(0, new=10)
    short = _req(1, new=2)
    queued = _req(2, new=2)
    rep = driver.serve_continuous([long, short, queued], horizon=30.0)
    driver.shutdown()
    assert rep.completed == 3 and rep.failed == 0
    # static batching would hold `queued` until `long` finished
    assert short.finish_t < long.finish_t
    assert queued.finish_t < long.finish_t
    assert len(long.generated) == 10 and len(queued.generated) == 2


def test_virtual_clock_timing_is_exact():
    """Decode wall time is the modeled step cost, nothing else — the
    driver's clock protocol keeps the whole plane on virtual time."""
    driver = _driver(n_replicas=1, max_batch=1)
    req = _req(0, prompt_len=3, new=4)       # steps_total = 6
    rep = driver.serve_continuous([req], horizon=10.0)
    driver.shutdown()
    assert rep.decode_steps == 6
    assert req.latency_s == pytest.approx(6 * STEP_S)


def test_static_serve_runs_on_virtual_clock():
    driver = _driver(n_replicas=2, max_batch=2)
    reqs = [_req(i, prompt_len=3, new=4) for i in range(2)]
    rep = driver.serve(reqs)
    assert rep.completed == 2
    # 6 steps at the modeled cost, measured on the virtual clock
    assert rep.wall_s == pytest.approx(rep.decode_steps * STEP_S)


# ------------------------------------------------------------- admission --
def test_infeasible_deadline_rejected_at_admission_without_decode():
    driver = _driver(n_replicas=1, max_batch=2,
                     admission=SLOAdmissionPolicy(default_step_s=STEP_S))
    doomed = _req(0, prompt_len=5, new=16, deadline_s=0.1)   # needs 0.4s
    fine = _req(1, prompt_len=3, new=4, deadline_s=5.0)
    rep = driver.serve_continuous([doomed, fine], horizon=30.0)
    driver.shutdown()
    assert doomed.status == "rejected" and "SLO infeasible" in doomed.reason
    assert doomed.generated == []            # zero decode steps consumed
    assert fine.status == "done"
    assert rep.rejected == 1 and rep.completed == 1
    # only the feasible request's steps ever ran (steps_total is 0 once
    # a request is complete — it derives from replay state, not history)
    assert rep.decode_steps == len(fine.prompt) + fine.max_new_tokens - 1
    assert fine.steps_total == 0
    events = [e["event"] for e in driver.monitor.event_log
              if e.get("rid") == 0]
    assert events == ["request_rejected"]


def test_admission_estimate_tracks_monitored_decode_profile():
    clock = VirtualClock()
    monitor = MonitoringDatabase(clock=clock)
    pol = SLOAdmissionPolicy(default_step_s=0.01, min_samples=3)
    assert pol.step_estimate_s(monitor) == 0.01      # no samples yet
    for _ in range(5):
        monitor.record_task_placement("decode_step", "replica0", "serve",
                                      ok=True, duration=0.25)
    assert pol.step_estimate_s(monitor) == pytest.approx(0.25)


def test_bounded_queue_sheds_overflow():
    clock = VirtualClock()
    q = RequestQueue(clock=clock, capacity=2)
    assert q.push(_req(0)) and q.push(_req(1))
    r = _req(2)
    assert not q.push(r)
    assert r.status == "rejected" and "queue full" in r.reason


def test_queue_sheds_expired_deadline_at_pop():
    clock = VirtualClock()
    q = RequestQueue(clock=clock)
    r = _req(0, deadline_s=0.5)
    q.push(r)
    clock.advance(1.0)
    assert q.pop_ready(4) == []
    assert r.status == "shed" and "deadline" in r.reason


# ------------------------------------------------------------ autoscaler --
def test_autoscaler_grows_into_backlog_and_shrinks_after_drain():
    driver = _driver(
        n_replicas=1, max_batch=2,
        policy=[ReplicaAutoscaler(min_replicas=1, max_replicas=4,
                                  patience=2, idle_ticks=3)])
    reqs = [_req(i, prompt_len=4, new=6) for i in range(30)]
    rep = driver.serve_continuous(reqs, arrivals=[0.0] * 30, horizon=60.0,
                                  tick_period=0.1, drain_s=2.0)
    driver.shutdown()
    assert rep.completed == 30
    assert rep.autoscaled_up > 0
    assert rep.autoscaled_down > 0
    assert rep.replicas_final == 1           # back to the floor
    events = [e["event"] for e in driver.monitor.event_log]
    assert "autoscale_grow" in events and "autoscale_shrink" in events


def test_autoscaler_replaces_lost_replica_below_floor():
    driver = _driver(
        n_replicas=2, max_batch=2,
        policy=[ReplicaAutoscaler(min_replicas=2, max_replicas=4,
                                  patience=2, idle_ticks=100)])
    reqs = [_req(i, new=8) for i in range(8)]
    rep = driver.serve_continuous(
        reqs, arrivals=[0.02 * i for i in range(8)],
        faults=[(0.1, "kill", "replica1")], horizon=60.0, tick_period=0.1)
    driver.shutdown()
    assert rep.completed == 8
    assert rep.autoscaled_up >= 1            # capacity repair
    assert len(driver.live_replicas()) >= 2


# ---------------------------------------------------------------- chaos --
def test_failover_requeues_in_flight_without_token_loss():
    driver = _driver(n_replicas=3, max_batch=2)
    reqs = [_req(i, new=6) for i in range(6)]
    rep = driver.serve_continuous(
        reqs, arrivals=[0.01 * i for i in range(6)],
        faults=[(0.05, "kill", "replica0")], horizon=60.0)
    driver.shutdown()
    assert rep.completed == 6 and rep.failed == 0
    assert rep.recoveries and "replica0" in rep.denylisted
    assert all(len(r.generated) == r.max_new_tokens for r in reqs)
    assert any(r.recoveries > 0 for r in reqs)


def test_denylist_updates_with_custom_policy_stack_continuous():
    """Regression: with a non-WRATH stack nothing used to maintain the
    driver denylist — retries could be routed back at the dead replica."""
    driver = _driver(n_replicas=3, max_batch=2, policy=[replay(3)])
    reqs = [_req(i, new=6) for i in range(6)]
    rep = driver.serve_continuous(
        reqs, arrivals=[0.01 * i for i in range(6)],
        faults=[(0.05, "kill", "replica0")], horizon=60.0)
    driver.shutdown()
    assert rep.completed == 6
    assert "replica0" in rep.denylisted
    adds = [e for e in driver.monitor.event_log
            if e["event"] == "denylist_add"]
    assert adds and adds[0]["source"] == "serve_driver"


def test_denylist_updates_with_custom_policy_stack_static():
    driver = _driver(n_replicas=3, max_batch=2, policy=[replay(3)])
    reqs = [_req(i, new=6) for i in range(4)]
    rep = driver.serve(reqs, kill_replica_at=("replica0", 2))
    assert rep.completed == 4
    assert "replica0" in rep.denylisted


def test_chaos_scenario_trace_byte_identical():
    scenario = ServeScenario(
        seed=0, n_replicas=3, max_batch=2, step_s=STEP_S,
        requests=[ServeRequestSpec(at=0.01 * i, prompt=(1, 2, 3),
                                   max_new_tokens=5,
                                   deadline_s=2.0 if i % 2 else None)
                  for i in range(12)],
        faults=[ServeFault(at=0.08, kind="kill", replica="replica1"),
                ServeFault(at=0.5, kind="restore", replica="replica1")],
        admission=True, autoscale=True)
    a = run_serve_scenario(scenario)
    b = run_serve_scenario(scenario)
    assert a.ok, a.violations
    assert a.trace == b.trace
    assert "replica_lost" in a.trace and "fault_injected" in a.trace


def test_seeded_serve_campaign_invariants_hold():
    results = serve_campaign(8, base_seed=1234, check_determinism=True)
    bad = [(r.seed, r.violations) for r in results if not r.ok]
    assert not bad, bad


# --------------------------------- decode-step accounting regressions --
def test_steps_total_derives_from_replay_state():
    """Regression: steps_total used to read only the original prompt, so
    a failed-over request (recovered tokens teacher-forced back into the
    feed) under-counted its remaining work in every backlog projection."""
    fresh = _req(0, prompt_len=3, new=6)
    assert fresh.steps_total == 3 + 6 - 1          # classic prefill+decode
    recovered = _req(1, prompt_len=3, new=6)
    recovered.generated = [7, 8]                   # survived a replica loss
    # replay feeds prompt+recovered (5 tokens), then decodes the 4 left;
    # the final step consumes the last feed slot AND emits the last token
    assert recovered.steps_total == 5 + 4 - 1
    finished = _req(2, prompt_len=3, new=2)
    finished.generated = [1, 2]
    assert finished.steps_total == 0               # nothing left to owe


def test_steps_remaining_tracks_live_slot_state():
    req = _req(0, prompt_len=3, new=6)
    req.feed = list(req.prompt)
    req.pos = 2                                    # mid-prefill
    assert req.steps_remaining == (3 - 2) + 6 - 1
    req.pos = 3
    req.generated = [9, 9, 9]
    assert req.steps_remaining == 3 - 1            # 3 tokens still to emit
    req.generated = [9] * 6
    assert req.steps_remaining == 0


def test_backlog_steps_sums_queue_totals_and_occupant_remainders():
    """Regression: each occupant used to contribute one phantom step to
    the backlog (its final step double-counted), inflating admission's
    queue-delay projection."""
    driver = _driver(n_replicas=1, max_batch=1)
    occupant = _req(0, prompt_len=3, new=8)
    waiting = _req(1, prompt_len=2, new=4)
    # seat the occupant mid-flight and queue the waiter
    driver._slots["replica0"].admit(occupant)
    occupant.pos = 2                              # two prefill steps done
    driver.queue.push(waiting)
    # occupant owes (3-2) feed + 8 new - 1 shared final step = 8;
    # the waiter owes its full 2 + 4 - 1 = 5 from admission
    assert occupant.steps_remaining == 8
    assert waiting.steps_total == 5
    assert driver.backlog_steps() == 13           # not 14: no phantom step
    driver.shutdown()


def test_failover_replay_steps_match_steps_total():
    """After a mid-decode replica loss the requeued request's
    steps_total equals the steps its replay actually consumes."""
    driver = _driver(n_replicas=2, max_batch=1)
    victim = _req(0, prompt_len=3, new=8)
    rep = driver.serve_continuous(
        [victim], arrivals=[0.0],
        faults=[(0.05, "kill", "replica0")],
        horizon=30.0)
    driver.shutdown()
    assert rep.completed == 1
    assert victim.recoveries >= 1
    assert len(victim.generated) == 8              # no token loss
    # replay accounting: steps after recovery = what steps_total promised
    # at requeue time (generated tokens teacher-forced, not re-decoded)
    assert victim.status == "done"


# ------------------------------------------- zero-slot admission gate --
def test_total_outage_rejects_slo_requests_at_admission():
    """Regression: with zero live replicas the old projection divided by
    max(slots, 1) — one phantom slot — and admitted requests that could
    not possibly start, let alone meet a deadline."""
    clock = VirtualClock()
    monitor = MonitoringDatabase(clock=clock, keep_event_log=True)
    driver = _driver(clock=clock, monitor=monitor, n_replicas=2,
                     max_batch=2,
                     admission=SLOAdmissionPolicy(default_step_s=STEP_S))
    slo = _req(0, prompt_len=3, new=4, deadline_s=5.0)
    besteffort = _req(1, prompt_len=3, new=4)
    rep = driver.serve_continuous(
        [slo, besteffort], arrivals=[0.2, 0.25],
        faults=[(0.05, "kill", "replica0"), (0.05, "kill", "replica1"),
                (1.0, "restore", "replica0")],
        horizon=30.0)
    driver.shutdown()
    # the SLO request arrived mid-outage: rejected at the door, no decode
    assert slo.status == "rejected"
    assert "no live decode slots" in slo.reason
    assert slo.generated == []
    # best-effort requests queue through the outage and finish after heal
    assert besteffort.status == "done"
    assert rep.rejected == 1 and rep.completed == 1


def test_serve_scenarios_sample_total_outage_windows():
    """The seeded sampler reaches the zero-slot regime: outage windows
    kill the whole pool (floor replica included) and always heal."""
    from repro.sim import ServeScenario, serve_campaign

    results = serve_campaign(20, base_seed=0, check_determinism=True,
                             scenario_kwargs={"outage_rate": 0.6})
    bad = [(r.seed, r.violations) for r in results if not r.ok]
    assert not bad, bad
    outage = [r for r in results
              if any(f.replica == "replica0" and f.kind == "kill"
                     for f in r.scenario.faults)]
    assert outage, "outage_rate=0.6 sampled no total outages in 20 seeds"
    # rate 0.0 must leave pre-existing seeds byte-identical (gated RNG)
    for seed in (0, 3, 11):
        assert ServeScenario.random(seed) == ServeScenario.random(
            seed, outage_rate=0.0)


# ------------------------------------------------ autoscaler cooldown --
def test_autoscaler_never_grows_back_to_back():
    """Regression: after a grow the gauge window still held pre-decision
    samples, so a sustained burst triggered a second grow on the very
    next tick — two replicas for one backlog signal.  The post-decision
    cooldown must keep load-following grows a full patience window apart
    without changing what the run converges to."""
    driver = _driver(
        n_replicas=1, max_batch=2,
        policy=[ReplicaAutoscaler(min_replicas=1, max_replicas=6,
                                  patience=2, idle_ticks=3)])
    reqs = [_req(i, prompt_len=4, new=8) for i in range(40)]
    rep = driver.serve_continuous(reqs, arrivals=[0.0] * 40, horizon=60.0,
                                  tick_period=0.1, drain_s=2.0)
    events = [e for e in driver.monitor.event_log
              if e["event"] == "autoscale_grow"
              and e.get("reason") == "sustained backlog"]
    driver.shutdown()
    assert rep.completed == 40
    assert len(events) >= 2                  # the burst still scales out
    gaps = [b["time"] - a["time"] for a, b in zip(events, events[1:])]
    assert all(g >= 2 * 0.1 - 1e-9 for g in gaps), gaps


def test_autoscaler_cooldown_preserves_determinism():
    scenario = ServeScenario(
        seed=0, n_replicas=1, max_batch=2, step_s=STEP_S,
        requests=[ServeRequestSpec(at=0.01 * i, prompt=(1, 2, 3, 4),
                                   max_new_tokens=8)
                  for i in range(24)],
        admission=False, autoscale=True, max_replicas=4,
        tick_period=0.1)
    a = run_serve_scenario(scenario)
    b = run_serve_scenario(scenario)
    assert a.ok, a.violations
    assert a.trace == b.trace
    assert "autoscale_grow" in a.trace


def test_autoscaler_capacity_repair_ignores_cooldown():
    """Replica loss below the floor is repaired immediately even inside
    a cooldown window — availability beats smoothing."""
    driver = _driver(
        n_replicas=2, max_batch=2,
        policy=[ReplicaAutoscaler(min_replicas=2, max_replicas=6,
                                  patience=2, idle_ticks=100,
                                  cooldown_ticks=50)])
    reqs = [_req(i, new=8) for i in range(10)]
    rep = driver.serve_continuous(
        reqs, arrivals=[0.02 * i for i in range(10)],
        faults=[(0.15, "kill", "replica1")], horizon=60.0,
        tick_period=0.1)
    driver.shutdown()
    assert rep.completed == 10
    repairs = [e for e in driver.monitor.event_log
               if e["event"] == "autoscale_grow"
               and e.get("reason") == "below min_replicas"]
    assert repairs                            # repaired despite cooldown
